"""``import codo`` — alias for :mod:`repro.api`, the traced-function
frontend of the CODO reproduction:

.. code-block:: python

    import codo

    def model(x):
        h = codo.F.fc(x, 512, relu=True)
        return codo.F.fc(h, 512) + x

    program = codo.compile(model, (64, 512))
    y = program(x_array)

See docs/frontend.md for the walkthrough and ``repro.core`` for the
low-level compiler API (``codo_opt``).
"""

from repro.api import (CodoOptions, CompiledProgram, F, ShapedBuffer,  # noqa: F401
                       TraceError, buffer, compile, load, trace)

__all__ = ["CodoOptions", "CompiledProgram", "F", "ShapedBuffer",
           "TraceError", "buffer", "compile", "load", "trace"]
