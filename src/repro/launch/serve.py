"""Serving launcher: batched greedy generation with slot-based batching,
plus a mode that serves a *compiled-design artifact* directly.

CPU-scale LM demo:
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-medium --smoke \\
        --requests 6 --batch 4 --max-new 8

Artifact serving — no recompile, no model code: import a versioned JSON
artifact (docs/artifact_format.md), lower it through the op registry, and
run a request loop against the jitted program:

    PYTHONPATH=src python -m repro.core.compiler --configs gpt2-medium \\
        --opts opt5 --export artifacts/
    PYTHONPATH=src python -m repro.launch.serve \\
        --artifact artifacts/gpt2-medium-opt5.json --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def serve_artifact(args) -> int:
    """Serve straight from an imported artifact: the design the compiler
    exported is the unit of deployment — this launcher never sees the
    model-building code that produced it."""
    from repro.core import lower
    from repro.core.artifact import artifact_summary, import_artifact
    from repro.kernels import register_all
    from repro.models.dataflow_models import random_inputs

    register_all()     # fused-group kinds resolve against this process
    compiled = import_artifact(args.artifact)   # validates before anything
    print(artifact_summary(args.artifact))
    low = lower(compiled)          # jitted
    print(low.summary())

    envs = [random_inputs(compiled.graph, seed=args.seed + i)
            for i in range(args.requests)]
    outs = low(envs[0])            # warmup: trace + compile
    jax.block_until_ready(outs)

    t0 = time.time()
    for env in envs:
        jax.block_until_ready(low(env))
    dt = time.time() - t0
    out_names = sorted(b.name for b in compiled.graph.outputs())
    print(f"{args.requests} requests in {dt * 1e3:.1f} ms "
          f"({args.requests / max(dt, 1e-9):.1f} req/s); "
          f"outputs {out_names}")
    return 0


def serve_lm(args) -> int:
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.serving.serve import Generator, Request

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    gen = Generator(cfg, params, batch=args.batch, cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        gen.submit(Request(rid, prompt=list(
            rng.integers(1, cfg.vocab, size=args.prompt_len)),
            max_new=args.max_new))

    t0 = time.time()
    finished = gen.run(max_steps=args.cache_len - 1)
    dt = time.time() - t0
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    print(f"{len(finished)}/{args.requests} finished; {gen.steps} decode "
          f"steps, {gen.tokens_out} tokens, "
          f"{gen.tokens_out / max(dt, 1e-9):.1f} tok/s (CPU smoke)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="LM architecture to serve (token generation)")
    ap.add_argument("--artifact", default="",
                    help="serve a compiled-design JSON artifact instead "
                         "(see docs/artifact_format.md)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if bool(args.arch) == bool(args.artifact):
        ap.error("exactly one of --arch or --artifact is required")
    if args.artifact and args.requests < 1:
        ap.error("--requests must be >= 1 when serving an artifact")
    return serve_artifact(args) if args.artifact else serve_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
