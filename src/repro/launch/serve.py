"""Deprecated alias — the serving CLI moved to :mod:`repro.serving.cli`
(the launcher now rides on the :class:`~repro.serving.runtime.
ServingRuntime`: dynamic batching, worker pool, hot-swap; see
``docs/serving.md``).

This shim warns once on import and delegates everything — ``python -m
repro.launch.serve`` keeps working, as do the documented
:class:`InputError` / :func:`load_input_env` / :func:`main` entry points.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.launch.serve is deprecated: use repro.serving.cli "
    "(python -m repro.serving.cli) instead",
    DeprecationWarning, stacklevel=2)

from repro.serving.cli import (InputError, load_input_env,  # noqa: E402
                               main, serve_artifact, serve_lm)

__all__ = ["InputError", "load_input_env", "main", "serve_artifact",
           "serve_lm"]


if __name__ == "__main__":
    raise SystemExit(main())
