"""Serving launcher: batched greedy generation with slot-based batching.

CPU-scale demo:
    PYTHONPATH=src python -m repro.launch.serve --arch gpt2-medium --smoke \\
        --requests 6 --batch 4 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf
from repro.serving.serve import Generator, Request


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    gen = Generator(cfg, params, batch=args.batch, cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        gen.submit(Request(rid, prompt=list(
            rng.integers(1, cfg.vocab, size=args.prompt_len)),
            max_new=args.max_new))

    t0 = time.time()
    finished = gen.run(max_steps=args.cache_len - 1)
    dt = time.time() - t0
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    print(f"{len(finished)}/{args.requests} finished; {gen.steps} decode "
          f"steps, {gen.tokens_out} tokens, "
          f"{gen.tokens_out / max(dt, 1e-9):.1f} tok/s (CPU smoke)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
