import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf iteration driver: compile one (arch × shape × mesh) cell under a
set of optimization-switch combinations and print the roofline-term
comparison — the hypothesis→change→measure loop as a command.

    PYTHONPATH=src python -m repro.launch.perf --arch gemma-7b \\
        --shape train_4k \\
        --variant base \\
        --variant h1:REPRO_ATTN_OPT=1 \\
        --variant h1d:REPRO_ATTN_OPT=1,REPRO_REMAT_POLICY=dots

Each variant spawns a fresh subprocess (the switches are read at import
time) running the dry-run for the cell, then the parent prints a table.
"""

import argparse
import json
import subprocess
import sys
import tempfile
from pathlib import Path


def run_variant(arch: str, shape: str, mesh: str, name: str,
                env_pairs: list[str], outdir: Path) -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # child sets its own
    for pair in env_pairs:
        k, v = pair.split("=", 1)
        env[k] = v
    vdir = outdir / name
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(vdir), "--force"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    rec = json.loads(next(vdir.glob("*.json")).read_text())
    rec["variant"] = name
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", action="append", default=[],
                    help="name[:ENV=V,ENV=V...]; 'base' = no switches")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    outdir = Path(args.out) if args.out else Path(tempfile.mkdtemp(prefix="perf_"))
    variants = args.variant or ["base"]

    sys.path.insert(0, str(Path(__file__).resolve().parents[3] / "benchmarks"))
    from benchmarks.roofline import roofline_row  # noqa: E402

    rows = []
    for v in variants:
        name, _, envs = v.partition(":")
        pairs = [p for p in envs.split(",") if p]
        rec = run_variant(args.arch, args.shape, args.mesh, name, pairs, outdir)
        rows.append((name, roofline_row(rec)))
        r = rows[-1][1]
        print(f"{name:<12s} compute={r.compute_s:9.4f}s memory={r.memory_s:9.4f}s "
              f"collective={r.collective_s:9.4f}s dominant={r.dominant:<10s} "
              f"useful={r.useful_ratio:5.2f} roofline={r.roofline_fraction:8.5f} "
              f"peak={r.peak_gib:6.2f}GiB", flush=True)
    if len(rows) > 1:
        base, last = rows[0][1], rows[-1][1]
        if base.roofline_fraction > 0:
            print(f"\nroofline gain {rows[-1][0]} vs {rows[0][0]}: "
                  f"{last.roofline_fraction / base.roofline_fraction:.2f}x")
    print(f"records in {outdir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
