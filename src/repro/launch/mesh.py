"""Production mesh construction (multi-pod dry-run §MULTI-POD).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state.  Shapes:

* single-pod: (16, 16)    axes ("data", "model")  — 256 chips
* multi-pod:  (2, 16, 16) axes ("pod", "data", "model") — 512 chips

``submesh`` builds the single-pod mesh out of the first 256 of 512 host
devices so one dry-run process can exercise both meshes.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == ndev:
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    if len(devs) > ndev:  # e.g. single-pod mesh on a 512-device host platform
        arr = np.asarray(devs[:ndev]).reshape(shape)
        return Mesh(arr, axes)
    raise RuntimeError(
        f"need {ndev} devices for mesh {shape}, have {len(devs)} — run under "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={ndev} (dry-run) "
        f"or on real hardware")


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for CPU multi-device tests (8 host devices)."""
    ndev = int(np.prod(shape))
    arr = np.asarray(jax.devices()[:ndev]).reshape(shape)
    return Mesh(arr, axes)


def mesh_from_spec(spec) -> Mesh:
    """Reconstruct a device mesh from a pure-data
    :class:`repro.distributed.plan.MeshSpec` (or a jax ``Mesh``, passed
    through).  This is the only place a :class:`ShardingPlan` touches
    device state, so an exported plan reloads on any machine with enough
    devices — CPU CI included."""
    if isinstance(spec, Mesh):
        return spec
    from repro.distributed.plan import MeshSpec
    spec = MeshSpec.of(spec)
    devs = jax.devices()
    if len(devs) < spec.size:
        raise RuntimeError(
            f"sharding plan needs {spec.size} devices for mesh "
            + "x".join(f"{n}:{s}" for n, s in spec.axes)
            + f", have {len(devs)} — run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={spec.size} or on "
            f"real hardware")
    shape = tuple(s for _, s in spec.axes)
    arr = np.asarray(devs[:spec.size]).reshape(shape)
    return Mesh(arr, spec.names)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh: Mesh) -> str:
    return "model"
