"""Deprecated alias — the training CLI moved to :mod:`repro.training.cli`
(the launcher now also drives the graph-level-autodiff
:class:`~repro.api.CompiledTrainStep` via ``--compiled``; see
``docs/autodiff.md``).

This shim warns once on import and delegates everything — ``python -m
repro.launch.train`` keeps working, as does the documented :func:`main`
entry point.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.launch.train is deprecated: use repro.training.cli "
    "(python -m repro.training.cli) instead",
    DeprecationWarning, stacklevel=2)

from repro.training.cli import main  # noqa: E402

__all__ = ["main"]


if __name__ == "__main__":
    raise SystemExit(main())
