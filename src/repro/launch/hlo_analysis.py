"""Compiled-HLO walker: loop-aware FLOP / byte / collective accounting.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**,
regardless of trip count — under a layer-``scan`` (and the nested
blockwise-attention scans) that undercounts a transformer step by orders
of magnitude.  This walker parses the post-optimization HLO text and
aggregates per-computation costs **multiplied through while-loop trip
counts**:

* FLOPs       — ``dot`` ops: 2 · |result| · K (K = contracted extent from
  the lhs operand's shape, resolved via a per-computation symbol table).
* HBM bytes   — fusion-boundary traffic: for every materializing op
  (fusion, dot, dynamic-slice/update, copy, collectives, ...) the result
  bytes + operand bytes.  Values internal to a fusion never hit memory —
  exactly XLA's own bytes-accessed convention, but loop-aware.
* Collectives — per-type link bytes with ring-algorithm multipliers
  (see EXPERIMENTS.md §Roofline) using ``replica_groups`` sizes.

Trip counts come from each while-condition computation's comparison
constant (jax scans lower to ``iter < C``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")
_CALL_ATTR = re.compile(
    r"(?:calls=|to_apply=|condition=|body=)%([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACES = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

# ops whose operands/results cross a memory boundary
_MATERIALIZING = ("fusion(", "dot(", "convolution(", "dynamic-slice(",
                  "dynamic-update-slice(", "copy(", "gather(", "scatter(",
                  "sort(", "reduce(", "transpose(", "concatenate(", "pad(",
                  "select(", "custom-call(")

# in-place-aliased accumulators: traffic = slice, not the whole buffer
_ALIASING = ("dynamic-update-slice", "dynamic_update_slice", "dynamic-slice",
             "dynamic_slice")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(text: str) -> tuple[str, int] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return m.group(1), _shape_elems(m.group(2))


def _all_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # var -> "dt[dims]"


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective: dict = field(default_factory=lambda: {
        k: 0.0 for k in _COLL_OPS} | {"count": 0.0})

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k in self.collective:
            self.collective[k] += other.collective.get(k, 0.0) * mult

    @property
    def collective_total(self) -> float:
        return sum(v for k, v in self.collective.items() if k != "count")


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                for var, shape in _PARAM_RE.findall(line):
                    cur.symbols[var] = shape
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            var, rhs = dm.group(1), dm.group(2)
            fs = _SHAPE_RE.match(rhs.strip().lstrip("("))
            if fs:
                cur.symbols[var] = f"{fs.group(1)}[{fs.group(2)}]"
    return comps


def _dot_flops(line: str, comp: Computation) -> float:
    dm = _DEF_RE.match(line)
    if not dm:
        return 0.0
    rhs = dm.group(2)
    res = _first_shape(rhs.split("dot(")[0])
    if res is None:
        return 0.0
    _dt, out_elems = res
    # contracted extent from lhs operand shape + lhs_contracting_dims
    args = rhs[rhs.index("dot(") + 4:]
    arg_names = re.findall(r"%([\w.\-]+)", args.split(")")[0])
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    k = 1
    if arg_names and cm:
        lhs_shape = comp.symbols.get(arg_names[0])
        if lhs_shape:
            dims = [int(d) for d in
                    _SHAPE_RE.match(lhs_shape).group(2).split(",") if d]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _operand_shapes(rhs: str, comp: Computation) -> list[str]:
    op_start = rhs.find("(")
    if op_start < 0:
        return []
    arg_str = rhs[op_start + 1:]
    depth, end = 1, 0
    for i, ch in enumerate(arg_str):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    out = []
    for name in re.findall(r"%([\w.\-]+)", arg_str[:end]):
        shp = comp.symbols.get(name)
        if shp:
            out.append(shp)
    return out


def _line_bytes(line: str, rhs: str, comp: Computation) -> float:
    """HBM traffic of one materializing op.

    * plain op: result bytes + operand bytes (XLA's bytes-accessed
      convention at the fusion boundary);
    * dynamic-(update-)slice, or a fusion wrapping one: the big buffer is
      aliased in place — traffic is the *slice* (operands whose shape
      differs from the result) read+written, not the whole accumulator.
    """
    result_b = float(_all_bytes(rhs.split("(")[0] if "(" in rhs else rhs))
    operands = _operand_shapes(rhs, comp)
    aliasing = any(tok in rhs for tok in _ALIASING) or \
        any(tok in line.split("=")[0] for tok in _ALIASING)
    if aliasing:
        op_bytes = [_all_bytes(s) for s in operands]
        big_op = max(op_bytes, default=0)
        if result_b >= big_op:
            # dus-like: result is the aliased accumulator; traffic = the
            # update slice (largest operand smaller than the buffer)
            slice_b = max([b for b in op_bytes if b < result_b],
                          default=result_b)
            return 2.0 * slice_b
        # ds-like: an operand is the aliased buffer; traffic = the slice out
        return 2.0 * result_b
    return result_b + sum(_all_bytes(s) for s in operands)


def _fusion_param_charges(called: Computation) -> list[float] | None:
    """Per-parameter HBM charge of a fusion computation.

    A fusion parameter whose only uses are ``dynamic-slice`` ops is read as
    slices (loop-carried big buffers: charge the slice, not the buffer);
    any other use reads the tensor fully.  Returns charges indexed by
    parameter number, or None if parsing fails.
    """
    params: dict[str, tuple[int, str]] = {}
    for line in called.lines:
        m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+\[[0-9,]*\])"
                     r"[^=]*parameter\((\d+)\)", line)
        if m:
            params[m.group(1)] = (int(m.group(3)), m.group(2))
    if not params:
        return None
    n = max(i for (i, _s) in params.values()) + 1
    charges = [0.0] * n
    sliced_only = {name: True for name in params}
    slice_bytes = {name: 0.0 for name in params}
    for line in called.lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rhs = dm.group(2)
        if re.search(r"parameter\(\d+\)", rhs):
            continue
        used = [nm for nm in re.findall(r"%([\w.\-]+)", rhs) if nm in params]
        if not used:
            continue
        is_ds = bool(re.search(r"\bdynamic-slice\(", rhs))
        for nm in used:
            if is_ds and nm == used[0]:
                slice_bytes[nm] += _all_bytes(rhs.split("(")[0])
            else:
                sliced_only[nm] = False
    for nm, (idx_, shp) in params.items():
        if sliced_only[nm] and slice_bytes[nm] > 0:
            charges[idx_] += slice_bytes[nm]
        else:
            charges[idx_] += _all_bytes(shp)
    return charges


def _trip_count(cond_name: str, comps: dict[str, Computation]) -> int:
    """Max s32 constant in the condition computation (+1 level of calls)."""
    seen = []
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    text = "\n".join(comp.lines)
    seen += [int(x) for x in _CONST_RE.findall(text)]
    for callee in _CALL_ATTR.findall(text):
        sub = comps.get(callee)
        if sub:
            seen += [int(x) for x in _CONST_RE.findall("\n".join(sub.lines))]
    return max(seen) if seen else 1


def _collective_moved(op: str, nbytes: float, g: int) -> float:
    if op == "all-reduce":
        return 2 * (g - 1) / g * nbytes
    if op == "all-gather":
        return (g - 1) / g * nbytes          # result = gathered tensor
    if op == "reduce-scatter":
        return float((g - 1)) * nbytes       # result = the shard
    if op == "all-to-all":
        return (g - 1) / g * nbytes
    return nbytes                             # collective-permute


def analyze(hlo: str, entry: str | None = None) -> HloCost:
    comps = parse_computations(hlo)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, HloCost] = {}

    def walk(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()          # break accidental cycles
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        cost = HloCost()
        # bytes are de-duplicated per tensor within one invocation of this
        # computation: a value read by many fusions inside one loop body
        # stays resident (the TPU mega-fusion view); sliced accumulators
        # still charge one slice per invocation.
        seen_tensors: set[str] = set()

        def tensor_bytes_unique(line: str, rhs: str) -> float:
            aliasing = any(tok in rhs for tok in _ALIASING) or \
                any(tok in line.split("=")[0] for tok in _ALIASING)
            if aliasing:
                return _line_bytes(line, rhs, comp)
            total = 0.0
            dm2 = _DEF_RE.match(line)
            res_name = dm2.group(1) if dm2 else None
            if res_name and res_name not in seen_tensors:
                seen_tensors.add(res_name)
                total += _all_bytes(rhs.split("(")[0])
            op_start = rhs.find("(")
            if op_start < 0:
                return total
            arg_str = rhs[op_start + 1:]
            depth, end = 1, 0
            for i, ch in enumerate(arg_str):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = re.findall(r"%([\w.\-]+)", arg_str[:end])
            # fusion interior analysis: parameters consumed only through
            # dynamic-slice charge the slice, not the full buffer
            charges = None
            cm = re.search(r"calls=%([\w.\-]+)", rhs)
            if cm and cm.group(1) in comps:
                charges = _fusion_param_charges(comps[cm.group(1)])
            for i, nm in enumerate(operands):
                shp = comp.symbols.get(nm)
                if not shp:
                    continue
                full = _all_bytes(shp)
                if charges is not None and i < len(charges):
                    charge = min(charges[i], full)
                    if charge < full:
                        total += charge      # sliced read: charge per call
                        continue
                if nm in seen_tensors:
                    continue
                seen_tensors.add(nm)
                total += full
            return total

        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            # ---- collectives ------------------------------------------------
            coll = next((c for c in _COLL_OPS
                         if re.search(rf"\b{c}(-start)?\(", rhs)), None)
            if coll is not None and f"{coll}-done(" not in rhs:
                gm = _GROUPS_RE.search(rhs)
                if gm:
                    g = int(gm.group(2))
                else:
                    gb = _GROUPS_BRACES.search(rhs)
                    g = len(gb.group(1).split(",")) if gb else 2
                nbytes = _all_bytes(rhs[:rhs.index(coll)])
                cost.collective[coll] += _collective_moved(coll, nbytes, max(g, 2))
                cost.collective["count"] += 1
                cost.bytes += nbytes
                continue
            # ---- while loops -----------------------------------------------
            if re.search(r"\bwhile\(", rhs):
                cm = re.search(r"condition=%([\w.\-]+)", rhs)
                bm = re.search(r"body=%([\w.\-]+)", rhs)
                trip = _trip_count(cm.group(1), comps) if cm else 1
                if bm:
                    cost.add(walk(bm.group(1)), mult=max(trip, 1))
                continue
            # ---- conditionals / calls ---------------------------------------
            br = _BRANCHES.search(rhs)
            if br:
                for callee in re.findall(r"%([\w.\-]+)", br.group(1)):
                    cost.add(walk(callee))
                continue
            called = _CALL_ATTR.findall(rhs)
            for callee in called:
                cost.add(walk(callee))
            # ---- flops --------------------------------------------------------
            if re.search(r"\bdot\(", rhs):
                cost.flops += _dot_flops(line, comp)
            if re.search(r"\b(exponential|tanh|logistic|log|rsqrt|power)\(", rhs):
                fs = _first_shape(rhs)
                if fs:
                    cost.transcendentals += fs[1]
            # ---- bytes ---------------------------------------------------------
            if any(tok in rhs for tok in _MATERIALIZING):
                cost.bytes += tensor_bytes_unique(line, rhs)
        memo[name] = cost
        return cost

    return walk(entry)
