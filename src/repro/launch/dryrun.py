import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) cell on the production meshes and dump
memory/cost/collective analysis for the roofline (deliverable g).

MUST keep the two lines above first: jax locks the device count on first
backend initialization.  Do NOT replicate that env var anywhere global —
smoke tests and benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi     # 2×16×16 only

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json with:
  status, flops/bytes (per device, from compiled.cost_analysis()),
  collective bytes per op type (parsed from compiled HLO),
  memory_analysis fields (proves it fits), MODEL_FLOPS, timings.
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import ASSIGNED, SHAPES, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import batch_spec, transformer as tf
from repro.distributed.sharding import (as_shardings, batch_specs,
                                        cache_specs, param_specs, use_mesh)
from repro.training.train_loop import build_train_step
from repro.training.optimizer import OptConfig
from repro.serving.generator import build_prefill_step, build_serve_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _tensor_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device link traffic by collective type (ring-algorithm
    accounting; see EXPERIMENTS.md §Roofline for the formulas)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        op, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            continue  # the matching -start already carried the payload
        lhs = line[:m.start()]
        nbytes = _tensor_bytes(lhs)
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 2
        if op == "all-reduce":
            moved = 2 * (g - 1) / g * nbytes
        elif op == "all-gather":
            moved = (g - 1) / g * nbytes            # lhs is the gathered result
        elif op == "reduce-scatter":
            moved = (g - 1) * nbytes                # lhs is the scattered shard
        elif op == "all-to-all":
            moved = (g - 1) / g * nbytes
        else:
            moved = nbytes
        out[op] += int(moved)
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS (global): 6·N_active·tokens for train, 2·N_active·tokens
    for inference-style cells."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_cell(arch_name: str, shape_name: str, mesh):
    """Returns (jitted_fn, arg_shapestructs) for the cell."""
    cfg = get_config(arch_name)
    shape = SHAPES[shape_name]
    pshapes = tf.param_shapes(cfg)
    pspecs = param_specs(pshapes, mesh, cfg)
    bspec_tree = batch_spec(cfg, shape)

    if shape.kind == "train":
        from jax.sharding import PartitionSpec as P
        oshapes = jax.eval_shape(
            lambda: {"m": jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, np.float32), pshapes),
                "v": jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, np.float32), pshapes),
                "step": jax.ShapeDtypeStruct((), np.int32)})
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        bspecs = batch_specs(bspec_tree, mesh)
        accum = int(os.environ.get("DRYRUN_ACCUM", "4"))
        pshard, oshard, bshard = (as_shardings(x, mesh)
                                  for x in (pspecs, ospecs, bspecs))
        fn = jax.jit(build_train_step(cfg, OptConfig(), accum=accum),
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        args = (pshapes, oshapes, bspec_tree)
    elif shape.kind == "prefill":
        bspecs = batch_specs(bspec_tree, mesh)
        fn = jax.jit(build_prefill_step(cfg),
                     in_shardings=(as_shardings(pspecs, mesh),
                                   as_shardings(bspecs, mesh)),
                     out_shardings=None)
        args = (pshapes, bspec_tree)
    else:  # decode
        from repro.distributed.sharding import sanitize_spec
        from jax.sharding import PartitionSpec as P
        cache_shapes = jax.eval_shape(
            lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = cache_specs(cache_shapes, mesh, cfg)
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        tok_spec = sanitize_spec(P(dp), (shape.global_batch,), mesh,
                                 strict=False)
        pshard, tshard, cshard = (as_shardings(x, mesh)
                                  for x in (pspecs, tok_spec, cspecs))
        fn = jax.jit(build_serve_step(cfg),
                     in_shardings=(pshard, tshard, cshard),
                     out_shardings=(tshard, None, cshard),
                     donate_argnums=(2,))
        args = (pshapes, bspec_tree["tokens"], cache_shapes)
    return fn, args


def run_cell(arch_name: str, shape_name: str, mesh_kind: str) -> dict:
    cfg = get_config(arch_name)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "params": cfg.param_count(), "active_params": cfg.active_param_count(),
           "model_flops": model_flops_for(cfg, shape)}
    ok, why = cfg.runnable(shape)
    if not ok:
        rec.update(status="skipped", skip_reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["mesh_shape"] = dict(mesh.shape)
    chips = int(np.prod(list(mesh.shape.values())))
    rec["chips"] = chips
    try:
        fn, args = build_cell(arch_name, shape_name, mesh)
        t0 = time.time()
        with use_mesh(mesh):
            lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ca = compiled.cost_analysis()
        if not isinstance(ca, dict):
            ca = ca[0]
        ma = compiled.memory_analysis()
        txt = compiled.as_text()
        # loop-aware walker (XLA's cost_analysis counts while bodies once —
        # see launch/hlo_analysis.py); raw values kept for reference.
        cost = hlo_analysis.analyze(txt)
        coll = dict(cost.collective)
        coll["total"] = cost.collective_total
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops_per_device=float(cost.flops),
            bytes_per_device=float(cost.bytes),
            transcendentals=float(cost.transcendentals),
            xla_flops_raw=float(ca.get("flops", 0.0)),
            xla_bytes_raw=float(ca.get("bytes accessed", 0.0)),
            collective=coll,
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                generated_code_bytes=ma.generated_code_size_in_bytes,
            ),
            hlo_bytes=len(txt),
        )
        # per-device peak = args + temps (aliased buffers counted once)
        rec["memory"]["peak_per_device"] = (
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    except Exception as e:  # noqa: BLE001 — recorded, not swallowed
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (the 10 assigned)")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="both", choices=["both", "single", "multi"])
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have a JSON")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                name = f"{arch}__{shape}__{mesh_kind}.json"
                path = outdir / name
                if path.exists() and not args.force:
                    rec = json.loads(path.read_text())
                    print(f"skip (cached) {name}: {rec['status']}")
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape, mesh_kind)
                rec["wall_s"] = round(time.time() - t0, 2)
                path.write_text(json.dumps(rec, indent=1))
                mem = rec.get("memory", {}).get("peak_per_device", 0) / 2**30
                print(f"{rec['status']:<8s} {name:<58s} "
                      f"flops/dev={rec.get('flops_per_device', 0):.3e} "
                      f"coll={rec.get('collective', {}).get('total', 0):.3e}B "
                      f"peak={mem:.2f}GiB wall={rec['wall_s']}s",
                      flush=True)
                if rec["status"] == "error":
                    failures += 1
                    print(rec["error"], file=sys.stderr)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
