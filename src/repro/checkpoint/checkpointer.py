"""Sharded, async, manifest-verified checkpointing with elastic restore.

Layout per step:

    <dir>/step_<N>/
        manifest.json      {step, leaf paths, shapes, dtypes, checksums}
        <leaf-hash>.npy    one file per pytree leaf

* **Async** — ``save()`` snapshots to host memory synchronously (cheap)
  and writes files on a background thread; ``wait()`` joins.
* **Integrity** — restore verifies per-leaf checksums and falls back to
  the newest *complete* checkpoint (a torn write from a killed host never
  poisons a restart).
* **Elastic** — leaves are stored whole (gathered); restore can therefore
  re-shard onto any mesh, including a *smaller* one after losing hosts
  (``restore_latest(shardings=...)`` places leaves per the new specs).
  At real fleet scale the same manifest format holds per-shard files; the
  gather/scatter here is the single-host degenerate case.
"""

from __future__ import annotations

import hashlib
import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        flat = _flatten(state)          # synchronous host snapshot
        treedef = jax.tree_util.tree_structure(state)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, str(treedef)), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, flat, treedef_repr: str) -> None:
        tmp = self.dir / f".tmp_step_{step}_{time.time_ns()}"
        tmp.mkdir(parents=True, exist_ok=True)
        manifest = {"step": step, "leaves": [], "treedef": treedef_repr,
                    "time": time.time()}
        for key, arr in flat:
            fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append({
                "key": key, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "checksum": hashlib.sha1(arr.tobytes()).hexdigest()[:16],
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)               # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def _load(self, step: int, verify: bool = True) -> dict[str, np.ndarray]:
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = {}
        for entry in manifest["leaves"]:
            arr = np.load(d / entry["file"])
            if verify:
                chk = hashlib.sha1(arr.tobytes()).hexdigest()[:16]
                if chk != entry["checksum"]:
                    raise IOError(f"checksum mismatch for {entry['key']} "
                                  f"at step {step}")
            leaves[entry["key"]] = arr
        return leaves

    def restore(self, step: int, like: Any, shardings: Any | None = None) -> Any:
        """Restore into the structure of ``like`` (values ignored), placing
        each leaf per ``shardings`` when given (elastic re-mesh path)."""
        leaves = self._load(step)
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(paths))
        out = []
        for (path, leaf), sh in zip(paths, shard_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = leaves[key]
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Any | None = None,
                       shardings: Any | None = None):
        """(step, state) from the newest checkpoint that verifies; torn or
        corrupt checkpoints are skipped."""
        for step in reversed(self.steps()):
            try:
                if like is None:
                    raw = self._load(step)
                    return step, raw
                return step, self.restore(step, like, shardings)
            except Exception:
                continue
        raise FileNotFoundError(f"no restorable checkpoint in {self.dir}")
