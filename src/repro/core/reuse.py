"""Violation-free reuse-buffer generation (paper §V-B, Fig. 7).

Stencil consumers (conv/pool windows) re-read each produced element up to
kh×kw times — incompatible with FIFO streaming.  The paper's fix builds a
**line buffer** (kh-1 retained rows) plus a **window buffer** (the kh×kw
working set) so every input element enters the task exactly once, in the
producer's row-major order, and all re-reads hit on-chip storage.

On TPU the line/window buffers are VMEM scratch inside the fused Pallas
kernel (see kernels/streamfuse); here the pass rewrites the IR so that

* the stencil read collapses to an exact-once read (index dims only,
  ``enclosing`` = the FIFO dims), arriving in (batch, spatial..., ci) order;
* the write's ``enclosing`` set is its own index dims (n, spatial..., co):
  the compute region runs as a sibling region under the spatial loops —
  Fig. 7's three-region structure;
* each loop is classified into the paper's safety rings:
  ``outer`` (red — unsafe to parallelize), ``fifo`` (orange — feasible but
  must be coordinated with the FIFO peer), ``reduction`` (green — free).

That classification is the *guidance for parallelism exploration* consumed
by schedule.py.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .graph import DataflowGraph, Task, idx
from .patterns import STENCIL_REREAD, fine_violations

_BATCH_VARS = ("n", "b")

# Pipeline declaration consumed by passes.default_passes().  Stencil
# rewriting changes stream orders, so reuse invalidates fine's guarantees:
# the manager re-runs fine right after ("reinvokes the correctness passes
# to avoid new violations").
PASS_INFO = {
    "name": "reuse",
    "result_attr": "reuse_report",
    "option_flag": "communication",
    "invalidates": ("fine",),
    "description": "violation-free reuse-buffer generation (Fig. 7)",
}


@dataclass
class ReuseReport:
    rewritten: list[str] = field(default_factory=list)
    line_buffer_bytes: int = 0
    window_buffer_bytes: int = 0

    def summary(self) -> str:
        return (f"reuse: {len(self.rewritten)} stencil tasks rewritten, "
                f"lb={self.line_buffer_bytes}B wb={self.window_buffer_bytes}B")


def _stencil_read(task: Task):
    for a in task.reads:
        for dim in a.index:
            live = [v for (v, _s) in dim
                    if task.has_loop(v) and task.loop(v).trip > 1]
            if len(live) > 1:
                return a
    return None


def rewrite_stencil_task(graph: DataflowGraph, task: Task, itemsize: int = 4
                         ) -> tuple[int, int] | None:
    """Apply Fig. 7's rewrite to one windowed task.  Returns (line, window)
    buffer sizes in bytes, or None when the task has no stencil read."""
    read = _stencil_read(task)
    if read is None:
        return None
    write = task.writes[0]
    trips = {l.var: l.trip for l in task.loops}

    # Classify vars.  For conv: spatial = (h,w) [stencil outer vars],
    # kernel = (kh,kw) [stencil inner vars], ci = read channel, co = write channel.
    spatial, kernel, new_index, stream_shape = [], [], [], []
    for dim in read.index:
        live = [(v, s) for (v, s) in dim if trips.get(v, 1) > 1]
        span = 1 + sum((trips[v] - 1) * abs(s) for (v, s) in live)
        stream_shape.append(span)
        if len(live) > 1:
            # outermost var is the sliding position, the rest the window
            live_sorted = sorted(live, key=lambda vs: task.loop_depth(vs[0]))
            pos, win = live_sorted[0], live_sorted[1:]
            spatial.append(pos[0])
            kernel += [v for (v, _s) in win]
            new_index.append(idx(pos))
        else:
            new_index.append(idx(*live) if live else ())
    read_only = read.vars() - write.vars()          # e.g. {ci, kh, kw}
    write_only = write.vars() - read.vars()         # e.g. {co}
    ci_vars = [v for v in read_only if v not in kernel and v not in spatial]
    batch = [v for v in (read.vars() & write.vars())
             if v not in spatial and v not in kernel]

    # --- new loop order (Fig. 7): batch, spatial, [load ci | compute co,ci,k...]
    depth0 = {l.var: i for i, l in enumerate(task.loops)}

    def order_key(l):
        if l.var in batch:
            return (0, depth0[l.var])
        if l.var in spatial:
            return (1, depth0[l.var])
        if l.var in write_only:
            return (2, depth0[l.var])
        if l.var in ci_vars:
            return (3, depth0[l.var])
        return (4, depth0[l.var])

    task.loops.sort(key=order_key)

    # --- ring classification (parallelism-exploration guidance, Fig. 7 text)
    for l in task.loops:
        if l.var in batch:
            l.ring = "outer"            # red: unrolls all regions — unsafe
        elif l.var in spatial or l.var in ci_vars or l.var in write_only:
            l.ring = "fifo"             # orange: tied to FIFO indices
        else:
            l.ring = "reduction"        # green: safe to parallelize

    # --- exact-once read: the load region consumes the full *input* extent
    # (stream_shape keeps the pre-rewrite spans, e.g. padded rows)
    read.index = tuple(new_index)
    read.enclosing = tuple(v for v in (batch + spatial + ci_vars))
    read.stream_shape = tuple(stream_shape)
    # --- write region runs under (batch, spatial, co): once per element
    write.enclosing = tuple(v for v in (batch + spatial + [x for x in write_only]))

    # --- reuse-buffer shapes (lb: kh-1 rows × row length; wb: window)
    k_trips = [trips[v] for v in kernel]
    row = 1
    if len(spatial) >= 1:
        innermost_spatial = spatial[-1]
        row = trips[innermost_spatial]
    ci_sz = 1
    for v in ci_vars:
        ci_sz *= trips[v]
    kh = k_trips[0] if k_trips else 1
    kw = k_trips[1] if len(k_trips) > 1 else 1
    lb = ci_sz * max(kh - 1, 1) * row
    wb = ci_sz * kh * kw
    task.reuse_buffers[f"lb_{read.buffer}"] = (ci_sz, max(kh - 1, 1), row)
    task.reuse_buffers[f"wb_{read.buffer}"] = (ci_sz, kh, kw)
    task.tags.add("reuse-rewritten")
    return lb * itemsize, wb * itemsize


def generate_reuse_buffers(graph: DataflowGraph) -> ReuseReport:
    """Rewrite every task holding a STENCIL_REREAD violation; also rewrite
    stencil reads of *external* inputs (profitable even without a FIFO peer
    — the reuse itself saves bandwidth, 'also applicable when the target
    array is implemented using ping-pong buffers')."""
    report = ReuseReport()
    flagged: set[str] = set()
    for v in fine_violations(graph):
        if v.kind == STENCIL_REREAD:
            flagged.add(v.consumer)
    for t in graph.tasks:
        if t.name in flagged or _stencil_read(t) is not None:
            r = rewrite_stencil_task(graph, t)
            if r is not None:
                report.rewritten.append(t.name)
                report.line_buffer_bytes += r[0]
                report.window_buffer_bytes += r[1]
    return report


def parallel_safety(task: Task, var: str) -> str:
    """Scheduler query: 'unsafe' | 'coordinate' | 'free' (Fig. 7 guidance +
    §V-B legality: no loop-carried deps; FIFO-indexed vars need peer
    coordination)."""
    l = task.loop(var)
    if l.ring == "outer" or "fused-control" in task.tags:
        return "unsafe"
    if l.ring == "fifo":
        return "coordinate"
    # free/reduction rings: legal if no carried dependency; reductions are
    # associative here (MAC trees), matching the paper's treatment.
    return "free"
