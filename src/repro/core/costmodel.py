"""Profiling-based performance model (paper §VI "Stage One", refs [43][48]),
re-parameterized for TPU v5e.

The paper profiles basic operators (adders, MACs) and estimates each loop's
latency from trip counts × parallelism.  We keep exactly that structure —
an op-level initiation-interval (II) table plus trip-count arithmetic — but
the resource vector becomes (compute units ≈ MXU/VPU lane groups, VMEM
bytes, HBM bytes/s per channel) instead of (DSP, BRAM, LUT, FF).

Latencies are reported in *cycles at the nominal TPU clock* so the
benchmark tables can mirror the paper's cycle counts, and in seconds for
the roofline cross-check.

The dataflow-graph latency evaluator implements Fig. 1/Fig. 2 semantics:

* FIFO edge — the consumer starts as soon as its first required element
  arrives: producer start + first-emit skew (+ line-buffer fill for
  stencil consumers).  Delayed FIFO writes (Fig. 2 Issue 2: un-rewritten
  reductions emit at ~8/9 of the iteration space) show up here directly.
* Ping-pong edge — the consumer waits for the producer's whole block.
* Sequential (unresolved coarse violation) — no overlap at all.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .buffers import BufferPlan
from .graph import FIFO, PINGPONG, DataflowGraph, Task
from .patterns import index_dims, reduction_dims

# --------------------------------------------------------------------------
# Hardware parameters (TPU v5e)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class HwParams:
    name: str = "tpu-v5e"
    clock_hz: float = 940e6            # nominal core clock
    peak_flops: float = 197e12         # bf16
    hbm_bw: float = 819e9              # bytes/s
    ici_bw: float = 50e9               # bytes/s per link
    vmem_bytes: int = 128 * 2**20
    hbm_channels: int = 8
    # "compute units": lane-groups the scheduler allocates, the DSP-budget
    # analogue.  One unit retires `unit_flops_per_cycle` flops per cycle.
    max_units: int = 2048
    unit_flops_per_cycle: float = 2.0  # 1 MAC / unit / cycle

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bw / self.clock_hz

    @property
    def channel_bytes_per_cycle(self) -> float:
        return self.hbm_bytes_per_cycle / self.hbm_channels


V5E = HwParams()

# Op-level initiation intervals (cycles per innermost iteration at degree 1)
# — the "profiled basic operation" table of §VI.
OP_II: dict[str, float] = {
    "conv": 1.0, "matmul": 1.0, "ewise": 1.0, "pad": 1.0, "copy": 1.0,
    "pool": 1.0, "reduce": 1.0, "norm": 2.0, "softmax": 4.0, "exp": 4.0,
    "generic": 1.0,
}

# Extra pipeline depth (fill) per op — constant, small.
OP_DEPTH: dict[str, float] = {"softmax": 24.0, "norm": 12.0}


# --------------------------------------------------------------------------
# Per-task cost
# --------------------------------------------------------------------------


@dataclass
class TaskCost:
    task: str
    compute_cycles: float
    memory_cycles: float
    latency: float          # max(compute, memory) + depth
    first_emit: float       # cycles until first FIFO write is available
    degree: int             # total parallel degree (product over loops)
    units: int              # compute units consumed
    vmem_bytes: int         # reuse buffers + accumulators

    @property
    def bound(self) -> str:
        return "compute" if self.compute_cycles >= self.memory_cycles else "memory"


def task_degree(task: Task) -> int:
    d = 1
    for l in task.loops:
        d *= max(1, l.parallel)
    return d


def _offchip_read_bytes(graph: DataflowGraph, task: Task) -> dict[int, float]:
    """bytes per HBM channel this task pulls from off-chip (inputs, weights,
    ping-pong intermediates)."""
    per_ch: dict[int, float] = {}
    for a in task.reads:
        buf = graph.buffers[a.buffer]
        off = buf.kind in ("input", "weight") or buf.impl == PINGPONG
        if not off:
            continue
        from .patterns import access_sig
        sig = access_sig(task, a)
        # after reuse rewriting reads are exact-once; otherwise each re-read
        # really hits memory
        elems = min(sig.total, max(sig.distinct, 1)) if "reuse-rewritten" in task.tags \
            or a.enclosing is not None else sig.total
        nbytes = elems * np.dtype(buf.dtype).itemsize
        burst_eff = 1.0
        if buf.burst_len:
            burst_eff = buf.burst_len / (buf.burst_len + 32)
        ch = buf.hbm_channel if buf.hbm_channel >= 0 else 0
        per_ch[ch] = per_ch.get(ch, 0.0) + nbytes / burst_eff
    for a in task.writes:
        buf = graph.buffers[a.buffer]
        if buf.kind == "output" or buf.impl == PINGPONG:
            ch = buf.hbm_channel if buf.hbm_channel >= 0 else 0
            per_ch[ch] = per_ch.get(ch, 0.0) + buf.nbytes
    return per_ch


def task_cost(graph: DataflowGraph, task: Task, hw: HwParams = V5E) -> TaskCost:
    ii = OP_II.get(task.op, 1.0)
    degree = task_degree(task)
    iters = task.total_iters
    compute = iters * ii / degree + OP_DEPTH.get(task.op, 0.0)

    per_ch = _offchip_read_bytes(graph, task)
    memory = max(per_ch.values()) / hw.channel_bytes_per_cycle if per_ch else 0.0

    latency = max(compute, memory) + sum(l.trip for l in task.loops[:2]) * 0.0

    # first-emit skew: how far into the iteration space the first FIFO write
    # lands.  Early (rewritten) writes emit after one reduction window;
    # un-rewritten reductions emit at the end of the innermost index sweep —
    # Fig. 2 Issue 2's "8/9 of iterations" penalty falls out of this.
    first = latency  # default: block semantics
    if task.writes:
        w = task.writes[0]
        red = reduction_dims(task, w)
        red_iters = int(np.prod([task.loop(v).trip for v in red])) if red else 1
        if w.enclosing is not None or not red:
            # rewritten (or naturally streaming): first element after one
            # reduction window at the current degree
            first = red_iters * ii / degree + OP_DEPTH.get(task.op, 0.0)
        else:
            # write still inside reduction: last-minute emission — the
            # consumer effectively waits for almost the whole task
            idx_iters = int(np.prod([task.loop(v).trip for v in index_dims(task, w)]))
            first = latency * (1.0 - 1.0 / max(idx_iters, 1))
    vmem = sum(int(np.prod(s)) * 4 for s in task.reuse_buffers.values())
    return TaskCost(task.name, compute, memory, latency, min(first, latency),
                    degree, degree, vmem)


# --------------------------------------------------------------------------
# Graph latency (dataflow schedule evaluation, Fig. 1/2 semantics)
# --------------------------------------------------------------------------


@dataclass
class GraphCost:
    total_cycles: float
    start: dict[str, float]
    finish: dict[str, float]
    costs: dict[str, TaskCost]
    bottleneck: str
    units: int
    vmem_bytes: int
    seconds: float = 0.0

    def summary(self) -> str:
        return (f"latency={self.total_cycles:,.0f} cycles ({self.seconds*1e3:.3f} ms), "
                f"bottleneck={self.bottleneck}, units={self.units}, "
                f"vmem={self.vmem_bytes/2**20:.2f} MiB")


def _num_blocks(task: Task) -> int:
    """Ping-pong block count: iterations of the outermost varying loop."""
    for l in task.loops:
        if l.trip > 1:
            return l.trip
    return 1


def _stencil_fill(task: Task, cost: TaskCost) -> float:
    """Line-buffer fill delay before a stencil consumer can start: kh-1 rows."""
    for name, shape in task.reuse_buffers.items():
        if name.startswith("lb_") and len(shape) == 3:
            ci, khm1, row = shape
            return ci * khm1 * row  # one cycle per element at arrival rate
    return 0.0


def graph_latency(graph: DataflowGraph, hw: HwParams = V5E,
                  plan: BufferPlan | None = None,
                  sequential: bool = False) -> GraphCost:
    costs = {t.name: task_cost(graph, t, hw) for t in graph.tasks}
    order = graph.toposort()
    start: dict[str, float] = {}
    finish: dict[str, float] = {}
    impl = plan.impl if plan is not None else {
        b.name: b.impl for b in graph.buffers.values()}

    for t in order:
        c = costs[t.name]
        ready = 0.0
        for a in t.reads:
            buf = graph.buffers[a.buffer]
            prods = graph.producers(a.buffer)
            if not prods:
                continue
            p = prods[0]
            pc, pf, ps = costs[p.name], finish[p.name], start[p.name]
            if sequential:
                ready = max(ready, pf)
            elif impl.get(a.buffer) == FIFO:
                skew = ps + pc.first_emit + _stencil_fill(t, c)
                ready = max(ready, skew)
            else:
                # ping-pong: blocks alternate at the producer's outermost
                # varying-loop granularity (Fig. 1(b)/Fig. 2(c)); the
                # consumer starts once the first block lands.
                ready = max(ready, ps + pc.latency / _num_blocks(p))
        start[t.name] = ready
        # steady state: a streaming consumer cannot finish before its
        # producers finish feeding it (rate matching), plus the drain of
        # its last block/element.
        drain = 0.0
        for a in t.reads:
            for p in graph.producers(a.buffer):
                if impl.get(a.buffer) == FIFO:
                    tail = c.latency / max(t.total_iters, 1)
                else:
                    tail = c.latency / _num_blocks(t)
                drain = max(drain, finish[p.name] + tail)
        finish[t.name] = max(ready + c.latency, drain)

    total = max(finish.values()) if finish else 0.0
    bottleneck = max(costs.values(), key=lambda c: c.latency).task if costs else ""
    units = sum(c.units for c in costs.values())
    vmem = sum(c.vmem_bytes for c in costs.values())
    if plan is not None:
        vmem += plan.vmem_bytes
    return GraphCost(total, start, finish, costs, bottleneck, units, vmem,
                     seconds=total / hw.clock_hz)


def sequential_latency(graph: DataflowGraph, hw: HwParams = V5E) -> GraphCost:
    """The Vitis-HLS-baseline analogue: every task at degree 1, no overlap."""
    g = graph.copy()
    for t in g.tasks:
        for l in t.loops:
            l.parallel = 1
    return graph_latency(g, hw, sequential=True)
