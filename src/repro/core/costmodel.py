"""Profiling-based performance model (paper §VI "Stage One", refs [43][48]),
re-parameterized for TPU v5e.

The paper profiles basic operators (adders, MACs) and estimates each loop's
latency from trip counts × parallelism.  We keep exactly that structure —
an op-level initiation-interval (II) table plus trip-count arithmetic — but
the resource vector becomes (compute units ≈ MXU/VPU lane groups, VMEM
bytes, HBM bytes/s per channel) instead of (DSP, BRAM, LUT, FF).

Latencies are reported in *cycles at the nominal TPU clock* so the
benchmark tables can mirror the paper's cycle counts, and in seconds for
the roofline cross-check.

The dataflow-graph latency evaluator implements Fig. 1/Fig. 2 semantics:

* FIFO edge — the consumer starts as soon as its first required element
  arrives: producer start + first-emit skew (+ line-buffer fill for
  stencil consumers).  Delayed FIFO writes (Fig. 2 Issue 2: un-rewritten
  reductions emit at ~8/9 of the iteration space) show up here directly.
* Ping-pong edge — the consumer waits for the producer's whole block.
* Sequential (unresolved coarse violation) — no overlap at all.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .buffers import BufferPlan
from .graph import FIFO, PINGPONG, DataflowGraph, Task
from .patterns import index_dims, reduction_dims

# --------------------------------------------------------------------------
# Hardware parameters (TPU v5e)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class HwParams:
    name: str = "tpu-v5e"
    clock_hz: float = 940e6            # nominal core clock
    peak_flops: float = 197e12         # bf16
    hbm_bw: float = 819e9              # bytes/s
    ici_bw: float = 50e9               # bytes/s per link
    vmem_bytes: int = 128 * 2**20
    hbm_channels: int = 8
    # "compute units": lane-groups the scheduler allocates, the DSP-budget
    # analogue.  One unit retires `unit_flops_per_cycle` flops per cycle.
    max_units: int = 2048
    unit_flops_per_cycle: float = 2.0  # 1 MAC / unit / cycle

    @property
    def hbm_bytes_per_cycle(self) -> float:
        return self.hbm_bw / self.clock_hz

    @property
    def channel_bytes_per_cycle(self) -> float:
        return self.hbm_bytes_per_cycle / self.hbm_channels


V5E = HwParams()

# Op-level initiation intervals (cycles per innermost iteration at degree 1)
# — the "profiled basic operation" table of §VI.
OP_II: dict[str, float] = {
    "conv": 1.0, "matmul": 1.0, "ewise": 1.0, "pad": 1.0, "copy": 1.0,
    "pool": 1.0, "reduce": 1.0, "norm": 2.0, "softmax": 4.0, "exp": 4.0,
    "generic": 1.0,
}

# Extra pipeline depth (fill) per op — constant, small.
OP_DEPTH: dict[str, float] = {"softmax": 24.0, "norm": 12.0}


# --------------------------------------------------------------------------
# Per-task cost
# --------------------------------------------------------------------------


@dataclass
class TaskCost:
    task: str
    compute_cycles: float
    memory_cycles: float
    latency: float          # max(compute, memory) + depth
    first_emit: float       # cycles until first FIFO write is available
    degree: int             # total parallel degree (product over loops)
    units: int              # compute units consumed
    vmem_bytes: int         # reuse buffers + accumulators

    @property
    def bound(self) -> str:
        return "compute" if self.compute_cycles >= self.memory_cycles else "memory"


def task_degree(task: Task) -> int:
    d = 1
    for l in task.loops:
        d *= max(1, l.parallel)
    return d


def _offchip_read_bytes(graph: DataflowGraph, task: Task) -> dict[int, float]:
    """bytes per HBM channel this task pulls from off-chip (inputs, weights,
    ping-pong intermediates)."""
    per_ch: dict[int, float] = {}
    for a in task.reads:
        buf = graph.buffers[a.buffer]
        off = buf.kind in ("input", "weight") or buf.impl == PINGPONG
        if not off:
            continue
        from .patterns import access_sig
        sig = access_sig(task, a)
        # after reuse rewriting reads are exact-once; otherwise each re-read
        # really hits memory
        elems = min(sig.total, max(sig.distinct, 1)) if "reuse-rewritten" in task.tags \
            or a.enclosing is not None else sig.total
        nbytes = elems * np.dtype(buf.dtype).itemsize
        burst_eff = 1.0
        if buf.burst_len:
            burst_eff = buf.burst_len / (buf.burst_len + 32)
        ch = buf.hbm_channel if buf.hbm_channel >= 0 else 0
        per_ch[ch] = per_ch.get(ch, 0.0) + nbytes / burst_eff
    for a in task.writes:
        buf = graph.buffers[a.buffer]
        if buf.kind == "output" or buf.impl == PINGPONG:
            ch = buf.hbm_channel if buf.hbm_channel >= 0 else 0
            per_ch[ch] = per_ch.get(ch, 0.0) + buf.nbytes
    return per_ch


def task_cost(graph: DataflowGraph, task: Task, hw: HwParams = V5E) -> TaskCost:
    ii = OP_II.get(task.op, 1.0)
    degree = task_degree(task)
    iters = task.total_iters
    compute = iters * ii / degree + OP_DEPTH.get(task.op, 0.0)

    per_ch = _offchip_read_bytes(graph, task)
    memory = max(per_ch.values()) / hw.channel_bytes_per_cycle if per_ch else 0.0

    latency = max(compute, memory) + sum(l.trip for l in task.loops[:2]) * 0.0

    # first-emit skew: how far into the iteration space the first FIFO write
    # lands.  Early (rewritten) writes emit after one reduction window;
    # un-rewritten reductions emit at the end of the innermost index sweep —
    # Fig. 2 Issue 2's "8/9 of iterations" penalty falls out of this.
    first = latency  # default: block semantics
    if task.writes:
        w = task.writes[0]
        red = reduction_dims(task, w)
        red_iters = int(np.prod([task.loop(v).trip for v in red])) if red else 1
        if w.enclosing is not None or not red:
            # rewritten (or naturally streaming): first element after one
            # reduction window at the current degree
            first = red_iters * ii / degree + OP_DEPTH.get(task.op, 0.0)
        else:
            # write still inside reduction: last-minute emission — the
            # consumer effectively waits for almost the whole task
            idx_iters = int(np.prod([task.loop(v).trip for v in index_dims(task, w)]))
            first = latency * (1.0 - 1.0 / max(idx_iters, 1))
    vmem = sum(int(np.prod(s)) * 4 for s in task.reuse_buffers.values())
    return TaskCost(task.name, compute, memory, latency, min(first, latency),
                    degree, degree, vmem)


# --------------------------------------------------------------------------
# Graph latency (dataflow schedule evaluation, Fig. 1/2 semantics)
# --------------------------------------------------------------------------


@dataclass
class GraphCost:
    total_cycles: float
    start: dict[str, float]
    finish: dict[str, float]
    costs: dict[str, TaskCost]
    bottleneck: str
    units: int
    vmem_bytes: int
    seconds: float = 0.0

    def summary(self) -> str:
        return (f"latency={self.total_cycles:,.0f} cycles ({self.seconds*1e3:.3f} ms), "
                f"bottleneck={self.bottleneck}, units={self.units}, "
                f"vmem={self.vmem_bytes/2**20:.2f} MiB")


def _num_blocks(task: Task) -> int:
    """Ping-pong block count: iterations of the outermost varying loop."""
    for l in task.loops:
        if l.trip > 1:
            return l.trip
    return 1


def _stencil_fill(task: Task, cost: TaskCost) -> float:
    """Line-buffer fill delay before a stencil consumer can start: kh-1 rows."""
    for name, shape in task.reuse_buffers.items():
        if name.startswith("lb_") and len(shape) == 3:
            ci, khm1, row = shape
            return ci * khm1 * row  # one cycle per element at arrival rate
    return 0.0


def graph_latency(graph: DataflowGraph, hw: HwParams = V5E,
                  plan: BufferPlan | None = None,
                  sequential: bool = False) -> GraphCost:
    costs = {t.name: task_cost(graph, t, hw) for t in graph.tasks}
    order = graph.toposort()
    start: dict[str, float] = {}
    finish: dict[str, float] = {}
    impl = plan.impl if plan is not None else {
        b.name: b.impl for b in graph.buffers.values()}

    for t in order:
        c = costs[t.name]
        ready = 0.0
        for a in t.reads:
            buf = graph.buffers[a.buffer]
            prods = graph.producers(a.buffer)
            if not prods:
                continue
            p = prods[0]
            pc, pf, ps = costs[p.name], finish[p.name], start[p.name]
            if sequential:
                ready = max(ready, pf)
            elif impl.get(a.buffer) == FIFO:
                skew = ps + pc.first_emit + _stencil_fill(t, c)
                ready = max(ready, skew)
            else:
                # ping-pong: blocks alternate at the producer's outermost
                # varying-loop granularity (Fig. 1(b)/Fig. 2(c)); the
                # consumer starts once the first block lands.
                ready = max(ready, ps + pc.latency / _num_blocks(p))
        start[t.name] = ready
        # steady state: a streaming consumer cannot finish before its
        # producers finish feeding it (rate matching), plus the drain of
        # its last block/element.
        drain = 0.0
        for a in t.reads:
            for p in graph.producers(a.buffer):
                if impl.get(a.buffer) == FIFO:
                    tail = c.latency / max(t.total_iters, 1)
                else:
                    tail = c.latency / _num_blocks(t)
                drain = max(drain, finish[p.name] + tail)
        finish[t.name] = max(ready + c.latency, drain)

    total = max(finish.values()) if finish else 0.0
    bottleneck = max(costs.values(), key=lambda c: c.latency).task if costs else ""
    units = sum(c.units for c in costs.values())
    vmem = sum(c.vmem_bytes for c in costs.values())
    if plan is not None:
        vmem += plan.vmem_bytes
    return GraphCost(total, start, finish, costs, bottleneck, units, vmem,
                     seconds=total / hw.clock_hz)


def sequential_latency(graph: DataflowGraph, hw: HwParams = V5E) -> GraphCost:
    """The Vitis-HLS-baseline analogue: every task at degree 1, no overlap."""
    g = graph.copy()
    for t in g.tasks:
        for l in t.loops:
            l.parallel = 1
    return graph_latency(g, hw, sequential=True)


# --------------------------------------------------------------------------
# Routing predictor (ISSUE 6): routed-kernel vs generic-XLA latency per
# pattern-matched chain.  Same II/trip-count arithmetic as above, plus a
# small per-backend parameter vector calibrated from the measured routing
# bench (results/bench/routing_groups.json).
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class RoutingCostParams:
    """Calibration constants the routing gate combines with structural
    :func:`task_cost` cycles.

    ``efficiency[pattern]`` is the kernel's measured throughput relative
    to the generic path for same-shaped work (geomean of bench speedups —
    1.0 means parity).  ``generic_spill``/``stream_overlap`` model what
    the kernel *structurally* changes: on TPU the generic path bounces
    chain interiors through HBM (spill=1) while the kernel pipelines
    stages (overlap=1); on CPU hosts both sides are one XLA:CPU fusion,
    so neither effect materializes and only the calibrated efficiency and
    the per-kernel dispatch overhead separate them.
    """

    backend: str = "cpu"
    efficiency: tuple[tuple[str, float], ...] = ()
    default_efficiency: float = 1.0
    overhead_cycles: float = 2600.0    # per-kernel dispatch/setup
    generic_spill: float = 0.0         # fraction of interior HBM round-trip
    stream_overlap: float = 0.0        # 0 = stages run back-to-back
    slack: float = 0.02                # noise band: route down to this loss

    def eff(self, pattern: str) -> float:
        return dict(self.efficiency).get(pattern, self.default_efficiency)

    def digest(self) -> str:
        import hashlib
        canon = (self.backend, tuple(sorted(self.efficiency)),
                 self.default_efficiency, self.overhead_cycles,
                 self.generic_spill, self.stream_overlap, self.slack)
        return hashlib.sha256(repr(canon).encode()).hexdigest()[:16]


# Defaults calibrated from the recorded CPU routing bench (nightly
# routing_groups.json; see calibrate_routing_params): conv chains sit at
# ~0.99x parity, mmchains at parity, and the softmaxmm tail measures 0.97x
# — below the slack band, so the gate routes it to generic XLA on CPU.
_CPU_PARAMS = RoutingCostParams(
    backend="cpu",
    efficiency=(("streamfuse.conv", 0.99), ("streamfuse.mmchain", 1.0),
                ("streamfuse.softmaxmm", 0.97),
                # Backward matmul + gradient-epilogue chains measure just
                # under parity on CPU (the epilogue replays registry impls);
                # the gate keeps them generic here — CI forces them on with
                # CODO_FORCE_PALLAS to exercise the kernel path.
                ("streamfuse.mmgrad", 0.98),
                # flashattn's CPU reference is the same fused-jnp chain, so
                # parity; chunked-scan references re-execute the recurrence
                # sequentially and measure slightly under parity — below the
                # slack band, so scans stay generic on CPU unless forced.
                ("flashattn.mha", 1.0), ("rglru.scan", 0.9),
                ("ssd.scan", 0.9)))
DEFAULT_ROUTING_PARAMS: dict[str, RoutingCostParams] = {
    "cpu": _CPU_PARAMS,
    # GPU hosts run the same fused-jnp reference path as CPU.
    "gpu": RoutingCostParams(backend="gpu",
                             efficiency=_CPU_PARAMS.efficiency),
    # On TPU the kernel is the real Pallas implementation: stages pipeline
    # through VMEM (overlap=1) and the generic path pays the interior HBM
    # round-trips (spill=1) — the paper's §VII-C win.  The attention and
    # chunked-scan kernels additionally beat the generic path on *work*:
    # flashattn never materializes the S×S score matrix and the chunked
    # scans trade O(S) sequential steps for O(S/chunk) (§VII-C, Table VI).
    "tpu": RoutingCostParams(backend="tpu", generic_spill=1.0,
                             stream_overlap=1.0, slack=0.0,
                             efficiency=(("flashattn.mha", 1.3),
                                         ("rglru.scan", 1.5),
                                         ("ssd.scan", 1.5))),
}


def routing_backend() -> str:
    """The backend the routing gate prices against: ``CODO_BACKEND`` when
    set, else jax's default backend, else ``"cpu"`` (jax-less hosts)."""
    import os
    env = os.environ.get("CODO_BACKEND", "").strip().lower()
    if env:
        return env
    try:
        import jax
        return str(jax.default_backend())
    except Exception:                        # pragma: no cover — stub builds
        return "cpu"


def calibrate_routing_params(doc: dict,
                             base: RoutingCostParams | None = None,
                             ) -> RoutingCostParams:
    """Fit per-pattern efficiency from a ``routing_groups.json`` document:
    the geomean of each pattern's measured ``speedup`` (xla_ms/pallas_ms),
    clamped to a sane band.  Everything else comes from ``base`` (defaults
    for the document's backend)."""
    from dataclasses import replace
    backend = str(doc.get("backend", "cpu"))
    if base is None:
        base = DEFAULT_ROUTING_PARAMS.get(
            backend, replace(_CPU_PARAMS, backend=backend))
    logs: dict[str, list[float]] = {}
    for r in doc.get("records", ()):
        s = float(r.get("speedup", 0.0) or 0.0)
        if s > 0:
            logs.setdefault(str(r.get("kernel", "?")), []).append(np.log(s))
    eff = dict(base.efficiency)
    for pat, ls in logs.items():
        eff[pat] = float(np.clip(np.exp(np.mean(ls)), 0.5, 2.0))
    return replace(base, backend=backend,
                   efficiency=tuple(sorted(eff.items())))


_CALIBRATION_CACHE: dict[str, RoutingCostParams] = {}


def routing_params(backend: str | None = None) -> RoutingCostParams:
    """Active gate parameters: defaults for ``backend`` (detected when
    ``None``), recalibrated from the ``CODO_ROUTING_CALIBRATION`` bench
    JSON when that points at a readable document for the same backend."""
    import json
    import os
    from dataclasses import replace
    backend = backend or routing_backend()
    base = DEFAULT_ROUTING_PARAMS.get(
        backend, replace(_CPU_PARAMS, backend=backend))
    path = os.environ.get("CODO_ROUTING_CALIBRATION", "").strip()
    if not path:
        return base
    key = f"{path}:{backend}"
    hit = _CALIBRATION_CACHE.get(key)
    if hit is not None:
        return hit
    try:
        doc = json.loads(open(path).read())
    except (OSError, ValueError):
        return base
    if str(doc.get("backend", backend)) != backend:
        params = base
    else:
        params = calibrate_routing_params(doc, base)
    _CALIBRATION_CACHE[key] = params
    return params


@dataclass(frozen=True)
class ChainEstimate:
    """Predicted latency of one pattern-matched chain both ways."""

    pattern: str
    tasks: tuple[str, ...]
    routed_cycles: float
    generic_cycles: float
    win: bool

    @property
    def predicted_speedup(self) -> float:
        return self.generic_cycles / max(self.routed_cycles, 1e-9)


def estimate_chain(graph: DataflowGraph, tasks: list[Task],
                   pattern: str, hw: HwParams = V5E,
                   params: RoutingCostParams | None = None) -> ChainEstimate:
    """Price a matched chain both ways with :func:`task_cost` cycles.

    generic = sum of stage latencies + the interior HBM round-trips the
    un-routed path materializes (backend-scaled); routed = the pipelined
    stage latencies at the kernel's calibrated efficiency plus a fixed
    dispatch overhead.  The gate routes iff routed is predicted no slower
    than generic beyond the noise band (``params.slack``).
    """
    if params is None:
        params = routing_params()
    costs = [task_cost(graph, t, hw) for t in tasks]
    total = sum(c.latency for c in costs)
    peak = max(c.latency for c in costs)
    interior_bytes = 0
    for t in tasks[:-1]:
        outs = {a.buffer for a in t.writes}
        for b in outs:
            interior_bytes += graph.buffers[b].nbytes
    spill = (2.0 * interior_bytes / hw.hbm_bytes_per_cycle
             * params.generic_spill)               # write + re-read
    generic = total + spill
    pipelined = total - params.stream_overlap * (total - peak)
    routed = pipelined / params.eff(pattern) + params.overhead_cycles
    win = routed <= generic * (1.0 + params.slack)
    return ChainEstimate(pattern, tuple(t.name for t in tasks),
                         routed, generic, win)


# --------------------------------------------------------------------------
# Sharding: compute-per-shard vs link bytes (ISSUE 9)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardingEstimate:
    """Per-device cost of one sharding candidate: the compute each device
    actually runs (task latency divided by its shard factor) plus the
    cycles its collective schedule spends on the inter-chip links."""

    strategy: str
    compute_cycles: float
    collective_cycles: float
    collective_bytes: int

    @property
    def total_cycles(self) -> float:
        return self.compute_cycles + self.collective_cycles


# Bytes each device moves per payload byte, per collective algorithm
# (the classic ring-algorithm link factors).
_LINK_FACTOR = {
    ("psum", "direct"): 2.0,          # all-reduce: reduce + broadcast
    ("psum", "rs_ag"): 2.0,           # 2(n-1)/n ~ 2, bandwidth-optimal
    ("all_gather", "direct"): 1.0,    # (n-1)/n ~ 1
    ("all_gather", "ring"): 1.0,
    ("reduce_scatter", "direct"): 1.0,
    ("ppermute", "direct"): 1.0,
}


def estimate_sharding(graph: DataflowGraph, plan, hw: HwParams = V5E):
    """Price a :class:`~repro.distributed.plan.ShardingPlan`.

    Compute: every task's single-device latency shrinks by the product of
    mesh-axis sizes sharding its output (a psum emitted after the task
    means its contraction was sharded too, so that axis also divides the
    work).  Collectives: payload bytes x the algorithm's link factor over
    the ICI bandwidth, expressed in core cycles so the two sides add.
    """
    psum_after: dict[str, set] = {}
    for s in plan.steps:
        if s.kind == "psum" and s.where == "after":
            psum_after.setdefault(s.task, set()).add(s.axis)

    compute = 0.0
    for task in graph.tasks:
        cost = task_cost(graph, task, hw)
        axes: set = set(psum_after.get(task.name, set()))
        for a in task.writes:
            spec = plan.spec_of(a.buffer, len(graph.buffers[a.buffer].shape))
            axes.update(d for d in spec.dims if d is not None)
        factor = 1
        for ax in axes:
            factor *= plan.mesh.axis_size(ax)
        compute += cost.latency / max(factor, 1)

    link_bps = max(hw.ici_bw, 1.0)
    bytes_per_cycle = link_bps / hw.clock_hz
    coll = 0.0
    total_bytes = 0
    for s in plan.steps:
        factor = _LINK_FACTOR.get((s.kind, s.via), 1.0)
        n = plan.mesh.axis_size(s.axis)
        if n <= 1:
            continue
        coll += s.bytes * factor / bytes_per_cycle
        total_bytes += s.bytes
    return ShardingEstimate(strategy=plan.strategy, compute_cycles=compute,
                            collective_cycles=coll,
                            collective_bytes=total_bytes)
