"""On-chip communication-buffer determination (paper §V-A).

FIFO-first strategy: every internal edge whose producer/consumer streams
are compatible after the correctness passes becomes a FIFO; everything
else falls back to a ping-pong (double) buffer.

TPU mapping — a FIFO edge means the two tasks are *fusable into one
streaming kernel*: the intermediate lives only as a VMEM tile (its "FIFO
depth").  A ping-pong edge means the intermediate is materialized in HBM
and the consumer's Pallas grid pipeline double-buffers the HBM→VMEM DMA —
the exact latency/flexibility trade of Fig. 1.  Resource accounting
follows: FIFO costs `depth × itemsize` of VMEM, ping-pong costs
`2 × block-bytes` (of HBM plus a VMEM staging tile).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .graph import FIFO, PINGPONG, DataflowGraph
from .patterns import fine_violations_edge

# Pipeline declaration consumed by passes.default_passes().  Always runs:
# even the Opt1/Opt2 ablations need every edge classified FIFO/ping-pong
# before the cost model can evaluate the design.
PASS_INFO = {
    "name": "buffers",
    "result_attr": "buffer_plan",
    "option_flag": None,
    "invalidates": (),
    "description": "communication-buffer determination (FIFO-first, §V-A)",
}


@dataclass
class BufferPlan:
    impl: dict[str, str] = field(default_factory=dict)          # buffer -> FIFO/PINGPONG
    fifo_depth: dict[str, int] = field(default_factory=dict)    # elements
    reasons: dict[str, str] = field(default_factory=dict)
    vmem_bytes: int = 0
    hbm_bytes: int = 0

    def fifo_fraction(self) -> float:
        """Table VIII's metric: share of internal buffers implemented as
        FIFOs."""
        if not self.impl:
            return 1.0
        n = sum(1 for v in self.impl.values() if v == FIFO)
        return n / len(self.impl)

    def summary(self) -> str:
        return (f"buffers: {sum(1 for v in self.impl.values() if v == FIFO)} FIFO / "
                f"{sum(1 for v in self.impl.values() if v == PINGPONG)} ping-pong "
                f"({self.fifo_fraction():.0%} FIFO), vmem={self.vmem_bytes}B "
                f"hbm={self.hbm_bytes}B")

    # ---- JSON serialization (docs/artifact_format.md `buffer_plan`) ------
    def to_dict(self) -> dict:
        return {"impl": dict(self.impl), "fifo_depth": dict(self.fifo_depth),
                "reasons": dict(self.reasons), "vmem_bytes": self.vmem_bytes,
                "hbm_bytes": self.hbm_bytes}

    @classmethod
    def from_dict(cls, doc: dict) -> "BufferPlan":
        return cls(impl=dict(doc.get("impl", {})),
                   fifo_depth={k: int(v)
                               for k, v in doc.get("fifo_depth", {}).items()},
                   reasons=dict(doc.get("reasons", {})),
                   vmem_bytes=int(doc.get("vmem_bytes", 0)),
                   hbm_bytes=int(doc.get("hbm_bytes", 0)))


def _fifo_depth(graph: DataflowGraph, buffer: str) -> int:
    """In-flight elements between producer emit and consumer consume.

    For a plain streaming edge a small constant suffices; when the consumer
    keeps a line buffer the skew is (kh-1) rows + a window, which is the
    reuse buffer's own storage — the FIFO proper still only needs the
    constant slack.  We charge the reuse storage to the task (reuse.py),
    and the FIFO with a depth-2 double slot, matching HLS's default
    ``fifo_depth=2`` plus retiming slack.
    """
    del graph, buffer
    return 4


def determine_buffers(graph: DataflowGraph) -> BufferPlan:
    plan = BufferPlan()
    for buf in graph.buffers.values():
        if buf.kind in ("input", "weight"):
            continue
        prods = graph.producers(buf.name)
        cons = graph.consumers(buf.name)
        if not prods or not cons:
            # graph boundary (model output): stays an off-chip stream
            if buf.kind == "intermediate":
                plan.impl[buf.name] = FIFO
                plan.fifo_depth[buf.name] = _fifo_depth(graph, buf.name)
                plan.reasons[buf.name] = "boundary stream"
            continue
        if len(prods) > 1 or len(cons) > 1:
            # coarse violation survived (pass disabled in ablation):
            # dataflow between these tasks is invalid -> block semantics.
            plan.impl[buf.name] = PINGPONG
            plan.reasons[buf.name] = "unresolved coarse violation"
            plan.hbm_bytes += 2 * buf.nbytes
            continue
        vs = fine_violations_edge(graph, prods[0], buf.name, cons[0])
        if vs:
            plan.impl[buf.name] = PINGPONG
            plan.reasons[buf.name] = f"fine violations: {[v.kind for v in vs]}"
            plan.hbm_bytes += 2 * buf.nbytes
        else:
            depth = _fifo_depth(graph, buf.name)
            plan.impl[buf.name] = FIFO
            plan.fifo_depth[buf.name] = depth
            plan.reasons[buf.name] = "fifo-compatible"
            plan.vmem_bytes += depth * np.dtype(buf.dtype).itemsize
        buf.impl = plan.impl[buf.name]
        buf.fifo_depth = plan.fifo_depth.get(buf.name, 0)
    # reuse buffers (line/window) are VMEM residents too
    for t in graph.tasks:
        for shape in t.reuse_buffers.values():
            plan.vmem_bytes += int(np.prod(shape)) * 4
    return plan


def downgrade_to_pingpong(graph: DataflowGraph, plan: BufferPlan, buffer: str,
                          reason: str) -> None:
    """Inter-task conflict resolution (§VI): keep the upstream FIFO chain,
    demote this edge to ping-pong."""
    if plan.impl.get(buffer) == FIFO:
        plan.vmem_bytes -= plan.fifo_depth.get(buffer, 0) * np.dtype(
            graph.buffers[buffer].dtype).itemsize
        plan.fifo_depth.pop(buffer, None)
    plan.impl[buffer] = PINGPONG
    plan.reasons[buffer] = reason
    plan.hbm_bytes += 2 * graph.buffers[buffer].nbytes
    graph.buffers[buffer].impl = PINGPONG
    graph.buffers[buffer].fifo_depth = 0
