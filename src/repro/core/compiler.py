"""codo_opt — the end-to-end compilation pipeline (paper Fig. 3).

Pass order (deeply co-optimizing, matching §III):

  1. coarse-grained violation elimination        (coarse.py)
  2. fine-grained violation elimination          (fine.py)
  3. reuse-buffer generation (+ re-run 1&2)      (reuse.py)
  4. communication-buffer determination          (buffers.py)
  5. off-chip transfer management                (offchip.py)
  6. automated dataflow scheduling + inter-task  (schedule.py)

Each pass can be disabled for the Opt1..Opt5 ablation of Table VII.  The
result is a :class:`CompiledDataflow`: the transformed graph, the buffer &
transfer plans, the schedule report, and latency estimates for the
baseline (sequential), the ping-pong-only design and the final design —
the numbers the benchmark tables report.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .buffers import BufferPlan, determine_buffers
from .coarse import CoarseReport, eliminate_coarse
from .costmodel import V5E, GraphCost, HwParams, graph_latency, sequential_latency
from .fine import FineReport, eliminate_fine
from .graph import DataflowGraph
from .offchip import TransferPlan, plan_offchip
from .patterns import coarse_violations, fine_violations
from .reuse import ReuseReport, generate_reuse_buffers
from .schedule import ScheduleReport, autoschedule


@dataclass
class CodoOptions:
    """User-facing knobs of ``codo-opt`` (§III: "users can optionally adjust
    input parameters like maximum parallelism and tiling factors")."""

    coarse: bool = True
    fine: bool = True
    communication: bool = True      # reuse buffers + buffer determination + offchip
    scheduling: bool = True
    enable_up: bool = True
    enable_dp: bool = True
    budget_units: int | None = None
    max_degree: int = 4096
    balance_n: float = 2.0
    hbm_channels: int = 8
    hw: HwParams = V5E

    # Table VII's ablation configurations.
    @staticmethod
    def opt1() -> "CodoOptions":
        return CodoOptions(coarse=False, fine=True, communication=False, scheduling=False)

    @staticmethod
    def opt2() -> "CodoOptions":
        return CodoOptions(coarse=True, fine=False, communication=False, scheduling=False)

    @staticmethod
    def opt3() -> "CodoOptions":
        return CodoOptions(coarse=True, fine=False, communication=True, scheduling=False)

    @staticmethod
    def opt4() -> "CodoOptions":
        return CodoOptions(coarse=True, fine=True, communication=True, scheduling=False)

    @staticmethod
    def opt5() -> "CodoOptions":
        return CodoOptions()


@dataclass
class CompiledDataflow:
    graph: DataflowGraph
    options: CodoOptions
    coarse_report: CoarseReport | None = None
    fine_report: FineReport | None = None
    reuse_report: ReuseReport | None = None
    buffer_plan: BufferPlan | None = None
    transfer_plan: TransferPlan | None = None
    schedule_report: ScheduleReport | None = None
    baseline: GraphCost | None = None          # sequential, degree 1
    final: GraphCost | None = None
    compile_seconds: float = 0.0

    @property
    def speedup(self) -> float:
        if not self.baseline or not self.final or self.final.total_cycles == 0:
            return 1.0
        return self.baseline.total_cycles / self.final.total_cycles

    @property
    def fifo_fraction(self) -> float:
        return self.buffer_plan.fifo_fraction() if self.buffer_plan else 0.0

    def report(self) -> str:
        lines = [f"== codo_opt({self.graph.name}) =="]
        for r in (self.coarse_report, self.fine_report, self.reuse_report,
                  self.buffer_plan, self.transfer_plan, self.schedule_report):
            if r is not None:
                lines.append("  " + r.summary())
        if self.baseline and self.final:
            lines.append(f"  baseline {self.baseline.total_cycles:,.0f} cyc -> "
                         f"final {self.final.total_cycles:,.0f} cyc "
                         f"({self.speedup:.1f}x, {self.fifo_fraction:.0%} FIFO)")
        lines.append(f"  compile time {self.compile_seconds*1e3:.1f} ms")
        return "\n".join(lines)


def codo_opt(graph: DataflowGraph, options: CodoOptions | None = None
             ) -> CompiledDataflow:
    import time
    t0 = time.perf_counter()
    opts = options or CodoOptions()
    g = graph.copy()
    g.validate()
    out = CompiledDataflow(g, opts)
    out.baseline = sequential_latency(g, opts.hw)

    if opts.coarse:
        out.coarse_report = eliminate_coarse(g)
    if opts.fine:
        out.fine_report = eliminate_fine(g)
    if opts.communication:
        out.reuse_report = generate_reuse_buffers(g)
        if opts.fine:
            # reuse rewriting changes stream orders: re-run correctness
            # ("reinvokes the correctness passes to avoid new violations")
            fr2 = eliminate_fine(g)
            out.fine_report.permutations += fr2.permutations
            out.fine_report.reductions_rewritten += fr2.reductions_rewritten
            out.fine_report.unresolved = fr2.unresolved
    out.buffer_plan = determine_buffers(g)
    if opts.communication:
        out.transfer_plan = plan_offchip(g, opts.hbm_channels)
    if opts.scheduling:
        out.schedule_report = autoschedule(
            g, out.buffer_plan, opts.hw, opts.budget_units, opts.max_degree,
            opts.balance_n, opts.enable_up, opts.enable_dp)

    # A design with surviving coarse violations cannot enter a dataflow
    # region at all — it executes sequentially (the Opt1 lesson of Fig. 10).
    sequential = bool(coarse_violations(g))
    out.final = graph_latency(g, opts.hw, out.buffer_plan, sequential=sequential)
    out.compile_seconds = time.perf_counter() - t0
    return out


def verify_violation_free(compiled: CompiledDataflow) -> list[str]:
    """Post-compilation invariant check (tests + CI): every FIFO edge must
    be violation-free; ping-pong edges may keep violations by design."""
    problems = []
    g = compiled.graph
    for v in coarse_violations(g):
        problems.append(f"coarse:{v.kind}:{v.buffer}")
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    for v in fine_violations(g):
        if impl.get(v.buffer) == "fifo":
            problems.append(f"fine-on-fifo:{v.kind}:{v.buffer}")
    return problems
