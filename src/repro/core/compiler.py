"""codo_opt — the end-to-end compilation pipeline (paper Fig. 3).

Pass order (deeply co-optimizing, matching §III):

  1. coarse-grained violation elimination        (coarse.py)
  2. fine-grained violation elimination          (fine.py)
  3. reuse-buffer generation (+ re-run 1&2)      (reuse.py)
  4. communication-buffer determination          (buffers.py)
  5. off-chip transfer management                (offchip.py)
  6. automated dataflow scheduling + inter-task  (schedule.py)

The pipeline is driven by :class:`repro.core.passes.PassManager`: each pass
is a named registry entry with declared invalidations, and every run emits
a :class:`~repro.core.passes.CompileDiagnostics` (per-pass wall time +
before/after violation census).  ``CodoOptions.preset("opt1").."opt5"``
reconstruct the Table VII ablations from :data:`ABLATION_PRESETS` — the
ablation grid is data, not code.

Results are memoized in a content-addressed :class:`CompileCache` keyed by
the graph's structural hash + the options, so recompiling an identical
graph is near-free (and, with ``CODO_CACHE_DIR`` set, free across
processes).

Batch mode compiles many (config, preset) cells concurrently — with worker
*processes* by default on the CLI (tasks are declarative OpSpec records,
so jobs and results pickle across the pool; workers share the disk cache
tier), or threads via ``codo_opt_batch(..., executor="thread")``:

    python -m repro.core.compiler --all --ablations --jobs 4   # Table VII grid
    python -m repro.core.compiler --configs gpt2-medium,mamba2-780m --opts opt5

Compiled designs are portable: ``--export DIR`` writes every grid cell as
a versioned JSON artifact (docs/artifact_format.md), and
``--import-artifact PATH`` reconstructs an executable design from one —
no recompile, any process.  ``--profile`` prints the per-pass timing
table aggregated from each compile's :class:`CompileDiagnostics`:

    python -m repro.core.compiler --configs gpt2-medium --export artifacts/
    python -m repro.core.compiler --import-artifact artifacts/gpt2-medium-opt5.json
    python -m repro.core.compiler --all --ablations --profile
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import hashlib
import multiprocessing
import os
import pickle
import sys
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass

from .buffers import BufferPlan
from .cache import CompileCache, _clone
from .coarse import CoarseReport
from .costmodel import V5E, GraphCost, HwParams, graph_latency, sequential_latency
from .fine import FineReport
from .graph import DataflowGraph
from .offchip import TransferPlan
from .passes import (ABLATION_PRESETS, DEFAULT_PASS_BUDGETS,
                     CompileDiagnostics, PassManager)
from .patterns import coarse_violations, fine_violations
from .reuse import ReuseReport
from .schedule import ScheduleReport


@dataclass
class CodoOptions:
    """User-facing knobs of ``codo-opt`` (§III: "users can optionally adjust
    input parameters like maximum parallelism and tiling factors")."""

    coarse: bool = True
    fine: bool = True
    communication: bool = True      # reuse buffers + buffer determination + offchip
    scheduling: bool = True
    enable_up: bool = True
    enable_dp: bool = True
    budget_units: int | None = None
    max_degree: int = 4096
    balance_n: float = 2.0
    hbm_channels: int = 8
    hw: HwParams = V5E
    # Per-pass budgets ({} / None = unenforced).  A bare number is a
    # wall-time budget in seconds (the original shape); a dict value may
    # set any of {"seconds": float, "mem_mb": float, "violations": int} —
    # peak python-allocation delta during the pass (measured via
    # tracemalloc only when requested) and a cap on the coarse+fine
    # violations *left* after the pass.  A pass exceeding any dimension
    # marks its PassRecord over_budget; see enforce_pass_budgets() and the
    # CLI --enforce-budgets/--strict flags.  Enforcement-only: budgets
    # never change the compiled design, so they are excluded from
    # cache_key().
    pass_budgets: dict[str, float | dict] | None = None

    def __post_init__(self):
        if self.pass_budgets is not None:
            norm = {}
            for k, v in sorted(dict(self.pass_budgets).items()):
                if isinstance(v, dict):
                    bad = set(v) - {"seconds", "mem_mb", "violations"}
                    if bad:
                        raise ValueError(
                            f"pass_budgets[{k!r}]: unknown dimension(s) "
                            f"{sorted(bad)}; allowed: seconds, mem_mb, "
                            f"violations")
                    norm[str(k)] = {dk: (int(dv) if dk == "violations"
                                         else float(dv))
                                    for dk, dv in sorted(v.items())}
                else:
                    norm[str(k)] = float(v)
            self.pass_budgets = norm

    # ---- pass-set presets (Table VII as data) -----------------------------
    def pass_set(self) -> tuple[str, ...]:
        """Names of the default-pipeline passes these options enable."""
        return tuple(PassManager.default().active(self))

    @classmethod
    def from_passes(cls, names, **overrides) -> "CodoOptions":
        """Options whose flags enable exactly the given pass names (plus
        ``buffers``, which always runs).  Raises when the set is not
        expressible — ``reuse`` and ``offchip`` share the single
        ``communication`` flag, so one without the other is rejected
        rather than silently widened."""
        names = set(names)
        known = {p.name for p in PassManager.default().passes}
        unknown = names - known
        if unknown:
            raise KeyError(f"unknown passes {sorted(unknown)}; known: {sorted(known)}")
        opts = cls(
            coarse="coarse" in names,
            fine="fine" in names,
            communication=bool(names & {"reuse", "offchip"}),
            scheduling="schedule" in names,
            **overrides,
        )
        got = set(opts.pass_set())
        want = names | {"buffers"}
        if got != want:
            raise ValueError(
                f"pass set {sorted(want)} is not expressible as option flags "
                f"(would enable {sorted(got)}); reuse/offchip are gated "
                f"together by `communication`")
        return opts

    @classmethod
    def preset(cls, name: str, **overrides) -> "CodoOptions":
        """Table VII ablation preset: ``preset("opt3", budget_units=512)``."""
        if name not in ABLATION_PRESETS:
            raise KeyError(f"unknown preset {name!r}; known: {sorted(ABLATION_PRESETS)}")
        return cls.from_passes(ABLATION_PRESETS[name], **overrides)

    @staticmethod
    def opt1() -> "CodoOptions":
        return CodoOptions.preset("opt1")

    @staticmethod
    def opt2() -> "CodoOptions":
        return CodoOptions.preset("opt2")

    @staticmethod
    def opt3() -> "CodoOptions":
        return CodoOptions.preset("opt3")

    @staticmethod
    def opt4() -> "CodoOptions":
        return CodoOptions.preset("opt4")

    @staticmethod
    def opt5() -> "CodoOptions":
        return CodoOptions.preset("opt5")

    # ---- content addressing ------------------------------------------------
    def cache_key(self) -> str:
        """Stable hash of every option field that affects the compiled
        design (HwParams is a frozen dataclass, so its repr is canonical).
        ``pass_budgets`` only gates *reporting*, so two compiles differing
        only in budgets share a cache entry."""
        sig = tuple((f.name, repr(getattr(self, f.name)))
                    for f in dataclasses.fields(self)
                    if f.name != "pass_budgets")
        return hashlib.sha256(repr(sig).encode()).hexdigest()

    # ---- JSON serialization (docs/artifact_format.md `options`) -----------
    def to_dict(self) -> dict:
        out = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
               if f.name != "hw"}
        out["hw"] = dataclasses.asdict(self.hw)
        return out

    @classmethod
    def from_dict(cls, doc: dict) -> "CodoOptions":
        doc = dict(doc)
        hw = doc.pop("hw", None)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(doc) - known
        if unknown:
            raise KeyError(f"unknown CodoOptions fields {sorted(unknown)}; "
                           f"known: {sorted(known)}")
        return cls(**doc, hw=HwParams(**hw) if hw is not None else V5E)


@dataclass
class CompiledDataflow:
    graph: DataflowGraph
    options: CodoOptions
    coarse_report: CoarseReport | None = None
    fine_report: FineReport | None = None
    reuse_report: ReuseReport | None = None
    buffer_plan: BufferPlan | None = None
    transfer_plan: TransferPlan | None = None
    schedule_report: ScheduleReport | None = None
    baseline: GraphCost | None = None          # sequential, degree 1
    final: GraphCost | None = None
    compile_seconds: float = 0.0
    diagnostics: CompileDiagnostics | None = None

    @property
    def speedup(self) -> float:
        if not self.baseline or not self.final or self.final.total_cycles == 0:
            return 1.0
        return self.baseline.total_cycles / self.final.total_cycles

    @property
    def fifo_fraction(self) -> float:
        return self.buffer_plan.fifo_fraction() if self.buffer_plan else 0.0

    @property
    def cache_hit(self) -> bool:
        return bool(self.diagnostics and self.diagnostics.cache_hit)

    def report(self) -> str:
        lines = [f"== codo_opt({self.graph.name}) =="]
        for r in (self.coarse_report, self.fine_report, self.reuse_report,
                  self.buffer_plan, self.transfer_plan, self.schedule_report):
            if r is not None:
                lines.append("  " + r.summary())
        if self.baseline and self.final:
            lines.append(f"  baseline {self.baseline.total_cycles:,.0f} cyc -> "
                         f"final {self.final.total_cycles:,.0f} cyc "
                         f"({self.speedup:.1f}x, {self.fifo_fraction:.0%} FIFO)")
        if self.diagnostics is not None:
            lines.append("  " + self.diagnostics.summary())
        lines.append(f"  compile time {self.compile_seconds*1e3:.1f} ms")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

_DEFAULT_MANAGER: PassManager | None = None
_DEFAULT_CACHE: CompileCache | None = None
_UNSET = object()


def default_manager() -> PassManager:
    global _DEFAULT_MANAGER
    if _DEFAULT_MANAGER is None:
        _DEFAULT_MANAGER = PassManager.default()
    return _DEFAULT_MANAGER


def default_cache() -> CompileCache:
    """Process-wide cache; ``CODO_CACHE_SIZE``/``CODO_CACHE_DIR`` configure
    the LRU size and the optional disk tier (``CODO_CACHE_JSON=1`` mirrors
    disk entries as inspectable JSON artifacts)."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = CompileCache(
            maxsize=int(os.environ.get("CODO_CACHE_SIZE", "256")),
            disk_dir=os.environ.get("CODO_CACHE_DIR") or None)
    return _DEFAULT_CACHE


def codo_opt(graph: DataflowGraph, options: CodoOptions | None = None, *,
             cache: CompileCache | None = _UNSET,
             manager: PassManager | None = None) -> CompiledDataflow:
    """Compile ``graph`` under ``options`` through the pass pipeline.

    ``cache=None`` disables memoization for this call; any other
    :class:`CompileCache` overrides the process default.
    """
    t0 = time.perf_counter()
    opts = options or CodoOptions()
    cache = default_cache() if cache is _UNSET else cache

    key = ""
    if cache is not None:
        key = cache.key(graph, opts)
        hit = cache.get(key)
        if hit is not None:
            hit.compile_seconds = time.perf_counter() - t0
            return hit

    g = graph.copy()
    g.validate()
    out = CompiledDataflow(g, opts)
    out.baseline = sequential_latency(g, opts.hw)
    diag = (manager or default_manager()).run(g, opts, out)

    # A design with surviving coarse violations cannot enter a dataflow
    # region at all — it executes sequentially (the Opt1 lesson of Fig. 10).
    sequential = bool(coarse_violations(g))
    out.final = graph_latency(g, opts.hw, out.buffer_plan, sequential=sequential)
    out.compile_seconds = time.perf_counter() - t0
    diag.total_seconds = out.compile_seconds
    diag.cache_key = key
    out.diagnostics = diag
    if cache is not None:
        cache.put(key, out)
    return out


class PassBudgetError(RuntimeError):
    """Raised by :func:`enforce_pass_budgets` in strict mode when any pass
    exceeded a budget dimension (wall time, memory delta, or the
    remaining-violation cap)."""


def enforce_pass_budgets(diagnostics, *, strict: bool = False) -> list[str]:
    """Collect per-pass budget violations across many
    :class:`CompileDiagnostics` (cache hits carry no pass records and are
    skipped).  Non-strict: emit one :class:`RuntimeWarning` per violation
    and return them; strict: raise :class:`PassBudgetError` listing all.
    """
    import warnings
    violations: list[str] = []
    for d in diagnostics:
        if d is None or d.cache_hit:
            continue
        violations.extend(d.budget_violations())
    if violations and strict:
        raise PassBudgetError(
            f"{len(violations)} pass-budget violation(s):\n  "
            + "\n  ".join(violations))
    for v in violations:
        warnings.warn(f"pass budget exceeded: {v}", RuntimeWarning,
                      stacklevel=2)
    return violations


def verify_violation_free(compiled: CompiledDataflow) -> list[str]:
    """Post-compilation invariant check (tests + CI): every FIFO edge must
    be violation-free; ping-pong edges may keep violations by design."""
    problems = []
    g = compiled.graph
    for v in coarse_violations(g):
        problems.append(f"coarse:{v.kind}:{v.buffer}")
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    for v in fine_violations(g):
        if impl.get(v.buffer) == "fifo":
            problems.append(f"fine-on-fifo:{v.kind}:{v.buffer}")
    return problems


# --------------------------------------------------------------------------
# Batch driver
# --------------------------------------------------------------------------


@dataclass
class BatchJob:
    """One cell of the batch grid.  ``build`` returns a fresh graph (called
    inside the worker so graph construction parallelizes too)."""

    config: str
    preset: str
    build: "object"           # () -> DataflowGraph
    options: CodoOptions


@dataclass
class BatchResult:
    config: str
    preset: str
    compiled: CompiledDataflow | None = None
    error: str = ""
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return self.compiled is not None

    @property
    def cache_hit(self) -> bool:
        return bool(self.compiled and self.compiled.cache_hit)

    def derived(self) -> str:
        """The per-cell metrics string shared by the CLI CSV and
        benchmarks.tables.batch_grid_rows."""
        if not self.ok:
            return self.error
        c = self.compiled
        return (f"fifo={c.fifo_fraction:.2f};cycles={c.final.total_cycles:.4g};"
                f"compile_ms={c.compile_seconds * 1e3:.2f};"
                f"cached={int(self.cache_hit)}")

    def csv(self) -> str:
        if not self.ok:
            return f"{self.config},{self.preset},error,{self.error}"
        return f"{self.config},{self.preset},{self.compiled.speedup:.4g},{self.derived()}"


def ablation_jobs(workloads: dict, presets=None, **option_overrides) -> list[BatchJob]:
    """(config × preset) grid over ``workloads`` (name -> graph factory)."""
    presets = list(presets) if presets is not None else list(ABLATION_PRESETS)
    jobs = []
    for cname, build in workloads.items():
        for pname in presets:
            jobs.append(BatchJob(cname, pname, build,
                                 CodoOptions.preset(pname, **option_overrides)))
    return jobs


def _run_job(job: BatchJob, cache, manager) -> BatchResult:
    """One cell: build the graph (inside the worker, so construction
    parallelizes too) and compile it."""
    t0 = time.perf_counter()
    res = BatchResult(job.config, job.preset)
    try:
        g = job.build() if callable(job.build) else job.build
        res.compiled = codo_opt(g, job.options, cache=cache, manager=manager)
    except Exception as e:  # keep the grid going; report per-cell
        res.error = f"{type(e).__name__}: {e}"
    res.seconds = time.perf_counter() - t0
    return res


# ---- process-pool plumbing -------------------------------------------------
# Each worker owns a private memory-tier cache; all workers share the disk
# tier (if any), so a warm grid is served from disk in every process.

_WORKER_CACHE: CompileCache | None = None


def _init_batch_worker(disk_dir: str | None, use_cache: bool,
                       json_mirror: bool = False) -> None:
    global _WORKER_CACHE
    _WORKER_CACHE = (CompileCache(disk_dir=disk_dir, json_mirror=json_mirror)
                     if use_cache else None)


def _run_job_in_worker(job: BatchJob) -> BatchResult:
    res = _run_job(job, _WORKER_CACHE, None)
    if res.compiled is not None:
        # Results cross the pipe back to the parent: closure overrides (if
        # any survived a closure-built job) cannot; specs can.
        res.compiled = _clone(res.compiled, strip_closures=True)
    return res


def _mp_context():
    """Start method for the batch pool: ``CODO_MP_START`` overrides, else
    fork where available.  Fork is safe here even with jax imported in the
    parent (jax warns about forking a threaded process) because workers
    only build and compile graphs — both jax-free since task numerics are
    declarative specs — and it avoids spawn's per-worker re-import cost
    (~5 s) and spawn's requirement of an importable ``__main__``.  Set
    ``CODO_MP_START=spawn`` if a worker ever needs to *execute* jax."""
    method = os.environ.get("CODO_MP_START")
    if not method:
        methods = multiprocessing.get_all_start_methods()
        method = "fork" if "fork" in methods else "spawn"
    return multiprocessing.get_context(method)


def codo_opt_batch(jobs, *, max_workers: int | None = None,
                   cache: CompileCache | None = _UNSET,
                   manager: PassManager | None = None,
                   executor: str = "thread") -> list[BatchResult]:
    """Compile every :class:`BatchJob` concurrently.

    ``executor="thread"`` (default) shares one in-process cache across a
    thread pool — the pipeline is pure Python, so threads mostly serialize
    on the GIL but tolerate arbitrary (closure) jobs.  ``executor="process"``
    fans out over a :class:`ProcessPoolExecutor` for real parallelism:
    jobs must pickle (declarative graphs / module-level builders — see
    :func:`batch_workloads`), a custom ``manager`` cannot ship, and workers
    share only the disk cache tier of ``cache``.
    """
    jobs = list(jobs)
    cache = default_cache() if cache is _UNSET else cache
    workers = max_workers or min(32, (os.cpu_count() or 4))
    if executor not in ("thread", "process"):
        raise ValueError(f"unknown executor {executor!r}: thread|process")

    if executor == "process" and workers > 1 and len(jobs) > 1:
        if manager is not None:
            raise ValueError("executor='process' cannot ship a custom "
                             "PassManager; workers use the default pipeline")
        try:
            pickle.dumps(jobs)
        except Exception as e:
            raise ValueError(
                "executor='process' requires picklable jobs (declarative "
                "specs + module-level graph builders, e.g. batch_workloads); "
                f"use executor='thread' for closure jobs ({e})") from e
        disk_dir = (str(cache.disk_dir)
                    if cache is not None and cache.disk_dir else None)
        with ProcessPoolExecutor(
                max_workers=min(workers, len(jobs)), mp_context=_mp_context(),
                initializer=_init_batch_worker,
                initargs=(disk_dir, cache is not None,
                          bool(cache is not None and cache.json_mirror))) as pool:
            return list(pool.map(_run_job_in_worker, jobs))

    if workers <= 1 or len(jobs) <= 1:
        return [_run_job(j, cache, manager) for j in jobs]
    with ThreadPoolExecutor(max_workers=workers) as pool:
        return list(pool.map(lambda j: _run_job(j, cache, manager), jobs))


def _resnet18_workload():
    from repro.models.dataflow_models import resnet18
    return resnet18(32)


def _gpt2_block_workload(seq: int = 128):
    from repro.models.dataflow_models import gpt2_block
    return gpt2_block(S=seq)


def _arch_workload(cfg, seq: int):
    from repro.models.dataflow_models import arch_block_graph
    return arch_block_graph(cfg, S=seq)


def batch_workloads(seq: int = 64):
    """The batch-compile model grid: every arch config in
    ``src/repro/configs/`` as a representative block graph, plus the
    paper's flagship ResNet-18 CNN and the Fig. 9 GPT-2 block (the two
    kernel-routing acceptance workloads).  Imported lazily so
    ``repro.core`` stays importable without jax.  Factories are
    ``functools.partial`` of module-level builders — picklable, so the
    grid ships to worker processes."""
    from repro.configs import CONFIGS

    workloads = {name: functools.partial(_arch_workload, cfg, seq)
                 for name, cfg in sorted(CONFIGS.items())}
    workloads["resnet18"] = _resnet18_workload
    workloads["gpt2_block"] = functools.partial(_gpt2_block_workload, seq)
    return workloads


def kernel_workloads():
    """The Table II kernels as batch-grid factories.  Every entry is a
    module-level *traced-function* builder (``trace`` of a module-level
    ``*_fn`` — see repro/models/dataflow_models.py), so jobs built from
    them pickle into the ``--jobs N`` worker processes like the config
    grid does: the frontend composes with batch ablations."""
    from repro.models.dataflow_models import KERNEL_BENCHES

    return dict(KERNEL_BENCHES)


# --------------------------------------------------------------------------
# Pass profile (CLI --profile)
# --------------------------------------------------------------------------


def profile_table(diagnostics) -> str:
    """Aggregate per-pass timing across many :class:`CompileDiagnostics`
    into the ``--profile`` table: calls, total/mean wall time, and share
    of all pass time.  Cache hits carry no pass records and are skipped."""
    totals: dict[str, float] = {}
    calls: dict[str, int] = {}
    compiles = 0
    for d in diagnostics:
        if d is None or d.cache_hit or not d.records:
            continue
        compiles += 1
        for r in d.records:
            totals[r.name] = totals.get(r.name, 0.0) + r.seconds
            calls[r.name] = calls.get(r.name, 0) + 1
    if not totals:
        return "profile: no pass records (every compile was a cache hit)"
    grand = sum(totals.values())
    lines = [f"-- pass profile: {compiles} compiles, "
             f"{grand * 1e3:.1f} ms in passes --",
             f"  {'pass':<10s} {'calls':>5s} {'total ms':>10s} "
             f"{'mean ms':>9s} {'share':>6s}"]
    for name, tot in sorted(totals.items(), key=lambda kv: -kv[1]):
        lines.append(f"  {name:<10s} {calls[name]:>5d} {tot * 1e3:>10.2f} "
                     f"{tot / calls[name] * 1e3:>9.2f} {tot / grand:>6.1%}")
    return "\n".join(lines)


def _register_kernel_patterns() -> None:
    """Routing-aware verbs (``--profile`` routing table, ``--export``
    artifacts, artifact import) see the real kernel registry."""
    from .routing import ensure_kernel_patterns
    ensure_kernel_patterns()


def routing_table(results) -> str:
    """The ``--profile`` kernel-routing table: per grid cell, how many
    fusion groups the cost gate routes to Pallas kernels, and per matched
    chain the predicted speedup vs the measured one (when the tuning
    database has an entry) — predicted-vs-measured at a glance.  Derived
    structurally (:func:`repro.core.routing.route_plan`) — no lowering,
    no jax execution."""
    from .routing import XLA_FUSED, route_plan
    lines = ["-- kernel routing (cost-gated; predicted vs measured) --"]
    pattern_counts: dict[str, int] = {}
    total = routed = 0

    def _chain_line(c: dict, verdict: str) -> str:
        pred = (c["predicted_generic_cycles"]
                / max(c["predicted_routed_cycles"], 1e-9))
        measured = c.get("measured_speedup")
        meas = f"{measured:.3f}x" if measured is not None else "--"
        return (f"    {c['kernel']:<24s} {verdict:<16s} "
                f"pred={pred:.3f}x meas={meas} "
                f"({'+'.join(c['tasks'])})")

    for r in results:
        if not r.ok:
            continue
        impl = r.compiled.buffer_plan.impl if r.compiled.buffer_plan else {}
        plan = route_plan(r.compiled.graph, impl)
        cell_routed = [p for p in plan if p["kernel"] != XLA_FUSED]
        total += len(plan)
        routed += len(cell_routed)
        for p in cell_routed:
            for route in p["routes"]:
                pattern_counts[route["kernel"]] = \
                    pattern_counts.get(route["kernel"], 0) + 1
        detail = (": " + ", ".join(sorted({p["kernel"] for p in cell_routed}))
                  if cell_routed else "")
        lines.append(f"  {r.config}/{r.preset}: {len(cell_routed)}/"
                     f"{len(plan)} groups pallas-routed{detail}")
        for p in plan:
            for c in p["routes"]:
                lines.append(_chain_line(c, c.get("decision", "?")))
            for c in p.get("rejected", ()):
                lines.append(_chain_line(c, c.get("decision", "?")))
    pats = (", ".join(f"{k} x{v}" for k, v in sorted(pattern_counts.items()))
            or "none")
    lines.append(f"  total: {routed}/{total} groups routed; patterns: {pats}")
    return "\n".join(lines)


def autotune_results(results, cache=None, *, repeats: int = 5,
                     warmup: int = 2) -> str:
    """The ``--autotune`` verb: measure routed-vs-generic (sweeping tile
    candidates) for every compiled cell, persist the winners in the
    process tuning database (and the cache's disk tier when present), and
    return a per-chain report."""
    from .tuning import autotune_compiled, default_tuning_db
    lines = ["-- autotune (measured routed-vs-generic per chain) --"]
    tuned = 0
    for r in results:
        if not r.ok:
            continue
        try:
            recs = autotune_compiled(r.compiled, repeats=repeats,
                                     warmup=warmup)
        except Exception as e:           # un-executable cells (stripped fns)
            lines.append(f"  {r.config}/{r.preset}: skipped ({e})")
            continue
        tuned += len(recs)
        for rec in recs:
            tile = f" tile={rec.tile}" if rec.tile else ""
            lines.append(
                f"  {r.config}/{r.preset} {rec.pattern:<24s} -> "
                f"{rec.choice:<9s} {rec.speedup:.3f}x "
                f"(routed={rec.routed_ms:.3f}ms generic={rec.generic_ms:.3f}"
                f"ms){tile}")
    db = default_tuning_db()
    where = ""
    if cache is not None and getattr(cache, "disk_dir", None):
        path = cache.save_tuning_db(db)
        where = f"; persisted to {path}"
    lines.append(f"  {tuned} chains measured; tuning DB has {len(db)} "
                 f"entries{where}")
    return "\n".join(lines)


# --------------------------------------------------------------------------
# CLI:  python -m repro.core.compiler --all --ablations
# --------------------------------------------------------------------------


def _fallback_grid(results) -> str:
    return "\n".join(["config,preset,speedup,derived"] + [r.csv() for r in results])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.compiler",
        description="Batch-compile the model-config grid through codo-opt "
                    "and print a Table VII-style ablation report.")
    ap.add_argument("--all", action="store_true",
                    help="compile every model config (default if --configs absent)")
    ap.add_argument("--configs", default="",
                    help="comma list of configs (see --list)")
    ap.add_argument("--ablations", action="store_true",
                    help="run the full opt1..opt5 grid (Table VII)")
    ap.add_argument("--opts", default="opt5",
                    help="comma list of presets when --ablations is not given")
    ap.add_argument("--jobs", type=int, default=0,
                    help="worker processes (0 = auto)")
    ap.add_argument("--executor", choices=("process", "thread"),
                    default="process",
                    help="batch executor: worker processes (default; real "
                         "parallelism, shared disk cache) or in-process "
                         "threads")
    ap.add_argument("--seq", type=int, default=64,
                    help="sequence length for LM block graphs")
    ap.add_argument("--kernels", action="store_true",
                    help="add the Table II traced-kernel workloads to the "
                         "grid (module-level traced builders: they ship to "
                         "the --jobs worker processes like the configs do)")
    ap.add_argument("--budget", type=int, default=2048,
                    help="scheduler budget units")
    ap.add_argument("--pass-budget", default="",
                    metavar="PASS=SEC[:MEM_MB[:VIOL]][,...]",
                    help="per-pass budgets: wall seconds, optional peak "
                         "python-allocation delta in MB, optional cap on "
                         "violations left after the pass, e.g. "
                         "'schedule=0.5,reuse=0.2:64,fine=0.1:32:0'; an "
                         "empty dimension skips it ('fine=::0'); unlisted "
                         "passes keep the DEFAULT_PASS_BUDGETS entry")
    ap.add_argument("--enforce-budgets", action="store_true",
                    help="after the grid, warn about every pass execution "
                         "that exceeded its time budget")
    ap.add_argument("--strict", action="store_true",
                    help="with --enforce-budgets: exit non-zero on any "
                         "budget violation")
    ap.add_argument("--cache-dir", default=os.environ.get("CODO_CACHE_DIR", ".codo_cache"),
                    help="on-disk compile-cache directory ('' to keep memory-only)")
    ap.add_argument("--no-cache", action="store_true", help="disable caching")
    ap.add_argument("--clear-cache", action="store_true",
                    help="drop existing disk-cache entries first")
    ap.add_argument("--csv", default="", help="also write the grid to this CSV file")
    ap.add_argument("--list", action="store_true", help="list configs and exit")
    ap.add_argument("--profile", action="store_true",
                    help="print the per-pass timing table aggregated from "
                         "CompileDiagnostics")
    ap.add_argument("--autotune", action="store_true",
                    help="after the grid, measure routed-vs-generic per "
                         "matched chain (sweeping each kernel's tile "
                         "candidates), persist the winners in the tuning "
                         "database, and print the measured table")
    ap.add_argument("--export", default="", metavar="DIR",
                    help="export every compiled cell as a versioned JSON "
                         "artifact to DIR (docs/artifact_format.md)")
    ap.add_argument("--import-artifact", default="", metavar="PATH",
                    help="import one exported artifact, print its report, "
                         "and exit (ignores the grid options)")
    args = ap.parse_args(argv)

    if args.import_artifact:
        from .artifact import artifact_summary, import_artifact
        _register_kernel_patterns()
        compiled = import_artifact(args.import_artifact)
        print(artifact_summary(args.import_artifact))
        print(compiled.report())
        if args.profile and compiled.diagnostics is not None:
            print(compiled.diagnostics.table())
        return 0

    workloads = batch_workloads(seq=args.seq)
    if args.kernels:
        workloads.update(kernel_workloads())
    if args.list:
        print("\n".join(sorted(workloads)))
        return 0
    if args.all and args.configs:
        ap.error("--all and --configs are mutually exclusive")
    if args.strict and not args.enforce_budgets:
        ap.error("--strict requires --enforce-budgets")
    if args.configs:
        names = [c.strip() for c in args.configs.split(",") if c.strip()]
        unknown = [n for n in names if n not in workloads]
        if unknown:
            ap.error(f"unknown configs {unknown}; known: {sorted(workloads)}")
        workloads = {n: workloads[n] for n in names}

    presets = (list(ABLATION_PRESETS) if args.ablations
               else [p.strip() for p in args.opts.split(",") if p.strip()])
    bad_presets = [p for p in presets if p not in ABLATION_PRESETS]
    if bad_presets:
        ap.error(f"unknown presets {bad_presets}; known: {sorted(ABLATION_PRESETS)}")
    if not presets:
        ap.error("no presets selected (use --ablations or --opts opt1,...)")

    if args.no_cache:
        cache = None
    else:
        cache = CompileCache(disk_dir=args.cache_dir or None)
        if args.clear_cache:
            cache.clear(disk=True)
        # Measured routing decisions persist next to the compiles.
        cache.load_tuning_db()

    budgets = None
    if args.pass_budget or args.enforce_budgets:
        budgets = dict(DEFAULT_PASS_BUDGETS)
        for item in args.pass_budget.split(","):
            if not item.strip():
                continue
            pname, _, val = item.partition("=")
            pname = pname.strip()
            if pname not in budgets or not val:
                ap.error(f"--pass-budget wants PASS=SEC[:MEM_MB[:VIOL]] "
                         f"with PASS in {sorted(budgets)}, got {item!r}")
            try:
                parts = val.split(":")
                if len(parts) > 3:
                    raise ValueError("too many ':' dimensions")
                dims: dict[str, float | int] = {}
                if parts[0]:
                    dims["seconds"] = float(parts[0])
                if len(parts) > 1 and parts[1]:
                    dims["mem_mb"] = float(parts[1])
                if len(parts) > 2 and parts[2]:
                    dims["violations"] = int(parts[2])
            except ValueError as e:
                ap.error(f"--pass-budget {item!r}: {e}")
            if not dims:
                ap.error(f"--pass-budget {item!r}: every dimension empty")
            budgets[pname] = (dims["seconds"]
                              if set(dims) == {"seconds"} else dims)

    if args.profile or args.export or args.autotune:
        _register_kernel_patterns()     # routing verbs see the real registry
    jobs = ablation_jobs(workloads, presets, budget_units=args.budget,
                         pass_budgets=budgets)
    t0 = time.perf_counter()
    results = codo_opt_batch(jobs, max_workers=args.jobs or None, cache=cache,
                             executor=args.executor)
    wall = time.perf_counter() - t0

    # Table VII-style report lives with the other paper tables.
    try:
        repo_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        if repo_root not in sys.path:
            sys.path.insert(0, repo_root)
        from benchmarks.tables import format_batch_grid
        print(format_batch_grid(results))
    except ImportError:
        print(_fallback_grid(results))

    hits = sum(1 for r in results if r.cache_hit)
    errors = [r for r in results if not r.ok]
    print(f"\n{len(results)} compilations ({len(workloads)} configs x "
          f"{len(presets)} presets) in {wall:.2f} s wall; "
          f"{hits} cache hits" + (f"; cache dir {args.cache_dir}" if cache and cache.disk_dir else ""))
    if cache is not None:
        if args.executor == "process":
            # Worker processes own the cache stats; the parent only sees
            # the per-cell hit flags aggregated above.
            print(f"cache: per-worker memory tiers"
                  + (f", shared disk tier at {cache.disk_dir}"
                     if cache.disk_dir else ""))
        else:
            print(cache.stats.summary())
    for r in errors:
        print(f"ERROR {r.config}/{r.preset}: {r.error}", file=sys.stderr)
    if args.autotune:
        print()
        print(autotune_results(results, cache))
    if args.profile:
        print()
        print(profile_table(r.compiled.diagnostics for r in results if r.ok))
        print()
        print(routing_table(results))
    if args.enforce_budgets:
        diags = [r.compiled.diagnostics for r in results if r.ok]
        checked = sum(1 for d in diags if d is not None and not d.cache_hit)
        try:
            violations = enforce_pass_budgets(diags, strict=args.strict)
        except PassBudgetError as e:
            print(f"STRICT: {e}", file=sys.stderr)
            return 1
        if violations:
            print(f"{len(violations)} pass-budget violation(s) "
                  f"(non-strict: warnings only)", file=sys.stderr)
        elif checked:
            print(f"pass budgets: all passes within budget "
                  f"({checked} compiles checked)")
        else:
            print("pass budgets: nothing to check (every compile was a "
                  "cache hit — no pass records)")
    if args.export:
        from .artifact import export_artifact
        os.makedirs(args.export, exist_ok=True)
        exported = 0
        for r in results:
            if not r.ok:
                continue
            try:
                export_artifact(r.compiled, os.path.join(
                    args.export, f"{r.config}-{r.preset}.json"))
                exported += 1
            except Exception as e:
                print(f"EXPORT FAIL {r.config}/{r.preset}: {e}",
                      file=sys.stderr)
        print(f"exported {exported}/{len(results)} artifacts to {args.export}")
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(_fallback_grid(results) + "\n")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
