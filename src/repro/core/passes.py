"""Pass-manager infrastructure for the ``codo-opt`` pipeline.

The paper's compiler is a fixed six-stage pipeline (Fig. 3); Table VII's
Opt1..Opt5 ablations and Fig. 10's lessons come from running *subsets* of
it over many graphs.  This module turns the hardcoded call sequence of the
old ``codo_opt()`` into data:

* each transformation registers as a named :class:`Pass` with a declared
  result slot on :class:`~repro.core.compiler.CompiledDataflow` and a
  declared set of *invalidations* — earlier passes whose guarantees it
  breaks.  ``reuse`` invalidates ``fine`` because stencil rewriting changes
  stream orders; the manager re-runs ``fine`` automatically and merges the
  reports (the paper: "reinvokes the correctness passes to avoid new
  violations").
* :class:`PassManager` executes the enabled subset in order, collecting a
  per-pass wall time and before/after violation census into a structured
  :class:`CompileDiagnostics`.
* :data:`ABLATION_PRESETS` is the Table VII grid as data: preset name →
  pass-name tuple.  ``CodoOptions.preset("opt3")`` reconstructs the
  corresponding option flags, so ablations never drift from the pipeline.

Pass metadata lives with each pass module (``PASS_INFO`` dicts in
coarse/fine/reuse/buffers/offchip/schedule) so a pass and its pipeline
declaration evolve together.
"""

from __future__ import annotations

import threading
import time
import tracemalloc
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from . import buffers as _buffers
from . import coarse as _coarse
from . import fine as _fine
from . import offchip as _offchip
from . import reuse as _reuse
from . import schedule as _schedule
from .patterns import coarse_violations, fine_violations

# Global execution census: pass name -> number of times the pass body ran
# in this process.  Tests use it to prove cache hits skip the pipeline.
PASS_RUN_COUNTS: Counter = Counter()
_COUNTS_LOCK = threading.Lock()


# --------------------------------------------------------------------------
# Pass + diagnostics
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Pass:
    """One named pipeline stage.

    ``run(graph, options, out)`` mutates ``graph`` in place and returns the
    pass report; the manager stores it on ``out.<result_attr>``.
    ``option_flag`` names the :class:`CodoOptions` boolean gating the pass
    (``None`` = always on).  ``invalidates`` lists earlier passes whose
    guarantees this pass breaks; the manager re-runs them right away.
    """

    name: str
    run: Callable[[Any, Any, Any], Any]
    result_attr: str | None = None
    option_flag: str | None = None
    invalidates: tuple[str, ...] = ()
    description: str = ""

    def enabled(self, options: Any) -> bool:
        if self.option_flag is None:
            return True
        return bool(getattr(options, self.option_flag))


def _budget_dims(raw) -> tuple[float, float, int]:
    """Normalize one ``CodoOptions.pass_budgets`` value to
    ``(seconds, mem_mb, violations)``.  A bare number is a wall-time
    budget (the original shape); a dict may set any of ``seconds``,
    ``mem_mb`` and ``violations``."""
    if isinstance(raw, dict):
        return (float(raw.get("seconds", 0.0)),
                float(raw.get("mem_mb", 0.0)),
                int(raw.get("violations", -1)))
    return float(raw or 0.0), 0.0, -1


@dataclass
class PassRecord:
    """Wall time + violation census for one pass execution."""

    name: str
    seconds: float
    coarse_before: int
    coarse_after: int
    fine_before: int
    fine_after: int
    rerun: bool = False        # re-execution triggered by an invalidation
    summary: str = ""
    budget: float = 0.0        # per-pass time budget in seconds (0 = none)
    # Structured budget dimensions (0 / -1 = unenforced).  mem_delta_mb is
    # the tracemalloc peak-over-entry python allocation during the pass,
    # measured only when a memory budget was requested (tracing costs
    # ~2x pass wall time, so it is opt-in per pass).
    mem_budget_mb: float = 0.0
    mem_delta_mb: float = 0.0
    violation_budget: int = -1  # cap on coarse+fine violations LEFT after

    @property
    def violations_after(self) -> int:
        """Census total after the pass (-1 when the census was off)."""
        if self.coarse_after < 0 or self.fine_after < 0:
            return -1
        return self.coarse_after + self.fine_after

    @property
    def over_time(self) -> bool:
        return self.budget > 0 and self.seconds > self.budget

    @property
    def over_memory(self) -> bool:
        return self.mem_budget_mb > 0 and self.mem_delta_mb > self.mem_budget_mb

    @property
    def over_violations(self) -> bool:
        return (self.violation_budget >= 0 and self.violations_after >= 0
                and self.violations_after > self.violation_budget)

    @property
    def over_budget(self) -> bool:
        return self.over_time or self.over_memory or self.over_violations

    def budget_problems(self) -> list[str]:
        """One phrase per exceeded budget dimension (empty = within)."""
        out = []
        if self.over_time:
            out.append(f"took {self.seconds * 1e3:.2f} ms > budget "
                       f"{self.budget * 1e3:.2f} ms")
        if self.over_memory:
            out.append(f"allocated {self.mem_delta_mb:.2f} MB > budget "
                       f"{self.mem_budget_mb:.2f} MB")
        if self.over_violations:
            out.append(f"left {self.violations_after} violation(s) > budget "
                       f"{self.violation_budget}")
        return out

    def line(self) -> str:
        tag = f"{self.name}*" if self.rerun else self.name
        census = ("" if self.coarse_before < 0 else
                  f"coarse {self.coarse_before:>3d}->{self.coarse_after:<3d} "
                  f"fine {self.fine_before:>3d}->{self.fine_after:<3d}  ")
        mem = (f" mem {self.mem_delta_mb:.2f} MB"
               if self.mem_budget_mb > 0 else "")
        over = (f"  OVER BUDGET ({'; '.join(self.budget_problems())})"
                if self.over_budget else "")
        return (f"{tag:<10s} {self.seconds * 1e3:8.2f} ms  "
                f"{census}{self.summary}{mem}{over}")

    def to_dict(self) -> dict:
        out = {"name": self.name, "seconds": self.seconds,
               "coarse_before": self.coarse_before,
               "coarse_after": self.coarse_after,
               "fine_before": self.fine_before, "fine_after": self.fine_after,
               "rerun": self.rerun, "summary": self.summary,
               "budget": self.budget}
        if self.mem_budget_mb > 0:
            out["mem_budget_mb"] = self.mem_budget_mb
            out["mem_delta_mb"] = self.mem_delta_mb
        if self.violation_budget >= 0:
            out["violation_budget"] = self.violation_budget
        return out

    @classmethod
    def from_dict(cls, doc: dict) -> "PassRecord":
        return cls(doc["name"], float(doc.get("seconds", 0.0)),
                   int(doc.get("coarse_before", -1)),
                   int(doc.get("coarse_after", -1)),
                   int(doc.get("fine_before", -1)),
                   int(doc.get("fine_after", -1)),
                   rerun=bool(doc.get("rerun", False)),
                   summary=doc.get("summary", ""),
                   budget=float(doc.get("budget", 0.0)),
                   mem_budget_mb=float(doc.get("mem_budget_mb", 0.0)),
                   mem_delta_mb=float(doc.get("mem_delta_mb", 0.0)),
                   violation_budget=int(doc.get("violation_budget", -1)))


@dataclass
class CompileDiagnostics:
    """Structured record of one ``codo_opt`` run (or cache hit)."""

    graph: str
    records: list[PassRecord] = field(default_factory=list)
    total_seconds: float = 0.0
    cache_hit: bool = False
    cache_key: str = ""
    # Kernel-routing record per fusion group (gid -> entry dict with the
    # winning "kernel", the cost gate's "decision", the predicted
    # routed/generic cycles, and the per-chain "routes"/"rejected"
    # verdicts).  Populated by lowering.lower() — empty until the design
    # has been lowered at least once.
    group_kernels: dict[str, dict] = field(default_factory=dict)

    @property
    def pass_names(self) -> list[str]:
        return [r.name for r in self.records]

    @property
    def pass_seconds(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for r in self.records:
            out[r.name] = out.get(r.name, 0.0) + r.seconds
        return out

    def budget_violations(self) -> list[str]:
        """Human-readable line per pass execution that blew any budget
        dimension (time, memory delta, or remaining-violation count)."""
        return [f"{self.graph}: pass {r.name}{'*' if r.rerun else ''} "
                + "; ".join(r.budget_problems())
                for r in self.records if r.over_budget]

    def routed_kernels(self) -> dict[str, str]:
        """Only the groups routed off the generic path (gid -> kernel)."""
        return {gid: e["kernel"] for gid, e in self.group_kernels.items()
                if e.get("kernel", "xla-fused") != "xla-fused"}

    def summary(self) -> str:
        src = "cache" if self.cache_hit else f"{len(self.records)} passes"
        over = sum(1 for r in self.records if r.over_budget)
        routed = len(self.routed_kernels())
        return (f"diagnostics: {src}, {self.total_seconds * 1e3:.1f} ms "
                f"({' '.join(self.pass_names)})"
                + (f"; {over} over budget" if over else "")
                + (f"; {routed}/{len(self.group_kernels)} groups "
                   f"pallas-routed" if self.group_kernels else ""))

    def table(self) -> str:
        head = f"-- passes({self.graph}) --" + (" [cache hit]" if self.cache_hit else "")
        return "\n".join([head] + ["  " + r.line() for r in self.records])

    # ---- JSON serialization (docs/artifact_format.md `diagnostics`) ------
    def to_dict(self) -> dict:
        out = {"graph": self.graph,
               "records": [r.to_dict() for r in self.records],
               "total_seconds": self.total_seconds,
               "cache_hit": self.cache_hit, "cache_key": self.cache_key}
        if self.group_kernels:
            out["group_kernels"] = {k: dict(v)
                                    for k, v in self.group_kernels.items()}
        return out

    @classmethod
    def from_dict(cls, doc: dict) -> "CompileDiagnostics":
        # Pre-1.2 artifacts recorded bare kernel strings per gid; wrap
        # them in the entry shape so consumers see one format.
        kernels = {}
        for k, v in (doc.get("group_kernels") or {}).items():
            kernels[str(k)] = dict(v) if isinstance(v, dict) else {
                "kernel": str(v)}
        return cls(graph=doc.get("graph", "?"),
                   records=[PassRecord.from_dict(r)
                            for r in doc.get("records", ())],
                   total_seconds=float(doc.get("total_seconds", 0.0)),
                   cache_hit=bool(doc.get("cache_hit", False)),
                   cache_key=doc.get("cache_key", ""),
                   group_kernels=kernels)


# --------------------------------------------------------------------------
# Default pipeline (paper Fig. 3 order)
# --------------------------------------------------------------------------


def _make_pass(info: dict, run: Callable[[Any, Any, Any], Any]) -> Pass:
    return Pass(
        name=info["name"],
        run=run,
        result_attr=info.get("result_attr"),
        option_flag=info.get("option_flag"),
        invalidates=tuple(info.get("invalidates", ())),
        description=info.get("description", ""),
    )


def default_passes() -> list[Pass]:
    """The six paper passes, in Fig. 3 order, built from each module's
    ``PASS_INFO`` declaration."""
    return [
        _make_pass(_coarse.PASS_INFO,
                   lambda g, o, out: _coarse.eliminate_coarse(g)),
        _make_pass(_fine.PASS_INFO,
                   lambda g, o, out: _fine.eliminate_fine(g)),
        _make_pass(_reuse.PASS_INFO,
                   lambda g, o, out: _reuse.generate_reuse_buffers(g)),
        _make_pass(_buffers.PASS_INFO,
                   lambda g, o, out: _buffers.determine_buffers(g)),
        _make_pass(_offchip.PASS_INFO,
                   lambda g, o, out: _offchip.plan_offchip(g, o.hbm_channels)),
        _make_pass(_schedule.PASS_INFO,
                   lambda g, o, out: _schedule.autoschedule(
                       g, out.buffer_plan, o.hw, o.budget_units, o.max_degree,
                       o.balance_n, o.enable_up, o.enable_dp)),
    ]


# Default per-pass wall-time budgets in seconds, used when budget
# enforcement is requested without explicit limits (CLI --enforce-budgets).
# Generous on purpose: they exist to catch pathological regressions (a pass
# going quadratic on a big graph), not to flag normal variance.  Override
# per compile via ``CodoOptions.pass_budgets``.
DEFAULT_PASS_BUDGETS: dict[str, float] = {
    "coarse": 2.0, "fine": 2.0, "reuse": 2.0,
    "buffers": 1.0, "offchip": 1.0, "schedule": 5.0,
}


# Table VII ablation grid as data: preset -> enabled pass names.
# (buffers always runs: even Opt1/Opt2 need an edge implementation to cost.)
ABLATION_PRESETS: dict[str, tuple[str, ...]] = {
    "opt1": ("fine", "buffers"),
    "opt2": ("coarse", "buffers"),
    "opt3": ("coarse", "reuse", "buffers", "offchip"),
    "opt4": ("coarse", "fine", "reuse", "buffers", "offchip"),
    "opt5": ("coarse", "fine", "reuse", "buffers", "offchip", "schedule"),
}


# --------------------------------------------------------------------------
# Manager
# --------------------------------------------------------------------------


class PassManager:
    """Ordered pass registry + execution engine.

    ``run(graph, options, out)`` executes every enabled pass in order,
    honouring invalidations, and returns a :class:`CompileDiagnostics`.
    """

    def __init__(self, passes: Sequence[Pass] | None = None, *,
                 census: bool = True):
        self.passes: list[Pass] = list(passes) if passes is not None else default_passes()
        # The before/after violation census costs two whole-graph scans per
        # pass (~25% of a large compile); census=False records -1 counts
        # for throughput-critical batch runs that never read diagnostics.
        self.census = census

    @classmethod
    def default(cls) -> "PassManager":
        return cls()

    # ---- registry --------------------------------------------------------
    def names(self) -> list[str]:
        return [p.name for p in self.passes]

    def get(self, name: str) -> Pass:
        for p in self.passes:
            if p.name == name:
                return p
        raise KeyError(f"no pass {name!r}; registered: {self.names()}")

    def register(self, p: Pass, *, before: str | None = None,
                 after: str | None = None, replace: bool = False) -> Pass:
        """Insert (or replace) a pass.  ``before``/``after`` anchor the
        position; default append."""
        if replace:
            self.passes[self.names().index(p.name)] = p
            return p
        if p.name in self.names():
            raise ValueError(f"pass {p.name!r} already registered")
        if before is not None:
            self.passes.insert(self.names().index(before), p)
        elif after is not None:
            self.passes.insert(self.names().index(after) + 1, p)
        else:
            self.passes.append(p)
        return p

    def active(self, options: Any) -> list[str]:
        """Pass names that would run for ``options`` (without invalidation
        re-runs)."""
        return [p.name for p in self.passes if p.enabled(options)]

    # ---- execution -------------------------------------------------------
    def _execute(self, p: Pass, graph: Any, options: Any, out: Any,
                 records: list[PassRecord], rerun: bool) -> None:
        budgets = getattr(options, "pass_budgets", None) or {}
        sec, mem_mb, viol = _budget_dims(budgets.get(p.name, 0.0))
        cb, fb = ((len(coarse_violations(graph)), len(fine_violations(graph)))
                  if self.census else (-1, -1))
        mem_delta = 0.0
        trace_mem = mem_mb > 0
        if trace_mem:
            was_tracing = tracemalloc.is_tracing()
            if not was_tracing:
                tracemalloc.start()
            tracemalloc.reset_peak()
            base, _ = tracemalloc.get_traced_memory()
        t0 = time.perf_counter()
        report = p.run(graph, options, out)
        dt = time.perf_counter() - t0
        if trace_mem:
            _, peak = tracemalloc.get_traced_memory()
            mem_delta = max(0.0, (peak - base) / 1e6)
            if not was_tracing:
                tracemalloc.stop()
        with _COUNTS_LOCK:
            PASS_RUN_COUNTS[p.name] += 1
        ca, fa = ((len(coarse_violations(graph)), len(fine_violations(graph)))
                  if self.census else (-1, -1))
        if p.result_attr is not None and out is not None:
            prev = getattr(out, p.result_attr, None)
            if rerun and prev is not None and hasattr(prev, "merge"):
                prev.merge(report)
            else:
                setattr(out, p.result_attr, report)
        summary = report.summary() if hasattr(report, "summary") else ""
        records.append(PassRecord(p.name, dt, cb, ca, fb, fa,
                                  rerun=rerun, summary=summary,
                                  budget=sec, mem_budget_mb=mem_mb,
                                  mem_delta_mb=mem_delta,
                                  violation_budget=viol))

    def run(self, graph: Any, options: Any, out: Any = None) -> CompileDiagnostics:
        t0 = time.perf_counter()
        records: list[PassRecord] = []
        ran: list[str] = []
        for p in self.passes:
            if not p.enabled(options):
                continue
            self._execute(p, graph, options, out, records, rerun=False)
            ran.append(p.name)
            for stale in p.invalidates:
                if stale == p.name or stale not in ran:
                    continue
                q = self.get(stale)
                if q.enabled(options):
                    self._execute(q, graph, options, out, records, rerun=True)
        return CompileDiagnostics(graph=getattr(graph, "name", "?"),
                                  records=records,
                                  total_seconds=time.perf_counter() - t0)


__all__ = [
    "ABLATION_PRESETS", "DEFAULT_PASS_BUDGETS", "CompileDiagnostics", "Pass",
    "PassManager", "PassRecord", "PASS_RUN_COUNTS", "default_passes",
]
