"""repro.core — the CODO dataflow compiler (the paper's contribution).

Public API:

    from repro.core import (DataflowGraph, codo_opt, CodoOptions, lower,
                            graph_latency, autoschedule)
"""

from .buffers import BufferPlan, determine_buffers, downgrade_to_pingpong
from .coarse import eliminate_coarse
from .compiler import CodoOptions, CompiledDataflow, codo_opt, verify_violation_free
from .costmodel import V5E, GraphCost, HwParams, graph_latency, sequential_latency, task_cost
from .fine import eliminate_fine
from .graph import (FIFO, PINGPONG, Access, Buffer, DataflowGraph, Loop, Task,
                    conv2d_task, copy_task, ewise_task, full_index, idx,
                    matmul_task, pad_task, pool_task, reduce_task, retarget_fn)
from .lowering import (LoweredProgram, fusion_groups, lower, register_group_kernel,
                       verify_lowering)
from .offchip import TransferPlan, host_manifest, plan_offchip
from .patterns import (coarse_violations, fine_violations, violation_report,
                       access_sig, arrival_order)
from .reuse import generate_reuse_buffers, parallel_safety
from .schedule import assign_stages, autoschedule

__all__ = [
    "Access", "Buffer", "BufferPlan", "CodoOptions", "CompiledDataflow",
    "DataflowGraph", "FIFO", "GraphCost", "HwParams", "Loop", "LoweredProgram",
    "PINGPONG", "Task", "TransferPlan", "V5E", "access_sig", "arrival_order",
    "assign_stages", "autoschedule", "coarse_violations", "codo_opt",
    "conv2d_task", "copy_task", "determine_buffers", "downgrade_to_pingpong",
    "eliminate_coarse", "eliminate_fine", "ewise_task", "fine_violations",
    "full_index", "fusion_groups", "generate_reuse_buffers", "graph_latency",
    "host_manifest", "idx", "lower", "matmul_task", "pad_task",
    "parallel_safety", "plan_offchip", "pool_task", "reduce_task",
    "register_group_kernel", "retarget_fn", "sequential_latency", "task_cost",
    "verify_lowering", "verify_violation_free", "violation_report",
]
