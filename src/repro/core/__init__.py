"""repro.core — the CODO dataflow compiler (the paper's contribution).

Public API:

    from repro.core import (DataflowGraph, codo_opt, CodoOptions, lower,
                            graph_latency, autoschedule)
"""

from .artifact import (SCHEMA_VERSION, ArtifactError, ArtifactWarning,
                       artifact_summary, artifact_weights, export_artifact,
                       import_artifact, validate_artifact)
from .buffers import BufferPlan, determine_buffers, downgrade_to_pingpong
from .cache import CacheStats, CompileCache
from .coarse import eliminate_coarse
from .compiler import (BatchJob, BatchResult, CodoOptions, CompiledDataflow,
                       PassBudgetError, ablation_jobs, batch_workloads,
                       codo_opt, codo_opt_batch, default_cache,
                       default_manager, enforce_pass_budgets,
                       kernel_workloads, verify_violation_free)
from .costmodel import V5E, GraphCost, HwParams, graph_latency, sequential_latency, task_cost
from .fine import eliminate_fine
from .frontend import (GB, ShapedBuffer, TraceError, Tracer, trace, trace_io,
                       weight_init)
from .graph import (FIFO, PINGPONG, Access, Buffer, DataflowGraph, Loop, Task,
                    conv2d_task, copy_task, ewise_task, full_index, idx,
                    matmul_task, pad_task, pool_task, reduce_task, retarget_fn)
from .lowering import (LOWER_CACHE_STATS, FusionGroup, LoweredProgram,
                       clear_lower_cache, fusion_groups, lower,
                       lower_artifact, register_group_kernel,
                       verify_lowering, verify_routing)
from .offchip import TransferPlan, host_manifest, plan_offchip
from .ops import (OpSpec, UnknownOpError, materialize, op_impl, register_op,
                  registered_ops)
from .passes import (ABLATION_PRESETS, DEFAULT_PASS_BUDGETS,
                     CompileDiagnostics, Pass, PassManager,
                     PassRecord, PASS_RUN_COUNTS, default_passes)
from .patterns import (coarse_violations, fine_violations, violation_report,
                       access_sig, arrival_order)
from .reuse import generate_reuse_buffers, parallel_safety
from .routing import (KernelPattern, RoutedKernel, XLA_FUSED,
                      pallas_disabled, register_kernel_pattern,
                      registered_patterns, route_plan)
from .schedule import assign_stages, autoschedule

__all__ = [
    "ABLATION_PRESETS", "Access", "ArtifactError", "ArtifactWarning",
    "BatchJob", "BatchResult", "Buffer", "DEFAULT_PASS_BUDGETS",
    "PassBudgetError",
    "BufferPlan", "CacheStats", "CodoOptions", "CompileCache",
    "CompileDiagnostics", "CompiledDataflow", "DataflowGraph", "FIFO",
    "FusionGroup", "GB", "GraphCost", "HwParams", "KernelPattern",
    "LOWER_CACHE_STATS", "Loop", "LoweredProgram",
    "OpSpec", "PINGPONG", "PASS_RUN_COUNTS", "Pass", "PassManager",
    "RoutedKernel", "XLA_FUSED",
    "PassRecord", "SCHEMA_VERSION", "ShapedBuffer", "Task", "TraceError",
    "Tracer", "TransferPlan", "UnknownOpError",
    "V5E",
    "ablation_jobs", "access_sig", "arrival_order", "artifact_summary",
    "artifact_weights",
    "assign_stages", "batch_workloads", "enforce_pass_budgets",
    "kernel_workloads",
    "autoschedule", "clear_lower_cache", "coarse_violations", "codo_opt",
    "codo_opt_batch", "conv2d_task", "copy_task", "default_cache",
    "default_manager", "default_passes", "determine_buffers",
    "downgrade_to_pingpong", "eliminate_coarse", "eliminate_fine",
    "ewise_task", "export_artifact", "fine_violations", "full_index",
    "fusion_groups", "generate_reuse_buffers", "graph_latency",
    "host_manifest", "idx", "import_artifact", "lower", "lower_artifact",
    "materialize", "matmul_task", "op_impl", "pad_task",
    "pallas_disabled", "parallel_safety", "plan_offchip", "pool_task",
    "reduce_task",
    "register_group_kernel", "register_kernel_pattern", "register_op",
    "registered_ops", "registered_patterns", "retarget_fn", "route_plan",
    "sequential_latency", "task_cost", "trace", "trace_io",
    "validate_artifact",
    "verify_lowering", "verify_routing", "verify_violation_free",
    "violation_report",
    "weight_init",
]
