"""Task-level pipeline executor — CODO's dataflow execution on a chip mesh.

The paper's accelerator overlaps *tasks* on one FPGA.  At pod scale the
same schedule maps onto pipeline parallelism: the scheduler's balanced
stages (schedule.assign_stages) each own a mesh slice, activations stream
stage→stage over ICI via ``collective_permute`` (= the FIFO), and
microbatches fill the pipeline exactly like Fig. 1(a)'s elements.

Implemented with ``shard_map`` over a ``stage`` axis:

* every device holds its stage's weights only,
* a ``jax.lax.scan`` over (num_microbatches + num_stages - 1) ticks runs
  the classic GPipe fill/steady/drain schedule,
* each tick: compute your stage on the held activation, then
  ``ppermute`` the result one stage forward (overlap: the permute of tick
  t and the compute of tick t+1 pipeline through XLA's async collectives).

The stage functions must be shape-preserving (activation (mb, ...) in/out),
which the transformer-block stages used in tests/examples satisfy.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclass
class PipelineSchedule:
    num_stages: int
    num_microbatches: int

    @property
    def ticks(self) -> int:
        return self.num_microbatches + self.num_stages - 1

    @property
    def bubble_fraction(self) -> float:
        return (self.num_stages - 1) / self.ticks


def pipeline_fn(stage_fns: Sequence[Callable[[Any, jax.Array], jax.Array]],
                mesh: Mesh, axis: str = "stage"):
    """Build a pipelined forward: (stacked_params, microbatched_x) -> y.

    ``stacked_params`` is a pytree whose leaves have a leading ``stage``
    dim (one slice per stage, sharded over ``axis``).  ``x`` is
    (num_microbatches, mb, ...) with outputs of the same shape.
    """
    num_stages = mesh.shape[axis]

    def per_device(params, x):  # params: this stage's slice; x: all microbatches
        params = jax.tree.map(lambda a: a[0], params)  # drop the stage dim
        sid = jax.lax.axis_index(axis)
        nmb = x.shape[0]
        ticks = nmb + num_stages - 1
        buf = jnp.zeros_like(x[0])
        outs = jnp.zeros_like(x)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, nmb - 1)
            inject = x[mb_idx]
            cur = jnp.where(sid == 0, inject, buf)
            y = _apply_stage(params, cur, sid)
            # last stage emits microbatch (t - num_stages + 1)
            out_idx = jnp.clip(t - (num_stages - 1), 0, nmb - 1)
            valid = jnp.logical_and(sid == num_stages - 1,
                                    t >= num_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[out_idx].set(y),
                lambda o: o,
                outs)
            # stream forward one stage (the inter-stage FIFO)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % num_stages) for i in range(num_stages)])
            return (nxt, outs), None

        def _apply_stage(params, h, sid):
            # dispatch to this stage's function via switch (stage fns may
            # differ structurally)
            branches = [partial(lambda f, p, a: f(p, a), f) for f in stage_fns]
            return jax.lax.switch(sid, branches, params, h)

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage populated outs; psum replicates it (all other
        # stages contribute zeros) so out_specs=P() holds
        return jax.lax.psum(outs, axis)

    from ..distributed.sharding import shard_map  # version-compat wrapper

    return jax.jit(
        shard_map(
            per_device, mesh=mesh,
            in_specs=(P(axis), P()),     # params sharded by stage; x replicated
            out_specs=P(),
            check_vma=False,
        ))


def reference_serial(stage_fns, params_stacked, x):
    """Oracle: run stages sequentially over all microbatches (no pipeline)."""
    nmb = x.shape[0]
    outs = []
    for m in range(nmb):
        h = x[m]
        for s, f in enumerate(stage_fns):
            p = jax.tree.map(lambda a: a[s], params_stacked)
            h = f(p, h)
        outs.append(h)
    return jnp.stack(outs)
