"""Off-chip data-transfer management (paper §V-C), re-targeted to TPU HBM.

The paper stripes weights across U280 HBM pseudo-channels and emits burst
accesses.  On TPU there are no user-visible channels, but the same two
levers exist:

* **burst length** → contiguous innermost extent of each DMA.  We plan
  layouts so the last dim is lane-aligned (multiple of 128) and compute the
  achievable burst per buffer; short bursts get flagged with a padded
  layout plan.
* **channel parallelism** → splitting independent weight streams across
  the (8, 16, ...) HBM "channel" queues maps to issuing independent async
  copies (double-buffered prefetch in the Pallas grid): we round-robin
  buffers over ``num_channels`` queues balancing bytes, which becomes the
  prefetch schedule of the lowered kernels.

The resulting plan feeds the cost model's bandwidth-utilization term and
the launch-time host code (launch/*.py prints the transfer manifest).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .graph import DataflowGraph

LANE = 128          # TPU lane width (f32 elements)
SUBLANE = 8

# Pipeline declaration consumed by passes.default_passes().
PASS_INFO = {
    "name": "offchip",
    "result_attr": "transfer_plan",
    "option_flag": "communication",
    "invalidates": (),
    "description": "off-chip transfer management (§V-C: channels + bursts)",
}


@dataclass
class TransferPlan:
    channel_of: dict[str, int] = field(default_factory=dict)
    burst_len: dict[str, int] = field(default_factory=dict)     # elements
    padded_shape: dict[str, tuple] = field(default_factory=dict)
    channel_bytes: list[int] = field(default_factory=list)
    bandwidth_util: float = 0.0

    def summary(self) -> str:
        return (f"offchip: {len(self.channel_of)} buffers over "
                f"{len(self.channel_bytes)} channels, "
                f"bw_util={self.bandwidth_util:.2f}, "
                f"max_channel={max(self.channel_bytes) if self.channel_bytes else 0}B")

    # ---- JSON serialization (docs/artifact_format.md `transfer_plan`) ----
    def to_dict(self) -> dict:
        return {"channel_of": dict(self.channel_of),
                "burst_len": dict(self.burst_len),
                "padded_shape": {k: list(v)
                                 for k, v in self.padded_shape.items()},
                "channel_bytes": list(self.channel_bytes),
                "bandwidth_util": self.bandwidth_util}

    @classmethod
    def from_dict(cls, doc: dict) -> "TransferPlan":
        return cls(
            channel_of={k: int(v) for k, v in doc.get("channel_of", {}).items()},
            burst_len={k: int(v) for k, v in doc.get("burst_len", {}).items()},
            padded_shape={k: tuple(int(s) for s in v)
                          for k, v in doc.get("padded_shape", {}).items()},
            channel_bytes=[int(b) for b in doc.get("channel_bytes", ())],
            bandwidth_util=float(doc.get("bandwidth_util", 0.0)))


def _burst(shape: tuple[int, ...]) -> int:
    """Contiguous innermost extent (elements) of a row-major layout."""
    if not shape:
        return 1
    b = 1
    for d in reversed(shape):
        b *= d
        if d % LANE != 0 and b != int(np.prod(shape)):
            break
    return min(b, int(np.prod(shape)))


def _pad_to_lanes(shape: tuple[int, ...]) -> tuple[int, ...]:
    if not shape:
        return shape
    out = list(shape)
    out[-1] = ((out[-1] + LANE - 1) // LANE) * LANE
    if len(out) >= 2:
        out[-2] = ((out[-2] + SUBLANE - 1) // SUBLANE) * SUBLANE
    return tuple(out)


def plan_offchip(graph: DataflowGraph, num_channels: int = 8,
                 min_burst: int = LANE) -> TransferPlan:
    plan = TransferPlan(channel_bytes=[0] * num_channels)
    offchip = [b for b in graph.buffers.values()
               if b.kind in ("input", "weight", "output")
               or b.impl == "pingpong"]
    # Greedy largest-first balancing over channels (paper: "distributes
    # parameters ... across different HBM channels, enabling parallel
    # access to independent memory regions").
    for buf in sorted(offchip, key=lambda b: -b.nbytes):
        ch = int(np.argmin(plan.channel_bytes))
        plan.channel_of[buf.name] = ch
        plan.channel_bytes[ch] += buf.nbytes
        buf.hbm_channel = ch
        burst = _burst(buf.shape)
        if burst < min_burst:
            plan.padded_shape[buf.name] = _pad_to_lanes(buf.shape)
            burst = _burst(plan.padded_shape[buf.name])
        plan.burst_len[buf.name] = burst
        buf.burst_len = burst

    # Bandwidth utilization estimate: long bursts amortize DMA setup; model
    # eff = burst/(burst+overhead) averaged over bytes, times channel balance.
    total = sum(b.nbytes for b in offchip)
    if total:
        OVERHEAD = 32  # elements of setup per burst (descriptor + latency)
        eff = sum(b.nbytes * (plan.burst_len[b.name]
                              / (plan.burst_len[b.name] + OVERHEAD))
                  for b in offchip) / total
        balance = (total / num_channels) / max(plan.channel_bytes) \
            if max(plan.channel_bytes) else 1.0
        plan.bandwidth_util = eff * min(1.0, balance * num_channels / num_channels)
    return plan


def host_manifest(graph: DataflowGraph, plan: TransferPlan) -> str:
    """The generated 'host code' — a transfer manifest the launcher executes
    (replaces the paper's codo-transmit OpenCL host generation)."""
    lines = ["# transfer manifest (buffer, channel, bytes, burst_elems)"]
    for name, ch in sorted(plan.channel_of.items()):
        b = graph.buffers[name]
        lines.append(f"h2d {name:<28s} ch={ch} bytes={b.nbytes} burst={plan.burst_len[name]}"
                     + (f" padded={plan.padded_shape[name]}" if name in plan.padded_shape else ""))
    return "\n".join(lines)
