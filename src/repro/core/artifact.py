"""Portable compiled-design artifacts: versioned JSON export/import.

The paper's flow ends in a *product*: a violation-free graph plus a
schedule and data-movement plan handed to downstream tooling.  This module
makes that product a language-neutral, versioned JSON document —
:func:`export_artifact` serializes a :class:`~repro.core.compiler.
CompiledDataflow` and :func:`import_artifact` reconstructs a fully
*executable* one (it lowers, executes, and passes ``verify_lowering``) in
any process, including non-Python consumers reading the JSON directly.

The field-by-field format contract lives in ``docs/artifact_format.md``
(every example block there is executed by ``tools/check_docs.py`` in CI,
so the spec cannot drift from this implementation).  In short, a document
contains:

``schema_version``     format version, ``"<major>.<minor>"``
``graph``              topology: buffers (shape/dtype/kind/impl) + tasks
                       (loop nests, accesses, declarative ``OpSpec``s)
``options``            the :class:`CodoOptions` the design was compiled under
``buffer_plan``        FIFO/ping-pong decision per internal edge
``transfer_plan``      HBM channel + burst assignment
``schedule``           parallel degrees + stage latencies (§VI report)
``fusion``             FIFO-connected fusion groups (derived, cross-checked)
``cost``               baseline/final cost-model summary
``diagnostics``        per-pass timing + violation census
``integrity``          the graph's ``structural_hash`` at export time

Compatibility policy
--------------------

* **Unknown fields warn** (forward compatible): a newer writer may add
  fields; readers ignore them with a :class:`ArtifactWarning`.
* **Version mismatch fails**: a different *major* version raises
  :class:`ArtifactError`; a newer *minor* version warns and proceeds.
* **Corruption fails loudly**: validation reports every problem with its
  JSON path, and the reconstructed graph must hash to the recorded
  ``integrity.structural_hash`` (disable with ``check_integrity=False``
  for deliberately hand-edited artifacts).

Everything here is importable without jax — export/import are pure data
transforms; only lowering/executing the imported design needs jax.
"""

from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
import sys
import warnings
from pathlib import Path
from typing import Any

import numpy as np

from .buffers import BufferPlan
from .compiler import CodoOptions, CompiledDataflow
from .costmodel import GraphCost, HwParams, graph_latency, sequential_latency
from .graph import FIFO, PINGPONG, UNDECIDED, DataflowGraph, GraphError
from .offchip import TransferPlan
from .ops import OpSpec, op_impl, registered_ops
from .passes import CompileDiagnostics
from .patterns import coarse_violations
from .routing import (XLA_FUSED, decide_route, ensure_kernel_patterns,
                      match_group, pallas_disabled)
from .schedule import ScheduleReport

SCHEMA_VERSION = "1.5"

# Schema changelog
# ----------------
# 1.5  `provenance`: source-graph provenance — the *pre-pass* source
#      graph's structural hash plus the trace origin (the traced
#      function's module-qualified name, or ``graph:<name>`` for
#      hand-built graphs).  The integrity hash covers the *optimized*
#      graph, so two artifacts compiled from the same source under
#      different pipelines used to be indistinguishable from two
#      different models; ``artifact diff`` now tells "same source,
#      different pipeline" from "different source".  Also introduces the
#      *train-step* document (``kind: "train_step"``): three linked
#      per-phase artifacts (`phases.forward/backward/update` — the
#      graph-level autodiff forward, cotangent, and AdamW-update designs)
#      plus a `train` section naming the loss buffer, seed cotangents,
#      shared residual buffers, per-parameter gradient buffers, and the
#      optimizer attrs, so ``codo.load`` reconstructs an executable
#      CompiledTrainStep in a fresh interpreter.  Older readers ignore
#      the `provenance` section (unknown-field policy) and this reader
#      accepts v1.0–v1.4 documents without it.
# 1.4  `sharding`: the multi-device ShardingPlan — pure-data mesh axes,
#      per-buffer placements, and the typed collective schedule
#      (all_gather / reduce_scatter / psum / ppermute steps with their
#      FIFO-depth buffer sizing and decomposition choices), plus a
#      content digest that is re-checked on import.  ``codo.load``
#      restores the plan so a sharded design round-trips to the same
#      ``shard_map`` execution; devices are only touched at run time.
#      Older readers ignore the section (unknown-field policy) and this
#      reader accepts v1.0–v1.3 documents without it.
# 1.3  `weights`: bound weight payloads — content-hashed arrays either
#      embedded (base64 of the raw little-endian bytes) or referenced from
#      an ``.npz`` sidecar next to the document, one entry per weight
#      buffer with its dtype/shape/sha256.  A weight-carrying artifact is
#      a *self-contained served model*: ``codo.load`` binds the arrays, so
#      execution never reaches ``weight_init``.  Older readers ignore the
#      section (unknown-field policy) and this reader accepts v1.0–v1.2
#      documents without it.
# 1.2  `tuning`: measured autotune results for the design's routed chains
#      — `{"entries": [TuningRecord dicts]}` keyed on chain structural
#      signature + backend + hw name (see repro.core.tuning).  Importers
#      merge the entries into the process tuning database so measured
#      routing decisions travel with the artifact; older readers ignore
#      the section (unknown-field policy) and this reader accepts v1.0/
#      v1.1 documents without it.  `diagnostics.group_kernels` values
#      became per-group entry dicts (kernel + cost-gate decision +
#      predicted cycles); bare v1.1 strings are still read.
# 1.1  `fusion.kernels`: per-group kernel-routing decision ("xla-fused" or
#      "pallas:<pattern>[+...]"), aligned with `fusion.groups`; advisory —
#      readers re-derive routing against their own kernel registry and
#      warn (never fail) on drift.  v1.0 readers ignore it (unknown-field
#      policy); this reader accepts v1.0 documents without it.

# Tool identifier recorded in `generator`; consumers should key behaviour
# on `schema_version`, never on this string.
GENERATOR = "codo-repro"


class ArtifactError(ValueError):
    """A document failed validation, version, or integrity checks.  The
    message lists every problem with its JSON path."""


class ArtifactWarning(UserWarning):
    """Forward-compat warnings: unknown fields, newer minor versions,
    cost-model drift."""


def _warn(msg: str) -> None:
    warnings.warn(msg, ArtifactWarning, stacklevel=3)


# --------------------------------------------------------------------------
# Export
# --------------------------------------------------------------------------


def _fifo_groups(graph: DataflowGraph, impl: dict[str, str]) -> list[list[str]]:
    """Maximal FIFO-connected task sets in topo order — the fusion decision
    the artifact records.  Mirrors ``lowering.fusion_groups`` but stays
    jax-free and does not mutate ``fused_group`` ids."""
    parent = {t.name: t.name for t in graph.tasks}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for p, buf, c in graph.internal_edges():
        if impl.get(buf) == FIFO:
            parent[find(p.name)] = find(c.name)

    order = [t.name for t in graph.toposort()]
    by_root: dict[str, list[str]] = {}
    for n in order:
        by_root.setdefault(find(n), []).append(n)
    pos = {n: i for i, n in enumerate(order)}
    return sorted(by_root.values(), key=lambda names: pos[names[0]])


def _group_kernels(graph: DataflowGraph, impl: dict[str, str],
                   groups: list[list[str]],
                   compiled: "CompiledDataflow | None" = None) -> list[str]:
    """Per-group kernel-routing decision, aligned with ``groups``.

    Prefers the decision the lowering recorded on the design's diagnostics
    (the kernels that actually ran); otherwise derives it structurally
    from this process's pattern registry — jax-free either way.  The
    record is advisory: importers re-derive against their own registry.
    """
    recorded = (compiled.diagnostics.group_kernels
                if compiled is not None and compiled.diagnostics is not None
                else {})
    if recorded and set(recorded) == {str(i) for i in range(len(groups))}:
        return [recorded[str(i)].get("kernel", XLA_FUSED)
                if isinstance(recorded[str(i)], dict) else str(recorded[str(i)])
                for i in range(len(groups))]
    ensure_kernel_patterns()     # best-effort; jax-less stays xla-fused
    if pallas_disabled():
        return [XLA_FUSED] * len(groups)
    out = []
    for names in groups:
        routed = []
        if len(names) > 1:
            for pat, tasks in match_group(graph, names, impl):
                if decide_route(graph, tasks, pat).routed:   # cost gate
                    routed.append(pat.name)
        out.append("pallas:" + "+".join(routed) if routed else XLA_FUSED)
    return out


def _hash_array(arr: np.ndarray) -> str:
    """Content hash of a weight payload: sha256 over the raw (C-contiguous,
    native-endian) bytes."""
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def sidecar_path(path: str | Path) -> Path:
    """Where a sidecar-weights export puts its ``.npz`` — next to the JSON
    document, same stem."""
    return Path(path).with_suffix(".weights.npz")


def _weights_section(graph: DataflowGraph, weights: dict,
                     path: str | Path | None, sidecar: bool) -> dict:
    """Build (and, for sidecar format, write) the v1.3 ``weights`` payload.
    Every array is validated against the graph's weight-buffer table so a
    weight-carrying artifact can never ship values its design cannot bind.
    """
    by_name = {b.name: b for b in graph.weights()}
    unknown = sorted(set(weights) - set(by_name))
    if unknown:
        raise ArtifactError(
            f"cannot export weights {unknown}: not weight buffers of "
            f"{graph.name!r} (weights: {sorted(by_name)})")
    if sidecar and path is None:
        raise ArtifactError("sidecar weights need a document path — the "
                            ".npz lives next to the JSON (pass path=, or "
                            "use embedded weights for in-memory documents)")
    arrays: dict[str, dict] = {}
    payload: dict[str, np.ndarray] = {}
    for name in sorted(weights):
        buf = by_name[name]
        arr = np.asarray(weights[name])
        if tuple(arr.shape) != tuple(buf.shape):
            raise ArtifactError(
                f"weight {name!r} has shape {tuple(arr.shape)}, buffer "
                f"expects {tuple(buf.shape)}")
        arr = arr.astype(np.dtype(buf.dtype), copy=False)
        entry = {"dtype": np.dtype(buf.dtype).name,
                 "shape": [int(s) for s in arr.shape],
                 "sha256": _hash_array(arr)}
        if not sidecar:
            entry["data"] = base64.b64encode(
                np.ascontiguousarray(arr).tobytes()).decode("ascii")
        arrays[name] = entry
        payload[name] = arr
    section: dict[str, Any] = {
        "format": "sidecar" if sidecar else "embedded",
        "arrays": arrays,
    }
    if sidecar:
        sc = sidecar_path(path)
        np.savez(sc, **payload)
        section["file"] = sc.name
    return section


def export_artifact(compiled: CompiledDataflow,
                    path: str | Path | None = None, *,
                    weights: dict | None = None,
                    weights_sidecar: bool = False,
                    sharding=None, provenance: dict | None = None) -> dict:
    """Serialize a compiled design to the versioned JSON artifact format.

    Returns the document as a dict; when ``path`` is given, also writes it
    as canonical JSON (sorted keys, 2-space indent).  Raises
    :class:`ArtifactError` for closure-built tasks — closures cannot
    serialize; build graphs with declarative ``OpSpec``s (``repro.core.
    ops``) so the artifact stays executable after import.

    ``weights`` (v1.3) binds concrete arrays to the design's weight
    buffers: content-hashed payloads embedded in the document, or — with
    ``weights_sidecar`` — written to ``<path>.weights.npz`` next to it.
    ``codo.load`` binds them back, so a weight-carrying artifact serves
    with no model code and no initializer in reach.

    ``sharding`` (v1.4) records the design's
    :class:`~repro.distributed.plan.ShardingPlan` — placements +
    collective schedule — so the importer reconstructs the same
    multi-device program without re-partitioning.

    ``provenance`` (v1.5) records where the design came from: the
    *pre-pass* source graph's structural hash and the trace origin.  The
    integrity hash covers the optimized graph only, so this section is
    what lets ``artifact diff`` separate "same source, different
    pipeline" from "different source".
    """
    g = compiled.graph
    closures = [t.name for t in g.tasks if t.fn_is_closure]
    if closures:
        raise ArtifactError(
            f"cannot export {g.name!r}: tasks {closures[:3]} carry raw "
            "closure numerics, which do not serialize. Attach declarative "
            "OpSpecs (repro.core.ops) instead — see docs/artifact_format.md.")
    missing = [t.name for t in g.tasks if t.spec is None]
    if missing:
        raise ArtifactError(
            f"cannot export {g.name!r}: tasks {missing[:3]} have no "
            "numeric semantics (no OpSpec); the imported design could "
            "never execute. Attach specs at graph construction.")

    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    groups = _fifo_groups(g, impl)
    tuning = _design_tuning(g, impl, groups)
    doc: dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "generator": GENERATOR,
        "graph": g.to_dict(),
        "options": compiled.options.to_dict(),
        "buffer_plan": (compiled.buffer_plan.to_dict()
                        if compiled.buffer_plan else None),
        "transfer_plan": (compiled.transfer_plan.to_dict()
                          if compiled.transfer_plan else None),
        "schedule": (compiled.schedule_report.to_dict()
                     if compiled.schedule_report else None),
        "fusion": {"groups": groups,
                   "kernels": _group_kernels(g, impl, groups, compiled)},
        "cost": {
            "baseline_cycles": (compiled.baseline.total_cycles
                                if compiled.baseline else None),
            "final_cycles": (compiled.final.total_cycles
                             if compiled.final else None),
            "speedup": compiled.speedup,
            "fifo_fraction": compiled.fifo_fraction,
            "bottleneck": (compiled.final.bottleneck
                           if compiled.final else None),
            "units": compiled.final.units if compiled.final else None,
        },
        "diagnostics": (compiled.diagnostics.to_dict()
                        if compiled.diagnostics else None),
        "tuning": tuning,
        "integrity": {"structural_hash": g.structural_hash()},
    }
    if weights is not None:
        doc["weights"] = _weights_section(g, weights, path, weights_sidecar)
    if sharding is not None:
        doc["sharding"] = sharding.to_dict()
    if provenance is not None:
        doc["provenance"] = dict(provenance)
    if path is not None:
        Path(path).write_text(dumps(doc))
    return doc


def _design_tuning(graph: DataflowGraph, impl: dict[str, str],
                   groups: list[list[str]]) -> dict | None:
    """The v1.2 ``tuning`` payload: every process tuning-database entry
    whose chain signature occurs in this design (all backends/hardware —
    the importer's routing picks the entries for its own environment).
    ``None`` when no measured entries apply."""
    from .tuning import chain_signature, default_tuning_db
    ensure_kernel_patterns()
    sigs = set()
    for names in groups:
        if len(names) < 2:
            continue
        for _pat, tasks in match_group(graph, names, impl):
            sigs.add(chain_signature(graph, tasks))
    entries = [r.to_dict() for k, r in
               sorted(default_tuning_db().entries.items())
               if r.signature in sigs]
    return {"entries": entries} if entries else None


def dumps(doc: dict) -> str:
    """Canonical JSON text of an artifact document."""
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"


# --------------------------------------------------------------------------
# Validation
# --------------------------------------------------------------------------

_NUM = (int, float)
_OPT_STR = (str, type(None))

# Field tables: name -> (accepted types, required).  ``None`` types means
# any JSON value (checked by a dedicated validator instead).
_TOP_FIELDS = {
    "schema_version": ((str,), True),
    "generator": ((str,), False),
    "graph": ((dict,), True),
    "options": ((dict,), True),
    "buffer_plan": ((dict, type(None)), False),
    "transfer_plan": ((dict, type(None)), False),
    "schedule": ((dict, type(None)), False),
    "fusion": ((dict, type(None)), False),
    "cost": ((dict, type(None)), False),
    "diagnostics": ((dict, type(None)), False),
    # v1.2: measured autotune entries for the design's routed chains.
    "tuning": ((dict, type(None)), False),
    # v1.3: bound weight payloads (embedded base64 or .npz sidecar).
    "weights": ((dict, type(None)), False),
    # v1.4: the multi-device ShardingPlan (mesh + placements + collectives).
    "sharding": ((dict, type(None)), False),
    # v1.5: pre-pass source hash + trace origin.
    "provenance": ((dict, type(None)), False),
    "integrity": ((dict, type(None)), False),
}

_PROVENANCE_FIELDS = {
    "source_structural_hash": ((str,), True),
    "origin": ((str,), False),
}

# v1.5 train-step document (kind: "train_step"): three linked per-phase
# artifacts plus the autodiff linking section.
TRAIN_STEP_KIND = "train_step"

_TRAIN_TOP_FIELDS = {
    "schema_version": ((str,), True),
    "generator": ((str,), False),
    "kind": ((str,), True),
    "phases": ((dict,), True),
    "train": ((dict,), True),
    "provenance": ((dict, type(None)), False),
}

_TRAIN_FIELDS = {
    "loss": ((str,), True),
    "seeds": ((dict,), True),
    "residuals": ((list,), True),
    "grads": ((dict,), True),
    "params": ((list,), True),
    "opt": ((dict,), True),
}

_SHARDING_FIELDS = {
    "mesh": ((dict,), True),
    "strategy": ((str,), True),
    "specs": ((dict,), False),
    "steps": ((list,), False),
    "estimated_cycles": (_NUM, False),
    "digest": ((str,), False),
}

_SHARDING_STEP_FIELDS = {
    "kind": ((str,), True),
    "buffer": ((str,), True),
    "axis": ((str,), True),
    "task": ((str,), True),
    "where": ((str,), False),
    "dim": (_NUM, False),
    "bytes": (_NUM, False),
    "chunk_bytes": (_NUM, False),
    "depth": (_NUM, False),
    "channel": (_NUM, False),
    "via": ((str,), False),
}

_GRAPH_FIELDS = {
    "name": ((str,), True),
    "buffers": ((list,), True),
    "tasks": ((list,), True),
}

_BUFFER_FIELDS = {
    "name": ((str,), True),
    "shape": ((list,), True),
    "dtype": ((str,), True),
    "kind": ((str,), True),
    "impl": ((str,), False),
    "fifo_depth": (_NUM, False),
    "hbm_channel": (_NUM, False),
    "burst_len": (_NUM, False),
}

_TASK_FIELDS = {
    "name": ((str,), True),
    "loops": ((list,), True),
    "reads": ((list,), True),
    "writes": ((list,), True),
    "op": ((str,), False),
    "flops_per_iter": (_NUM, False),
    "bytes_per_iter": (_NUM, False),
    "fused_group": (_NUM, False),
    "stage": (_NUM, False),
    "reduction_rewritten": ((bool,), False),
    "reuse_buffers": ((dict,), False),
    "tags": ((list,), False),
    "spec": ((dict, type(None)), False),
}

_LOOP_FIELDS = {
    "var": ((str,), True),
    "trip": (_NUM, True),
    "parallel": (_NUM, False),
    "tile": (_NUM, False),
    "ring": ((str,), False),
}

_ACCESS_FIELDS = {
    "buffer": ((str,), True),
    "index": ((list,), True),
    "is_write": ((bool,), True),
    "enclosing": ((list, type(None)), False),
    "stream_shape": ((list, type(None)), False),
}

_SPEC_FIELDS = {
    "kind": ((str,), True),
    "ins": ((list,), False),
    "outs": ((list,), False),
    "attrs": ((dict,), False),
    "parts": ((list,), False),
}

_FUSION_FIELDS = {
    "groups": ((list,), True),
    # v1.1: advisory per-group routing decision, aligned with `groups`.
    "kernels": ((list,), False),
}

_COST_FIELDS = {
    "baseline_cycles": (_NUM + (type(None),), False),
    "final_cycles": (_NUM + (type(None),), False),
    "speedup": (_NUM, False),
    "fifo_fraction": (_NUM, False),
    "bottleneck": (_OPT_STR, False),
    "units": (_NUM + (type(None),), False),
}

_TUNING_FIELDS = {
    "entries": ((list,), True),
}

# Per-entry fields of the v1.2 `tuning.entries` records (TuningRecord).
_TUNING_ENTRY_FIELDS = {
    "signature": ((str,), True),
    "backend": ((str,), True),
    "hw": ((str,), True),
    "pattern": ((str,), False),
    "choice": ((str,), False),
    "tile": ((dict, type(None)), False),
    "routed_ms": (_NUM, False),
    "generic_ms": (_NUM, False),
    "workload": ((str,), False),
    "tasks": ((list,), False),
}

# v1.3 `weights` section and its per-array entries.
_WEIGHTS_FIELDS = {
    "format": ((str,), True),
    "file": ((str,), False),
    "arrays": ((dict,), True),
}

_WEIGHT_ENTRY_FIELDS = {
    "dtype": ((str,), True),
    "shape": ((list,), True),
    "sha256": ((str,), True),
    "data": ((str,), False),
}

_WEIGHT_FORMATS = ("embedded", "sidecar")

_INTEGRITY_FIELDS = {
    "structural_hash": ((str,), False),
}

# Known option/hw field names: unknown entries warn and are dropped on
# import (same forward-compat stance as everywhere else in the document —
# the cost cross-check flags any semantic consequence).
_OPTIONS_KNOWN = {f.name for f in dataclasses.fields(CodoOptions)}
_HW_KNOWN = {f.name for f in dataclasses.fields(HwParams)}

_BUFFER_KINDS = ("input", "weight", "intermediate", "output")
_IMPLS = (FIFO, PINGPONG, UNDECIDED)


def _check_fields(doc: dict, path: str, fields: dict,
                  errors: list[str], notes: list[str]) -> None:
    for name, (types, required) in fields.items():
        if name not in doc:
            if required:
                errors.append(f"{path}.{name}: missing required field")
            continue
        v = doc[name]
        if not isinstance(v, types):
            want = "|".join(t.__name__ for t in types)
            errors.append(f"{path}.{name}: expected {want}, "
                          f"got {type(v).__name__}")
    for k in doc:
        if k not in fields:
            notes.append(f"{path}.{k}: unknown field (ignored — written by a "
                         "newer schema minor version?)")


def _check_spec(doc: dict, path: str, errors: list[str],
                notes: list[str]) -> None:
    _check_fields(doc, path, _SPEC_FIELDS, errors, notes)
    for i, part in enumerate(doc.get("parts", ()) or ()):
        if isinstance(part, dict):
            _check_spec(part, f"{path}.parts[{i}]", errors, notes)
        else:
            errors.append(f"{path}.parts[{i}]: expected object, "
                          f"got {type(part).__name__}")


def _check_graph(doc: dict, errors: list[str], notes: list[str]) -> None:
    _check_fields(doc, "graph", _GRAPH_FIELDS, errors, notes)
    buf_names = set()
    for i, b in enumerate(doc.get("buffers") or ()):
        p = f"graph.buffers[{i}]"
        if not isinstance(b, dict):
            errors.append(f"{p}: expected object, got {type(b).__name__}")
            continue
        _check_fields(b, p, _BUFFER_FIELDS, errors, notes)
        name = b.get("name")
        if name in buf_names:
            errors.append(f"{p}.name: duplicate buffer {name!r}")
        buf_names.add(name)
        if b.get("kind") not in (None,) + _BUFFER_KINDS:
            errors.append(f"{p}.kind: {b['kind']!r} not one of {_BUFFER_KINDS}")
        if b.get("impl") not in (None,) + _IMPLS:
            errors.append(f"{p}.impl: {b['impl']!r} not one of {_IMPLS}")
    task_names = set()
    for i, t in enumerate(doc.get("tasks") or ()):
        p = f"graph.tasks[{i}]"
        if not isinstance(t, dict):
            errors.append(f"{p}: expected object, got {type(t).__name__}")
            continue
        _check_fields(t, p, _TASK_FIELDS, errors, notes)
        name = t.get("name")
        if name in task_names:
            errors.append(f"{p}.name: duplicate task {name!r}")
        task_names.add(name)
        for j, l in enumerate(t.get("loops") or ()):
            if isinstance(l, dict):
                _check_fields(l, f"{p}.loops[{j}]", _LOOP_FIELDS, errors, notes)
            else:
                errors.append(f"{p}.loops[{j}]: expected object, "
                              f"got {type(l).__name__}")
        for side in ("reads", "writes"):
            for j, a in enumerate(t.get(side) or ()):
                q = f"{p}.{side}[{j}]"
                if not isinstance(a, dict):
                    errors.append(f"{q}: expected object, "
                                  f"got {type(a).__name__}")
                    continue
                _check_fields(a, q, _ACCESS_FIELDS, errors, notes)
                if (isinstance(a.get("buffer"), str)
                        and a["buffer"] not in buf_names):
                    errors.append(f"{q}.buffer: {a['buffer']!r} is not a "
                                  "declared graph buffer")
        spec = t.get("spec")
        if isinstance(spec, dict):
            _check_spec(spec, f"{p}.spec", errors, notes)


def _parse_version(v: str) -> tuple[int, int]:
    try:
        major, minor = v.split(".")
        return int(major), int(minor)
    except Exception:
        raise ArtifactError(
            f"schema_version: {v!r} is not '<major>.<minor>'") from None


def validate_artifact(doc: Any) -> list[str]:
    """Strict structural validation of an artifact document.

    Returns the list of forward-compat notes (unknown fields — the caller
    decides whether to warn).  Raises :class:`ArtifactError` naming every
    hard problem with its JSON path: missing/ill-typed fields, duplicate
    names, dangling buffer references, bad enum values, or an incompatible
    ``schema_version`` major.
    """
    if not isinstance(doc, dict):
        raise ArtifactError(
            f"artifact root: expected a JSON object, got "
            f"{type(doc).__name__} — is this file an exported artifact?")
    errors: list[str] = []
    notes: list[str] = []
    _check_fields(doc, "artifact", _TOP_FIELDS, errors, notes)

    version = doc.get("schema_version")
    if isinstance(version, str):
        major, minor = _parse_version(version)
        ours = _parse_version(SCHEMA_VERSION)
        if major != ours[0]:
            errors.append(
                f"schema_version: artifact is v{version}, this reader "
                f"understands v{SCHEMA_VERSION} (same major only) — "
                "re-export with a matching codo version")
        elif (major, minor) > ours:
            notes.append(
                f"schema_version: artifact v{version} is newer than this "
                f"reader (v{SCHEMA_VERSION}); unknown fields are ignored")

    if isinstance(doc.get("graph"), dict):
        _check_graph(doc["graph"], errors, notes)
    fusion = doc.get("fusion")
    if isinstance(fusion, dict):
        _check_fields(fusion, "fusion", _FUSION_FIELDS, errors, notes)
        kernels = fusion.get("kernels")
        groups = fusion.get("groups")
        if (isinstance(kernels, list) and isinstance(groups, list)
                and len(kernels) != len(groups)):
            errors.append(f"fusion.kernels: {len(kernels)} entries for "
                          f"{len(groups)} groups (must align)")
    if isinstance(doc.get("cost"), dict):
        _check_fields(doc["cost"], "cost", _COST_FIELDS, errors, notes)
    tuning = doc.get("tuning")
    if isinstance(tuning, dict):
        _check_fields(tuning, "tuning", _TUNING_FIELDS, errors, notes)
        for i, entry in enumerate(tuning.get("entries") or ()):
            if not isinstance(entry, dict):
                errors.append(f"tuning.entries[{i}]: expected dict, got "
                              f"{type(entry).__name__}")
                continue
            _check_fields(entry, f"tuning.entries[{i}]",
                          _TUNING_ENTRY_FIELDS, errors, notes)
    wts = doc.get("weights")
    if isinstance(wts, dict):
        _check_fields(wts, "weights", _WEIGHTS_FIELDS, errors, notes)
        fmt = wts.get("format")
        if isinstance(fmt, str) and fmt not in _WEIGHT_FORMATS:
            errors.append(f"weights.format: {fmt!r} not one of "
                          f"{_WEIGHT_FORMATS}")
        if fmt == "sidecar" and not isinstance(wts.get("file"), str):
            errors.append("weights.file: required for sidecar format "
                          "(names the .npz next to the document)")
        weight_bufs = {b.get("name") for b in
                       (doc.get("graph") or {}).get("buffers") or ()
                       if isinstance(b, dict) and b.get("kind") == "weight"}
        for name, entry in (wts.get("arrays") or {}).items():
            p = f"weights.arrays.{name}"
            if not isinstance(entry, dict):
                errors.append(f"{p}: expected object, "
                              f"got {type(entry).__name__}")
                continue
            _check_fields(entry, p, _WEIGHT_ENTRY_FIELDS, errors, notes)
            if name not in weight_bufs:
                errors.append(f"{p}: {name!r} is not a weight buffer of "
                              "the graph")
            if fmt == "embedded" and not isinstance(entry.get("data"), str):
                errors.append(f"{p}.data: required for embedded format")
    shard = doc.get("sharding")
    if isinstance(shard, dict):
        from repro.distributed.plan import COLLECTIVE_KINDS  # jax-free
        _check_fields(shard, "sharding", _SHARDING_FIELDS, errors, notes)
        mesh = shard.get("mesh")
        axes = mesh.get("axes") if isinstance(mesh, dict) else None
        axis_names = set()
        if isinstance(axes, list):
            for i, ax in enumerate(axes):
                if (not isinstance(ax, list) or len(ax) != 2
                        or not isinstance(ax[0], str)
                        or not isinstance(ax[1], int)):
                    errors.append(f"sharding.mesh.axes[{i}]: expected "
                                  "[name, size]")
                else:
                    axis_names.add(ax[0])
        elif mesh is not None:
            errors.append("sharding.mesh.axes: missing or not a list")
        buf_names = {b.get("name") for b in
                     (doc.get("graph") or {}).get("buffers") or ()
                     if isinstance(b, dict)}
        task_names = {t.get("name") for t in
                      (doc.get("graph") or {}).get("tasks") or ()
                      if isinstance(t, dict)}
        for name, spec in (shard.get("specs") or {}).items():
            p = f"sharding.specs.{name}"
            if name not in buf_names:
                errors.append(f"{p}: not a graph buffer")
            dims = spec.get("dims") if isinstance(spec, dict) else None
            if not isinstance(dims, list):
                errors.append(f"{p}.dims: missing or not a list")
                continue
            for d in dims:
                if d is not None and d not in axis_names:
                    errors.append(f"{p}.dims: {d!r} is not a mesh axis")
        for i, step in enumerate(shard.get("steps") or ()):
            p = f"sharding.steps[{i}]"
            if not isinstance(step, dict):
                errors.append(f"{p}: expected object, "
                              f"got {type(step).__name__}")
                continue
            _check_fields(step, p, _SHARDING_STEP_FIELDS, errors, notes)
            if step.get("kind") not in COLLECTIVE_KINDS:
                errors.append(f"{p}.kind: {step.get('kind')!r} not one of "
                              f"{COLLECTIVE_KINDS}")
            if step.get("buffer") not in buf_names:
                errors.append(f"{p}.buffer: {step.get('buffer')!r} is not "
                              "a graph buffer")
            if step.get("task") not in task_names:
                errors.append(f"{p}.task: {step.get('task')!r} is not a "
                              "graph task")
            if step.get("axis") not in axis_names:
                errors.append(f"{p}.axis: {step.get('axis')!r} is not a "
                              "mesh axis")
    if isinstance(doc.get("integrity"), dict):
        _check_fields(doc["integrity"], "integrity", _INTEGRITY_FIELDS,
                      errors, notes)
    if isinstance(doc.get("provenance"), dict):
        _check_fields(doc["provenance"], "provenance", _PROVENANCE_FIELDS,
                      errors, notes)
    opts = doc.get("options")
    if isinstance(opts, dict):
        for k in set(opts) - _OPTIONS_KNOWN:
            notes.append(f"options.{k}: unknown field (ignored — forward-"
                         "compat; the cost cross-check flags semantic drift)")
        hw = opts.get("hw")
        if isinstance(hw, dict):
            for k in set(hw) - _HW_KNOWN:
                notes.append(f"options.hw.{k}: unknown field (ignored — "
                             "forward-compat)")
    plan = doc.get("buffer_plan")
    if isinstance(plan, dict):
        buf_names = {b.get("name") for b in
                     (doc.get("graph") or {}).get("buffers") or ()
                     if isinstance(b, dict)}
        for name, impl in (plan.get("impl") or {}).items():
            if name not in buf_names:
                errors.append(f"buffer_plan.impl.{name}: not a graph buffer")
            if impl not in _IMPLS:
                errors.append(f"buffer_plan.impl.{name}: {impl!r} not one "
                              f"of {_IMPLS}")
    if errors:
        raise ArtifactError(
            "invalid artifact (%d problem%s):\n  " %
            (len(errors), "s" if len(errors) != 1 else "")
            + "\n  ".join(errors))
    return notes


# --------------------------------------------------------------------------
# Import
# --------------------------------------------------------------------------


def _load(source: str | Path | dict) -> dict:
    if isinstance(source, dict):
        return source
    path = Path(source)
    try:
        text = path.read_text()
    except OSError as e:
        raise ArtifactError(f"cannot read artifact {path}: {e}") from e
    try:
        return json.loads(text)
    except json.JSONDecodeError as e:
        raise ArtifactError(
            f"{path} is not valid JSON (line {e.lineno}: {e.msg}) — "
            "artifact truncated or corrupted?") from e


def _check_ops_registered(spec: OpSpec, task: str) -> None:
    try:
        op_impl(spec.kind)
    except KeyError:
        raise ArtifactError(
            f"task {task!r}: op kind {spec.kind!r} has no registered "
            f"implementation (registered: {registered_ops()}). Import the "
            "module that registers it (e.g. repro.kernels.register_all()) "
            "before import_artifact, or register_op yours.") from None
    for part in spec.parts:
        _check_ops_registered(part, task)


def import_artifact(source: str | Path | dict, *,
                    check_integrity: bool = True) -> CompiledDataflow:
    """Reconstruct an executable :class:`CompiledDataflow` from an artifact.

    ``source`` is a path to a JSON file or an already-parsed document.
    The result lowers, executes, and verifies like a freshly compiled
    design — every task re-derives its numerics from its ``OpSpec``
    through the op registry of *this* process.

    Validation is strict (see :func:`validate_artifact`); unknown fields
    and version-minor skew emit :class:`ArtifactWarning`.  With
    ``check_integrity`` (default), the reconstructed graph must hash to
    the recorded ``integrity.structural_hash`` — pass ``False`` to accept
    deliberately hand-edited artifacts.
    """
    doc = _load(source)
    for note in validate_artifact(doc):
        _warn(note)

    try:
        graph = DataflowGraph.from_dict(doc["graph"])
    except GraphError as e:
        raise ArtifactError(f"graph does not reconstruct: {e}") from e
    for t in graph.tasks:
        if t.spec is not None:
            _check_ops_registered(t.spec, t.name)

    recorded = (doc.get("integrity") or {}).get("structural_hash")
    if check_integrity and recorded:
        got = graph.structural_hash()
        if got != recorded:
            raise ArtifactError(
                f"integrity check failed: reconstructed graph hashes to "
                f"{got[:16]}…, artifact records {recorded[:16]}… — the "
                "document was modified after export (pass "
                "check_integrity=False to import an edited artifact).")

    # Unknown option/hw fields were noted by validate_artifact; drop them
    # here so forward-compat documents reconstruct (known fields still
    # apply and the cost cross-check below flags semantic drift).
    opts_doc = {k: v for k, v in doc["options"].items()
                if k in _OPTIONS_KNOWN}
    if isinstance(opts_doc.get("hw"), dict):
        opts_doc["hw"] = {k: v for k, v in opts_doc["hw"].items()
                          if k in _HW_KNOWN}
    try:
        options = CodoOptions.from_dict(opts_doc)
    except (KeyError, TypeError) as e:
        raise ArtifactError(f"options do not reconstruct: {e}") from e

    def _section(name: str, ctor, payload):
        if not payload:
            return None
        try:
            return ctor(payload)
        except (KeyError, TypeError, ValueError) as e:
            raise ArtifactError(
                f"{name} does not reconstruct ({type(e).__name__}: {e}) — "
                "corrupted values?") from e

    out = CompiledDataflow(
        graph, options,
        buffer_plan=_section("buffer_plan", BufferPlan.from_dict,
                             doc.get("buffer_plan")),
        transfer_plan=_section("transfer_plan", TransferPlan.from_dict,
                               doc.get("transfer_plan")),
        schedule_report=_section("schedule", ScheduleReport.from_dict,
                                 doc.get("schedule")),
        diagnostics=_section("diagnostics", CompileDiagnostics.from_dict,
                             doc.get("diagnostics")),
    )

    # Fusion cross-check: the groups are derivable from graph + plan, so a
    # stored section that disagrees means the document is inconsistent.
    stored = (doc.get("fusion") or {}).get("groups")
    impl = out.buffer_plan.impl if out.buffer_plan else {}
    if stored is not None:
        derived = _fifo_groups(graph, impl)
        if [list(g) for g in stored] != derived:
            raise ArtifactError(
                "fusion.groups disagree with the groups derived from the "
                "graph + buffer_plan — artifact edited inconsistently? "
                f"(stored {len(stored)} groups, derived {len(derived)})")
        # v1.1 `fusion.kernels` is *advisory*: routing depends on the
        # reading process's kernel registry and env switches, so drift
        # warns (the reader re-routes at lower()) instead of failing.
        stored_kernels = (doc.get("fusion") or {}).get("kernels")
        if stored_kernels is not None:
            local = _group_kernels(graph, impl, derived)
            if [str(k) for k in stored_kernels] != local:
                _warn("fusion.kernels drift: the exporter routed "
                      f"{sum(1 for k in stored_kernels if k != XLA_FUSED)} "
                      f"group(s) to Pallas kernels, this process derives "
                      f"{sum(1 for k in local if k != XLA_FUSED)} — routing "
                      "is re-derived against the local kernel registry at "
                      "lower() time")

    # v1.2 tuning entries: merge the measured routing decisions into the
    # process tuning database so this design (and any same-shaped chain)
    # routes on measurement here too.  The DB digest is part of the
    # lowering memo key, so the merge invalidates stale lowerings.
    tuning = doc.get("tuning") or {}
    if tuning.get("entries"):
        from .tuning import TuningRecord, default_tuning_db
        try:
            default_tuning_db().merge(
                TuningRecord.from_dict(e) for e in tuning["entries"])
        except (KeyError, TypeError, ValueError) as e:
            raise ArtifactError(
                f"tuning does not reconstruct ({type(e).__name__}: {e}) — "
                "corrupted values?") from e

    # v1.4 sharding section: reconstruct the pure-data plan (its stored
    # digest is re-checked by from_dict) and attach it to the design —
    # ``codo.load`` turns it back into a multi-device program.  No device
    # or jax state is touched here.
    if doc.get("sharding"):
        from repro.distributed.plan import ShardingPlan
        try:
            out.sharding_plan = ShardingPlan.from_dict(doc["sharding"])
        except (KeyError, TypeError, ValueError) as e:
            raise ArtifactError(
                f"sharding does not reconstruct ({type(e).__name__}: {e}) "
                "— corrupted values?") from e

    # The final cost is recomputed (the model is deterministic pure Python
    # over the stored graph); the recorded summary cross-checks for
    # cost-model drift across versions.  The *baseline* measured the
    # pre-optimization source graph, which the artifact does not carry
    # (passes insert duplicators and rewrite accesses), so it is restored
    # from the recorded cycles — falling back to the optimized graph's
    # sequential latency when the optional `cost` section is absent.
    sequential = bool(coarse_violations(graph))
    out.final = graph_latency(graph, options.hw, out.buffer_plan,
                              sequential=sequential)
    cost = doc.get("cost") or {}
    base = cost.get("baseline_cycles")
    if base is not None:
        out.baseline = GraphCost(
            total_cycles=float(base), start={}, finish={}, costs={},
            bottleneck="", units=0, vmem_bytes=0,
            seconds=float(base) / options.hw.clock_hz)
    else:
        out.baseline = sequential_latency(graph, options.hw)
    recorded_final = cost.get("final_cycles")
    if recorded_final is not None and out.final.total_cycles:
        drift = abs(recorded_final - out.final.total_cycles) \
            / max(out.final.total_cycles, 1.0)
        if drift > 1e-6:
            _warn(f"cost-model drift: artifact records "
                  f"{recorded_final:,.0f} final cycles, this version "
                  f"computes {out.final.total_cycles:,.0f} "
                  f"({drift:.1%}) — exported by a different codo version?")
    return out


def load_artifact(source: str | Path | dict) -> dict:
    """Parse an artifact file (or pass a parsed document through) without
    validating it — the cheap first step when the caller needs to dispatch
    on ``kind`` (design vs. v1.5 ``train_step``) before importing."""
    return _load(source)


# --------------------------------------------------------------------------
# v1.5 train-step documents
# --------------------------------------------------------------------------

_TRAIN_PHASES = ("forward", "backward", "update")


def export_train_step_artifact(phases: dict, train: dict,
                               path: str | Path | None = None, *,
                               weights: dict | None = None,
                               provenance: dict | None = None) -> dict:
    """Serialize a compiled training step (v1.5, ``kind: "train_step"``).

    ``phases`` maps ``forward``/``backward``/``update`` to their
    :class:`CompiledDataflow`; each is exported as a full artifact under
    ``phases.<name>``, so every per-phase guarantee (integrity hash,
    fusion cross-check, tuning merge) holds phase by phase on import.
    ``train`` is the autodiff linking section — loss buffer, seed
    cotangents, residual buffers shared forward→backward, per-parameter
    gradient buffers, and the optimizer attrs.  ``weights`` embeds the
    parameters into the forward phase (v1.3 semantics)."""
    missing = [p for p in _TRAIN_PHASES if p not in phases]
    if missing:
        raise ArtifactError(f"train-step export needs phases "
                            f"{_TRAIN_PHASES}; missing {missing}")
    doc: dict[str, Any] = {
        "schema_version": SCHEMA_VERSION,
        "generator": GENERATOR,
        "kind": TRAIN_STEP_KIND,
        "phases": {name: export_artifact(phases[name], None,
                                         weights=(weights if name == "forward"
                                                  else None))
                   for name in _TRAIN_PHASES},
        "train": dict(train),
    }
    if provenance is not None:
        doc["provenance"] = dict(provenance)
    if path is not None:
        Path(path).write_text(dumps(doc))
    return doc


def import_train_step(source: str | Path | dict, *,
                      check_integrity: bool = True):
    """Reconstruct the three phases of a v1.5 train-step artifact.

    Returns ``(phases, train, weights)`` — ``phases`` maps
    ``forward``/``backward``/``update`` to executable
    :class:`CompiledDataflow`\\ s (each imported through the full
    per-phase validation of :func:`import_artifact`), ``train`` is the
    linking section, ``weights`` the forward phase's bound parameter
    arrays (empty when the document carries none)."""
    doc = _load(source)
    if doc.get("kind") != TRAIN_STEP_KIND:
        raise ArtifactError(
            f"not a train-step artifact (kind={doc.get('kind')!r}); "
            "use import_artifact for single-design documents")
    errors: list[str] = []
    notes: list[str] = []
    _check_fields(doc, "artifact", _TRAIN_TOP_FIELDS, errors, notes)
    if isinstance(doc.get("train"), dict):
        _check_fields(doc["train"], "train", _TRAIN_FIELDS, errors, notes)
    if isinstance(doc.get("provenance"), dict):
        _check_fields(doc["provenance"], "provenance", _PROVENANCE_FIELDS,
                      errors, notes)
    phase_docs = doc.get("phases")
    if isinstance(phase_docs, dict):
        for p in _TRAIN_PHASES:
            if not isinstance(phase_docs.get(p), dict):
                errors.append(f"phases.{p}: missing or not an object")
    if errors:
        raise ArtifactError(
            "invalid train-step artifact (%d problem%s):\n  " %
            (len(errors), "s" if len(errors) != 1 else "")
            + "\n  ".join(errors))
    for note in notes:
        _warn(note)
    phases = {p: import_artifact(phase_docs[p],
                                 check_integrity=check_integrity)
              for p in _TRAIN_PHASES}
    train = doc["train"]
    loss = train.get("loss")
    if loss not in set(phases["forward"].graph.buffers):
        raise ArtifactError(f"train.loss: {loss!r} is not a forward-phase "
                            "buffer")
    bwd_bufs = set(phases["backward"].graph.buffers)
    dangling = [r for r in train.get("residuals", ()) if r not in bwd_bufs]
    if dangling:
        raise ArtifactError(
            f"train.residuals: {dangling[:3]} are not backward-phase "
            "buffers — phases edited inconsistently?")
    weights = artifact_weights(phase_docs["forward"])
    return phases, train, weights


def artifact_weights(source: str | Path | dict, *,
                     base_dir: str | Path | None = None) -> dict:
    """The bound weight arrays of a v1.3 artifact, verified against their
    recorded content hashes.

    Returns ``{buffer_name: np.ndarray}`` — empty for documents without a
    ``weights`` section (v1.0–v1.2).  ``source`` is a path or a parsed
    document; for sidecar-format weights the ``.npz`` is resolved relative
    to ``base_dir`` (default: the source path's directory, or the current
    directory for dict sources).  Raises :class:`ArtifactError` on a
    missing sidecar, an array the sidecar does not contain, undecodable
    payload bytes, or any sha256 mismatch — corruption never loads.
    """
    doc = _load(source)
    wts = doc.get("weights")
    if not wts:
        return {}
    fmt = wts.get("format")
    arrays = wts.get("arrays") or {}
    if base_dir is None:
        base_dir = (Path(source).parent
                    if not isinstance(source, dict) else Path("."))
    npz = None
    if fmt == "sidecar":
        sc = Path(base_dir) / wts.get("file", "")
        try:
            npz = np.load(sc)
        except OSError as e:
            raise ArtifactError(
                f"weights sidecar {sc} is missing or unreadable ({e}) — "
                "the artifact's .npz must travel next to its JSON") from e
    elif fmt != "embedded":
        raise ArtifactError(f"weights.format: {fmt!r} not one of "
                            f"{_WEIGHT_FORMATS}")
    out: dict[str, np.ndarray] = {}
    for name in sorted(arrays):
        entry = arrays[name]
        dtype = np.dtype(entry["dtype"])
        shape = tuple(int(s) for s in entry["shape"])
        if npz is not None:
            if name not in npz.files:
                raise ArtifactError(
                    f"weights.arrays.{name}: not present in sidecar "
                    f"{wts.get('file')!r} (has {sorted(npz.files)})")
            arr = np.asarray(npz[name])
            if arr.dtype != dtype or arr.shape != shape:
                raise ArtifactError(
                    f"weights.arrays.{name}: sidecar holds "
                    f"{arr.dtype.name}{list(arr.shape)}, document records "
                    f"{dtype.name}{list(shape)}")
        else:
            try:
                raw = base64.b64decode(entry["data"], validate=True)
                arr = np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
            except (ValueError, KeyError) as e:
                raise ArtifactError(
                    f"weights.arrays.{name}: embedded payload does not "
                    f"decode to {dtype.name}{list(shape)} ({e})") from e
        got = _hash_array(arr)
        if got != entry["sha256"]:
            raise ArtifactError(
                f"weights.arrays.{name}: content hash mismatch — payload "
                f"hashes to {got[:16]}…, document records "
                f"{entry['sha256'][:16]}… (corrupted or tampered weights)")
        out[name] = arr
    return out


# --------------------------------------------------------------------------
# Inspection
# --------------------------------------------------------------------------


def artifact_summary(source: str | Path | dict) -> str:
    """One-paragraph human summary of an artifact (used by the CLI's
    ``--import-artifact`` verb and handy in notebooks)."""
    doc = _load(source)
    if doc.get("kind") == TRAIN_STEP_KIND:
        train = doc.get("train") or {}
        lines = [f"train-step artifact (schema "
                 f"v{doc.get('schema_version')}): loss={train.get('loss')}, "
                 f"{len(train.get('params') or ())} params, "
                 f"{len(train.get('residuals') or ())} residuals"]
        for p in _TRAIN_PHASES:
            phase = (doc.get("phases") or {}).get(p)
            if phase:
                lines += ["  " + ln for ln in
                          artifact_summary(phase).splitlines()]
        return "\n".join(lines)
    g = doc.get("graph") or {}
    cost = doc.get("cost") or {}
    plan = doc.get("buffer_plan") or {}
    impl = plan.get("impl") or {}
    fifo = sum(1 for v in impl.values() if v == FIFO)
    groups = (doc.get("fusion") or {}).get("groups") or []
    kernels = (doc.get("fusion") or {}).get("kernels") or []
    routed = sum(1 for k in kernels if k != XLA_FUSED)
    lines = [
        f"artifact {g.get('name', '?')} (schema v{doc.get('schema_version')})",
        f"  {len(g.get('tasks') or ())} tasks, "
        f"{len(g.get('buffers') or ())} buffers; "
        f"{fifo}/{len(impl)} internal edges FIFO; "
        f"{len(groups)} fusion groups"
        + (f" ({routed} pallas-routed)" if kernels else ""),
    ]
    if cost.get("final_cycles") is not None:
        lines.append(
            f"  cost: {cost['final_cycles']:,.0f} cycles "
            f"({cost.get('speedup', 1.0):.1f}x vs sequential), "
            f"bottleneck={cost.get('bottleneck')}")
    sched = doc.get("schedule") or {}
    if sched:
        lines.append(f"  schedule: units={sched.get('units_used')}, "
                     f"{len(sched.get('degrees') or {})} tasks scheduled")
    return "\n".join(lines)


def diff_artifacts(a: str | Path | dict, b: str | Path | dict) -> list[str]:
    """Compare two artifact documents; return human-readable differences.

    An empty list means the artifacts agree on everything the compiler
    decided: schema version, graph structure (structural hash + counts),
    fusion grouping and kernel routing, autotuning entries, and the v1.4
    ``sharding`` section.  Cosmetic fields (generator string, measured
    milliseconds inside tuning records) are ignored so re-exports of the
    same design diff clean.

    With v1.5 ``provenance`` on both sides, a graph difference is
    classified: *same source, different pipeline* (equal pre-pass source
    hashes — the designs came from one model compiled under different
    options/passes) vs. *different source* (the models themselves
    differ).  Two v1.5 train-step documents diff phase by phase.
    """
    da, db = _load(a), _load(b)
    if (da.get("kind") == TRAIN_STEP_KIND) != (db.get("kind") == TRAIN_STEP_KIND):
        return [f"kind: {da.get('kind')!r} != {db.get('kind')!r} "
                "(train-step vs single-design artifact)"]
    if da.get("kind") == TRAIN_STEP_KIND:
        out = []
        for p in _TRAIN_PHASES:
            out += [f"phases.{p}.{line}" for line in
                    diff_artifacts((da.get("phases") or {}).get(p) or {},
                                   (db.get("phases") or {}).get(p) or {})]
        if da.get("train") != db.get("train"):
            out.append("train: linking sections differ")
        return out
    out: list[str] = []

    def _field(label, va, vb):
        if va != vb:
            out.append(f"{label}: {va!r} != {vb!r}")

    _field("schema_version", da.get("schema_version"), db.get("schema_version"))
    ha = (da.get("integrity") or {}).get("structural_hash")
    hb = (db.get("integrity") or {}).get("structural_hash")
    _field("integrity.structural_hash", ha, hb)
    pa, pb = da.get("provenance") or {}, db.get("provenance") or {}
    sa_hash, sb_hash = (pa.get("source_structural_hash"),
                        pb.get("source_structural_hash"))
    if sa_hash and sb_hash and ha != hb:
        # v1.5: the integrity hash covers the optimized graph; the source
        # hash tells whether the divergence is the model or the pipeline.
        if sa_hash == sb_hash:
            out.append("provenance: same source, different pipeline "
                       f"(source {sa_hash[:16]}…; optimized graphs differ)")
        else:
            out.append(f"provenance: different source "
                       f"({sa_hash[:16]}… != {sb_hash[:16]}…)")
    elif pa.get("origin") != pb.get("origin"):
        _field("provenance.origin", pa.get("origin"), pb.get("origin"))
    ga, gb = da.get("graph") or {}, db.get("graph") or {}
    _field("graph.name", ga.get("name"), gb.get("name"))
    _field("graph.tasks", len(ga.get("tasks") or ()), len(gb.get("tasks") or ()))
    _field("graph.buffers", len(ga.get("buffers") or ()),
           len(gb.get("buffers") or ()))

    fa, fb = da.get("fusion") or {}, db.get("fusion") or {}
    gra = [tuple(g) for g in fa.get("groups") or ()]
    grb = [tuple(g) for g in fb.get("groups") or ()]
    if gra != grb:
        out.append(f"fusion.groups: {len(gra)} group(s) != {len(grb)} group(s)")
    ka, kb = list(fa.get("kernels") or ()), list(fb.get("kernels") or ())
    if ka != kb:
        out.append(f"fusion.kernels: {ka} != {kb}")

    def _tuning(doc):
        entries = (doc.get("tuning") or {}).get("entries") or ()
        return {f"{e.get('signature')}:{e.get('backend')}:{e.get('hw')}":
                (e.get("choice"), json.dumps(e.get("tile"), sort_keys=True))
                for e in entries}

    ta, tb = _tuning(da), _tuning(db)
    for key in sorted(set(ta) - set(tb)):
        out.append(f"tuning[{key}]: only in first")
    for key in sorted(set(tb) - set(ta)):
        out.append(f"tuning[{key}]: only in second")
    for key in sorted(set(ta) & set(tb)):
        if ta[key] != tb[key]:
            out.append(f"tuning[{key}]: choice/tile {ta[key]} != {tb[key]}")

    sa, sb = da.get("sharding"), db.get("sharding")
    if (sa is None) != (sb is None):
        out.append("sharding: present in "
                   + ("first only" if sb is None else "second only"))
    elif sa is not None:
        _field("sharding.strategy", sa.get("strategy"), sb.get("strategy"))
        _field("sharding.mesh", (sa.get("mesh") or {}).get("axes"),
               (sb.get("mesh") or {}).get("axes"))
        _field("sharding.digest", sa.get("digest"), sb.get("digest"))
        na, nb = len(sa.get("steps") or ()), len(sb.get("steps") or ())
        if na != nb:
            out.append(f"sharding.steps: {na} != {nb}")
    return out


def main(argv: list[str] | None = None) -> int:
    """``python -m repro.core.artifact diff A.json B.json``.

    Exit status: 0 = artifacts agree, 1 = they differ (differences on
    stdout, one per line), 2 = usage or load error.  Stable for CI use:
    ``python -m repro.core.artifact diff golden.json fresh.json`` guards
    against silent compiler-decision drift.
    """
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.core.artifact",
        description="Inspect and compare CODO artifact files.")
    sub = parser.add_subparsers(dest="verb", required=True)
    p_diff = sub.add_parser(
        "diff", help="compare two artifacts' compiler decisions")
    p_diff.add_argument("a", help="first artifact JSON")
    p_diff.add_argument("b", help="second artifact JSON")
    p_show = sub.add_parser("summary", help="print a one-paragraph summary")
    p_show.add_argument("a", help="artifact JSON")
    try:
        ns = parser.parse_args(argv)
    except SystemExit as e:
        return 2 if e.code else 0
    try:
        if ns.verb == "summary":
            print(artifact_summary(ns.a))
            return 0
        diffs = diff_artifacts(ns.a, ns.b)
    except (OSError, ValueError, ArtifactError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    for line in diffs:
        print(line)
    if diffs:
        print(f"{len(diffs)} difference(s)")
        return 1
    print("artifacts match")
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    sys.exit(main())


__all__ = ["SCHEMA_VERSION", "TRAIN_STEP_KIND", "ArtifactError",
           "ArtifactWarning", "artifact_summary", "artifact_weights",
           "diff_artifacts", "dumps", "export_artifact",
           "export_train_step_artifact", "import_artifact",
           "import_train_step", "load_artifact", "main", "sidecar_path",
           "validate_artifact"]
