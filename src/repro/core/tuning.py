"""Measured autotuning for routed kernels (ISSUE 6).

The routing gate (:mod:`repro.core.routing`) decides routed-vs-generic
from *predicted* cycles.  This module replaces prediction with
measurement on demand: :func:`autotune_compiled` runs every structurally
matched chain of a compiled design both ways — sweeping the pattern's
declared tile/block candidates on the routed side — and persists the
winners in a :class:`TuningDB`.

Database entries are keyed on ``(chain structural signature, backend,
hw name)``: the signature hashes the chain's op kinds, attrs, and operand
shapes/dtypes (not buffer names), so a tuned decision transfers to any
design containing the same-shaped chain.  The routing layer consults the
database before the cost gate — measured beats predicted — and the
database digest enters the lowering memo key, so updating it never serves
a stale program.  Entries travel in artifact schema v1.2 (``tuning``
section) and in the disk compile cache (``tuning.json``).

Everything except :func:`autotune_compiled` is importable without jax.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Any, Iterable

from .graph import DataflowGraph, Task


def chain_signature(graph: DataflowGraph, tasks: list[Task]) -> str:
    """Structural identity of a matched chain: op kinds, spec attrs, and
    operand shapes/dtypes in chain order.  Buffer names are excluded —
    equal signatures mean the same-shaped computation."""
    import hashlib

    import numpy as np

    def _sig(b):
        # np.dtype canonicalizes: a live graph holds the numpy scalar
        # *class*, an artifact-restored one the dtype's string name.
        return (tuple(graph.buffers[b].shape),
                str(np.dtype(graph.buffers[b].dtype)))

    parts = []
    for t in tasks:
        if t.spec is None:
            parts.append((t.op,))
            continue
        ins = tuple(_sig(b) for b in t.spec.ins)
        outs = tuple(_sig(b) for b in t.spec.outs)
        attrs = tuple(sorted((k, repr(v)) for k, v in t.spec.attrs.items()))
        parts.append((t.op, t.spec.kind, attrs, ins, outs))
    return hashlib.sha256(repr(parts).encode()).hexdigest()[:16]


@dataclass
class TuningRecord:
    """One measured routing decision: for this chain signature on this
    backend/hardware, ``choice`` won at ``tile`` (``None`` = the kernel's
    default blocking, or the generic path when choice is ``xla-fused``)."""

    signature: str
    backend: str
    hw: str
    pattern: str
    choice: str                       # "pallas" | "xla-fused"
    tile: dict | None = None
    routed_ms: float = 0.0
    generic_ms: float = 0.0
    workload: str = ""
    tasks: list[str] = field(default_factory=list)

    @property
    def key(self) -> str:
        return f"{self.signature}:{self.backend}:{self.hw}"

    @property
    def speedup(self) -> float:
        """Measured generic/routed ratio (>1 means the kernel won)."""
        return self.generic_ms / max(self.routed_ms, 1e-9)

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, doc: dict) -> "TuningRecord":
        return cls(signature=str(doc["signature"]),
                   backend=str(doc["backend"]), hw=str(doc["hw"]),
                   pattern=str(doc.get("pattern", "?")),
                   choice=str(doc.get("choice", "xla-fused")),
                   tile=doc.get("tile"),
                   routed_ms=float(doc.get("routed_ms", 0.0)),
                   generic_ms=float(doc.get("generic_ms", 0.0)),
                   workload=str(doc.get("workload", "")),
                   tasks=[str(t) for t in doc.get("tasks", ())])


class TuningDB:
    """Keyed store of :class:`TuningRecord`\\ s with a change-tracking
    digest (the lowering memo key ingredient)."""

    def __init__(self, records: Iterable[TuningRecord] = ()):
        self.entries: dict[str, TuningRecord] = {}
        self._digest: str | None = None
        for r in records:
            self.entries[r.key] = r

    def __len__(self) -> int:
        return len(self.entries)

    def lookup(self, signature: str, backend: str,
               hw: str) -> TuningRecord | None:
        return self.entries.get(f"{signature}:{backend}:{hw}")

    def update(self, record: TuningRecord) -> None:
        self.entries[record.key] = record
        self._digest = None

    def merge(self, records: Iterable[TuningRecord]) -> int:
        n = 0
        for r in records:
            self.entries[r.key] = r
            n += 1
        if n:
            self._digest = None
        return n

    def digest(self) -> str:
        if self._digest is None:
            import hashlib
            canon = sorted((k, repr(sorted(asdict(r).items())))
                           for k, r in self.entries.items())
            self._digest = hashlib.sha256(
                repr(canon).encode()).hexdigest()[:16]
        return self._digest

    # ---- JSON persistence (also the artifact v1.2 `tuning` payload) ------
    def to_dict(self) -> dict:
        return {"entries": [self.entries[k].to_dict()
                            for k in sorted(self.entries)]}

    @classmethod
    def from_dict(cls, doc: dict) -> "TuningDB":
        return cls(TuningRecord.from_dict(e)
                   for e in (doc or {}).get("entries", ()))

    def save(self, path: str | Path) -> None:
        Path(path).parent.mkdir(parents=True, exist_ok=True)
        Path(path).write_text(
            json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n")

    @classmethod
    def load(cls, path: str | Path) -> "TuningDB":
        return cls.from_dict(json.loads(Path(path).read_text()))


_DEFAULT_DB: TuningDB | None = None


def default_tuning_db() -> TuningDB:
    """The process-wide database routing consults.  Seeded once from the
    ``CODO_TUNING_DB`` JSON when that is set and readable; use
    :func:`reset_default_tuning_db` to re-read it."""
    global _DEFAULT_DB
    if _DEFAULT_DB is None:
        _DEFAULT_DB = TuningDB()
        path = os.environ.get("CODO_TUNING_DB", "").strip()
        if path:
            try:
                _DEFAULT_DB = TuningDB.load(path)
            except (OSError, ValueError, KeyError):
                pass
    return _DEFAULT_DB


def reset_default_tuning_db() -> None:
    global _DEFAULT_DB
    _DEFAULT_DB = None


# --------------------------------------------------------------------------
# The measured sweep (jax only from here down)
# --------------------------------------------------------------------------


def _random_env(graph: DataflowGraph, seed: int = 0) -> dict[str, Any]:
    """Random input/weight values straight from the buffer table — no
    model-builder dependency, so any compiled design can autotune."""
    import numpy as np
    rng = np.random.default_rng(seed)
    return {b.name: rng.standard_normal(b.shape).astype(b.dtype)
            for b in graph.buffers.values()
            if b.kind in ("input", "weight")}


def _best_of(fns: list, env: dict, block, warmup: int,
             repeats: int) -> list[float]:
    """Best-of-N ms per callable, reps interleaved round-robin so machine
    drift hits every candidate equally (same discipline as the routing
    bench)."""
    for _ in range(max(warmup, 1)):
        for fn in fns:
            block(fn(env))
    best = [float("inf")] * len(fns)
    for rep in range(max(repeats, 1)):
        order = range(len(fns)) if rep % 2 == 0 else range(len(fns) - 1, -1, -1)
        for i in order:
            t0 = time.perf_counter()
            block(fns[i](env))
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e3 for b in best]


def autotune_compiled(compiled, *, db: TuningDB | None = None,
                      repeats: int = 5, warmup: int = 2, seed: int = 0,
                      save_path: str | Path | None = None,
                      ) -> list[TuningRecord]:
    """Measure every structurally matched chain of ``compiled`` routed vs
    generic, sweeping the pattern's tile candidates, and record the
    winners in ``db`` (the process default when ``None``).

    Matching is gate-free — the whole point is to replace the predictor's
    verdict with a measurement — and honors only the hard
    ``CODO_DISABLE_PALLAS`` switch.  Returns the new records (also merged
    into ``db``; saved to ``save_path`` JSON when given).
    """
    import jax

    from . import routing
    from .artifact import _fifo_groups
    from .costmodel import routing_backend
    from .lowering import FusionGroup

    routing.ensure_kernel_patterns()
    if db is None:
        db = default_tuning_db()
    graph = compiled.graph
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    backend = routing_backend()
    hw_name = compiled.options.hw.name

    # Full buffer scope, produced task by task, to slice chain inputs from.
    scope = dict(_random_env(graph, seed))
    for t in graph.toposort():
        scope.update(t.fn(scope))

    block = jax.block_until_ready
    records: list[TuningRecord] = []
    for gid, names in enumerate(_fifo_groups(graph, impl)):
        if len(names) < 2 or routing.pallas_disabled():
            continue
        group_view = FusionGroup(gid, list(names),
                                 tuple(graph.task(n).op for n in names))
        for pat, tasks in routing.match_group(graph, names, impl):
            interior = {t.writes[0].buffer for t in tasks[:-1]}
            ext = sorted({a.buffer for t in tasks for a in t.reads
                          if a.buffer not in interior})
            env = {b: scope[b] for b in ext}
            out_buf = tasks[-1].writes[0].buffer
            fns = [t.fn for t in tasks]

            def generic(e, _fns=fns, _out=out_buf):
                s = dict(e)
                for f in _fns:
                    s.update(f(s))
                return {_out: s[_out]}

            tiles = pat.tiles(graph, tasks) if pat.tiles else [None]
            cands, steps = [], [jax.jit(generic)]
            for tile in tiles:
                step = (pat.factory(graph, group_view, tasks, tile=tile)
                        if tile is not None
                        else pat.factory(graph, group_view, tasks))
                if step is not None:
                    cands.append(tile)
                    steps.append(step)
            if not cands:
                continue
            times = _best_of(steps, env, block, warmup, repeats)
            generic_ms, routed_ms = times[0], times[1:]
            best = min(range(len(routed_ms)), key=routed_ms.__getitem__)
            choice = ("pallas" if routed_ms[best] <= generic_ms
                      else routing.XLA_FUSED)
            rec = TuningRecord(
                signature=chain_signature(graph, tasks), backend=backend,
                hw=hw_name, pattern=pat.name, choice=choice,
                tile=cands[best], routed_ms=round(routed_ms[best], 4),
                generic_ms=round(generic_ms, 4), workload=graph.name,
                tasks=[t.name for t in tasks])
            db.update(rec)
            records.append(rec)
    if save_path is not None:
        db.save(save_path)
    return records


__all__ = ["TuningDB", "TuningRecord", "autotune_compiled",
           "chain_signature", "default_tuning_db", "reset_default_tuning_db"]
