"""Lowering: a :class:`CompiledDataflow` → an executable JAX callable.

FIFO edges become *fusion groups*: maximal chains of FIFO-connected tasks
are executed as one fused function whose intermediates never round-trip
through HBM (inside jit, XLA fuses them; for hot patterns the group is
routed to a hand-written Pallas streaming kernel via the kernel registry).
Ping-pong edges are group boundaries — the intermediate materializes in
HBM, double-buffered by the consumer's grid pipeline.

This file is the analogue of the paper's HLS-C++ code generation (§VII-C);
functional equivalence against the un-optimized program is checked the
same way the paper's testbench does — by executing both and comparing.

Lowering results are memoized like compiles: keyed on the compiled graph's
``structural_hash()`` — which covers the fusion decisions (buffer impls,
fused-group ids) — plus the lowering flags and the kernel-registry epoch.
Re-lowering a structurally identical design (e.g. a disk-cache hit in a
fresh ``CompiledDataflow``) reuses the already-built (and, under jit, the
already-traced) program.  The same content-addressing contract as the
compile cache applies: graphs with equal structural hashes must have equal
numerics (automatic for spec-carrying tasks, the ``const:`` tag convention
for closure-built ones).
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from .compiler import CompiledDataflow
from .graph import FIFO, DataflowGraph, GraphError, Task
from .ops import registry_epoch as _ops_epoch

# Registry: op-pattern -> kernel factory.  kernels/__init__.py populates
# this with Pallas implementations ("streamfuse" etc.); the generic path
# composes the tasks' jnp fns and lets XLA fuse.
_KERNEL_REGISTRY: dict[tuple[str, ...], Callable[..., Callable]] = {}

# Epoch bumps on every kernel registration: memoized lowerings from before
# a registration must not serve afterwards (the group->kernel routing
# could differ).
_REGISTRY_EPOCH = 0


def register_group_kernel(pattern: tuple[str, ...],
                          factory: Callable[..., Callable]) -> None:
    global _REGISTRY_EPOCH
    _KERNEL_REGISTRY[pattern] = factory
    _REGISTRY_EPOCH += 1


@dataclass
class FusionGroup:
    gid: int
    tasks: list[str]
    ops: tuple[str, ...]
    kernel: str = "xla-fused"     # or the registered Pallas kernel name


@dataclass
class LoweredProgram:
    graph: DataflowGraph
    groups: list[FusionGroup]
    fn: Callable[[dict], dict]          # jitted: env(inputs+weights) -> outputs
    materialized: list[str] = field(default_factory=list)   # HBM intermediates

    def __call__(self, env: dict[str, Any]) -> dict[str, Any]:
        return self.fn(env)

    def summary(self) -> str:
        return (f"lowered {self.graph.name}: {len(self.groups)} fusion groups "
                f"({sum(len(g.tasks) for g in self.groups)} tasks), "
                f"{len(self.materialized)} HBM intermediates")


def fusion_groups(graph: DataflowGraph, impl: dict[str, str]) -> list[FusionGroup]:
    """Union tasks across FIFO edges (single-producer-single-consumer by
    construction after the coarse pass)."""
    parent: dict[str, str] = {t.name: t.name for t in graph.tasks}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: str, b: str) -> None:
        parent[find(a)] = find(b)

    for p, buf, c in graph.internal_edges():
        if impl.get(buf) == FIFO:
            union(p.name, c.name)

    order = [t.name for t in graph.toposort()]
    by_root: dict[str, list[str]] = {}
    for n in order:
        by_root.setdefault(find(n), []).append(n)
    groups = []
    for gid, (_root, names) in enumerate(
            sorted(by_root.items(), key=lambda kv: order.index(kv[1][0]))):
        ops = tuple(graph.task(n).op for n in names)
        g = FusionGroup(gid, names, ops)
        if ops in _KERNEL_REGISTRY:
            g.kernel = "+".join(ops)
        for n in names:
            graph.task(n).fused_group = gid
        groups.append(g)
    return groups


# Memoized lowerings: structural key -> LoweredProgram (LRU).
_LOWER_CACHE: OrderedDict[tuple, LoweredProgram] = OrderedDict()
_LOWER_LOCK = threading.Lock()
LOWER_CACHE_STATS = {"hits": 0, "misses": 0}


def _lower_cache_size() -> int:
    return max(1, int(os.environ.get("CODO_LOWER_CACHE_SIZE", "64")))


def clear_lower_cache() -> None:
    with _LOWER_LOCK:
        _LOWER_CACHE.clear()
        LOWER_CACHE_STATS.update(hits=0, misses=0)


def lower(compiled: CompiledDataflow, jit: bool = True,
          use_registered_kernels: bool = True, *,
          memo: bool = True) -> LoweredProgram:
    graph = compiled.graph
    stripped = [t.name for t in graph.tasks if t.fn is None]
    if stripped:
        raise GraphError(
            f"cannot lower {graph.name}: {len(stripped)} tasks have no numeric "
            f"semantics (e.g. {stripped[0]!r}). These tasks were built from "
            "raw closures (not picklable), so their disk compile-cache entry "
            "is structural-only; build graphs with declarative OpSpecs "
            "(repro.core.ops) for executable cache entries, or recompile "
            "with an in-memory cache / cache=None before lowering.")
    # Key covers fusion decisions (via the structural hash), both kernel
    # registries (group kernels AND op impls — re-registering either must
    # not serve programs built from the old implementations), and flags.
    key = (graph.structural_hash(), bool(jit), bool(use_registered_kernels),
           _REGISTRY_EPOCH, _ops_epoch())
    if memo:
        with _LOWER_LOCK:
            hit = _LOWER_CACHE.get(key)
            if hit is not None:
                _LOWER_CACHE.move_to_end(key)
                LOWER_CACHE_STATS["hits"] += 1
        if hit is not None:
            # Mirror the cached fusion decisions onto the caller's graph so
            # post-lowering introspection (fused_group ids) behaves as if
            # the lowering had run, then share the built program.
            for g in hit.groups:
                for n in g.tasks:
                    graph.task(n).fused_group = g.gid
            return LoweredProgram(graph, hit.groups, hit.fn,
                                  list(hit.materialized))
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    groups = fusion_groups(graph, impl)

    # Execution follows the global topo order (fusion groups may interleave
    # through ping-pong edges of *other* groups); a group is executed as a
    # registered fused kernel only when its tasks are topologically
    # contiguous, otherwise task-by-task (XLA still fuses under jit).
    order = graph.toposort()
    topo_pos = {t.name: i for i, t in enumerate(order)}
    steps: list[Callable[[dict], dict]] = []
    emitted: set[str] = set()
    for t in order:
        if t.name in emitted:
            continue
        g = groups[t.fused_group]
        contiguous = (sorted(topo_pos[n] for n in g.tasks)
                      == list(range(topo_pos[g.tasks[0]],
                                    topo_pos[g.tasks[0]] + len(g.tasks))))
        if (use_registered_kernels and g.ops in _KERNEL_REGISTRY
                and t.name == g.tasks[0] and contiguous):
            steps.append(_KERNEL_REGISTRY[g.ops](graph, g))
            emitted.update(g.tasks)
        else:
            steps.append(t.fn)
            emitted.add(t.name)

    outputs = [b.name for b in graph.outputs()]
    materialized = [b.name for b in graph.intermediates()
                    if impl.get(b.name) == "pingpong"]

    def program(env: dict) -> dict:
        scope = dict(env)
        for f in steps:
            scope.update(f(scope))
        return {k: scope[k] for k in outputs}

    fn = jax.jit(program) if jit else program
    out = LoweredProgram(graph, groups, fn, materialized)
    if memo:
        with _LOWER_LOCK:
            LOWER_CACHE_STATS["misses"] += 1
            _LOWER_CACHE[key] = out
            _LOWER_CACHE.move_to_end(key)
            while len(_LOWER_CACHE) > _lower_cache_size():
                _LOWER_CACHE.popitem(last=False)
    return out


def lower_artifact(source, *, jit: bool = True,
                   use_registered_kernels: bool = True, memo: bool = True,
                   check_integrity: bool = True) -> LoweredProgram:
    """One-step path from an exported JSON artifact (a file path or parsed
    document — see docs/artifact_format.md) to an executable program:
    ``import_artifact`` + :func:`lower`.  The artifact must have been
    exported from a spec-carrying design; op kinds resolve against this
    process's registry."""
    from .artifact import import_artifact  # lazy: artifact stays jax-free
    return lower(import_artifact(source, check_integrity=check_integrity),
                 jit=jit, use_registered_kernels=use_registered_kernels,
                 memo=memo)


def oracle_outputs(source_graph: DataflowGraph, env: dict) -> dict:
    """Run the *un-optimized* program — the golden reference the paper's
    auto-generated testbench compares against (§VII-C)."""
    return source_graph.execute(env)


def verify_lowering(source_graph: DataflowGraph, compiled: CompiledDataflow,
                    env: dict, rtol: float = 1e-5, atol: float = 1e-5) -> None:
    got = lower(compiled, jit=False)(env)
    want = oracle_outputs(source_graph, env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"output {k} diverged after lowering")
