"""Lowering: a :class:`CompiledDataflow` → an executable JAX callable.

FIFO edges become *fusion groups*: maximal chains of FIFO-connected tasks
are executed as one fused function whose intermediates never round-trip
through HBM.  Which implementation runs a group is a *routing* decision
(:mod:`repro.core.routing`): producer→consumer chains matching a
registered :class:`~repro.core.routing.KernelPattern` — the motivating
pad→conv→relu chain, matmul→\\*ewise→matmul chains, softmax·matmul
attention tails — execute as hand-written Pallas streaming kernels; the
rest composes the tasks' jnp fns and lets XLA fuse inside jit.  Ping-pong
edges are group boundaries — the intermediate materializes in HBM,
double-buffered by the consumer's grid pipeline.  ``CODO_DISABLE_PALLAS=1``
turns all routing off.

This file is the analogue of the paper's HLS-C++ code generation (§VII-C);
functional equivalence against the un-optimized program is checked the
same way the paper's testbench does — by executing both and comparing.

Lowering results are memoized like compiles: keyed on the compiled graph's
``structural_hash()`` — which covers the fusion decisions (buffer impls,
fused-group ids) — plus the lowering flags, the routing switches
(``CODO_DISABLE_PALLAS`` and the kernel-pattern registry epoch), and the
op-registry epoch.  Re-lowering a structurally identical design (e.g. a
disk-cache hit in a fresh ``CompiledDataflow``) reuses the already-built
(and, under jit, the already-traced) program; flipping any routing switch
changes the key, so a toggle never serves a stale program.  The same
content-addressing contract as the compile cache applies: graphs with
equal structural hashes must have equal numerics (automatic for
spec-carrying tasks, the ``const:`` tag convention for closure-built ones).
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from .compiler import CompiledDataflow
from .graph import FIFO, DataflowGraph, GraphError, Task
from .ops import registry_epoch as _ops_epoch
from .routing import (XLA_FUSED, KernelPattern, RoutedKernel,
                      ensure_kernel_patterns, pallas_interpret_forced,
                      register_kernel_pattern, route_groups,
                      routing_state_key)

def register_group_kernel(pattern: tuple[str, ...],
                          factory: Callable[..., Callable]) -> None:
    """Legacy exact-op registration (pre-routing API): ``pattern`` is the
    full op tuple of a group and ``factory(graph, group)`` builds the
    step.  Kept as a shim over :func:`repro.core.routing.
    register_kernel_pattern`; new kernels should register a
    :class:`~repro.core.routing.KernelPattern` directly."""
    def adapter(graph, group, tasks):
        # Old factories index group.tasks positionally, assuming the match
        # covers the whole group; hand them a group view of just the chain.
        chain = FusionGroup(group.gid, [t.name for t in tasks],
                            tuple(t.op for t in tasks))
        return factory(graph, chain)

    register_kernel_pattern(KernelPattern(
        name="+".join(pattern), pattern=tuple(pattern), factory=adapter,
        description="legacy exact-op registration"))


@dataclass
class FusionGroup:
    gid: int
    tasks: list[str]
    ops: tuple[str, ...]
    kernel: str = XLA_FUSED       # or "pallas:<pattern>[+<pattern>...]"
    routes: list[RoutedKernel] = field(default_factory=list)
    # Cost-gate record (ISSUE 6): structural matches the gate turned down,
    # the group-level decision, and predicted cycles both ways.
    rejected: list[RoutedKernel] = field(default_factory=list)
    decision: str = "generic"     # "routed" | "generic" | "disabled"
    predicted_routed_cycles: float = 0.0
    predicted_generic_cycles: float = 0.0


@dataclass
class LoweredProgram:
    graph: DataflowGraph
    groups: list[FusionGroup]
    fn: Callable[[dict], dict]          # jitted: env(inputs+weights) -> outputs
    materialized: list[str] = field(default_factory=list)   # HBM intermediates

    def __call__(self, env: dict[str, Any]) -> dict[str, Any]:
        return self.fn(env)

    @property
    def routed_groups(self) -> list[FusionGroup]:
        return [g for g in self.groups if g.routes]

    def summary(self) -> str:
        return (f"lowered {self.graph.name}: {len(self.groups)} fusion groups "
                f"({sum(len(g.tasks) for g in self.groups)} tasks, "
                f"{len(self.routed_groups)} pallas-routed), "
                f"{len(self.materialized)} HBM intermediates")


def fusion_groups(graph: DataflowGraph, impl: dict[str, str]) -> list[FusionGroup]:
    """Union tasks across FIFO edges (single-producer-single-consumer by
    construction after the coarse pass).  Routing (which kernel runs each
    group) is a separate decision — see :func:`repro.core.routing.
    route_groups`."""
    parent: dict[str, str] = {t.name: t.name for t in graph.tasks}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: str, b: str) -> None:
        parent[find(a)] = find(b)

    for p, buf, c in graph.internal_edges():
        if impl.get(buf) == FIFO:
            union(p.name, c.name)

    order = [t.name for t in graph.toposort()]
    by_root: dict[str, list[str]] = {}
    for n in order:
        by_root.setdefault(find(n), []).append(n)
    groups = []
    for gid, (_root, names) in enumerate(
            sorted(by_root.items(), key=lambda kv: order.index(kv[1][0]))):
        ops = tuple(graph.task(n).op for n in names)
        groups.append(FusionGroup(gid, names, ops))
        for n in names:
            graph.task(n).fused_group = gid
    return groups


# Memoized lowerings: structural key -> LoweredProgram (LRU).
_LOWER_CACHE: OrderedDict[tuple, LoweredProgram] = OrderedDict()
_LOWER_LOCK = threading.Lock()
LOWER_CACHE_STATS = {"hits": 0, "misses": 0}


def _lower_cache_size() -> int:
    return max(1, int(os.environ.get("CODO_LOWER_CACHE_SIZE", "64")))


def clear_lower_cache() -> None:
    with _LOWER_LOCK:
        _LOWER_CACHE.clear()
        LOWER_CACHE_STATS.update(hits=0, misses=0)


def _build_steps(graph: DataflowGraph, groups: list[FusionGroup],
                 use_registered_kernels: bool) -> list[Callable[[dict], dict]]:
    """The executable step list: routed chains become one kernel step
    emitted at the chain's *last* task position (every external operand of
    every matched task is in scope by then); everything else runs task by
    task (XLA still fuses under jit)."""
    step_at: dict[str, Callable[[dict], dict]] = {}
    skip: set[str] = set()
    if use_registered_kernels:
        from .routing import registered_patterns
        pats = {p.name: p for p in registered_patterns()}
        for g in groups:
            built: list[RoutedKernel] = []
            for route in g.routes:
                pat = pats.get(route.kernel)
                tasks = [graph.task(n) for n in route.tasks]
                if pat is None:
                    step = None
                elif route.tile is not None:    # tuned blocking wins
                    step = pat.factory(graph, g, tasks, tile=route.tile)
                else:
                    step = pat.factory(graph, g, tasks)
                if step is None:        # factory declined at build time
                    continue
                built.append(route)
                step_at[route.tasks[-1]] = step
                skip.update(route.tasks[:-1])
            if len(built) != len(g.routes):
                for r in g.routes:
                    if r not in built:
                        r.decision = "declined"     # factory said no
                        g.rejected.append(r)
                g.routes = built
                g.kernel = ("pallas:" + "+".join(r.kernel for r in built)
                            if built else XLA_FUSED)
                g.decision = "routed" if built else "generic"
    else:
        for g in groups:
            g.routes, g.rejected = [], []
            g.kernel = XLA_FUSED
            g.decision = "generic"

    steps: list[tuple[str, Callable[[dict], dict]]] = []
    for t in graph.toposort():
        if t.name in skip:
            continue
        steps.append((t.name, step_at.get(t.name, t.fn)))
    return steps


def _drop_sharded_routes(groups: list[FusionGroup], sharding) -> None:
    """Un-route any chain a collective lands inside.  A routed kernel step
    runs at the chain's *last* task with the interiors skipped, so the
    only anchor the sharded executor can honor is "after the last task"
    (the psum on a row-parallel matmul's output).  A gather before any
    chain task, or a reduction after an interior, would silently never
    run — fall back to the generic per-task path for that chain."""
    from repro.distributed import collectives as _coll
    before, after = _coll.attach(sharding.steps)
    for g in groups:
        kept = []
        for r in g.routes:
            bad = any(t in before for t in r.tasks) or \
                any(t in after for t in r.tasks[:-1])
            if bad:
                r.decision = "sharded"      # collective inside the chain
                g.rejected.append(r)
            else:
                kept.append(r)
        if len(kept) != len(g.routes):
            g.routes = kept
            g.kernel = ("pallas:" + "+".join(r.kernel for r in kept)
                        if kept else XLA_FUSED)
            g.decision = "routed" if kept else "generic"


def _sharded_program(graph: DataflowGraph, steps, outputs: list[str],
                     sharding) -> Callable[[dict], dict]:
    """Wrap the step list in ``shard_map`` over the plan's mesh.

    Inside the body every env entry is the *local shard* its
    :class:`ShardSpec` dictates; the plan's collective schedule rewrites
    scope values before the consumer that needs the full buffer
    (all_gather) and after the producer that left partial sums
    (psum / reduce_scatter+all_gather / ppermute ring)."""
    from repro.distributed import collectives as _coll
    from repro.distributed.sharding import shard_map
    from repro.launch.mesh import mesh_from_spec

    before, after = _coll.attach(sharding.steps)
    emitted = {name for name, _f in steps}
    missing = [s.task for s in sharding.steps if s.task not in emitted]
    if missing:        # _drop_sharded_routes guarantees this never fires
        raise GraphError(
            f"collective anchored on skipped task(s) {missing}")
    fns = {id(s): _coll.make_collective(s, sharding.mesh)
           for s in sharding.steps}
    in_specs, out_specs = _coll.env_partition_specs(graph, sharding)
    mesh = mesh_from_spec(sharding.mesh)

    def body(env: dict) -> dict:
        scope = dict(env)
        for name, f in steps:
            for s in before.get(name, ()):
                scope[s.buffer] = fns[id(s)](scope[s.buffer])
            scope.update(f(scope))
            for s in after.get(name, ()):
                scope[s.buffer] = fns[id(s)](scope[s.buffer])
        return {k: scope[k] for k in outputs}

    mapped = shard_map(body, mesh=mesh, in_specs=(in_specs,),
                       out_specs=out_specs, check_vma=False)

    def program(env: dict) -> dict:
        extra = set(env) - set(in_specs)
        if extra:
            raise GraphError(f"sharded program got unexpected env keys "
                             f"{sorted(extra)}")
        return mapped(dict(env))

    return program


def lower(compiled: CompiledDataflow, jit: bool = True,
          use_registered_kernels: bool = True, *,
          memo: bool = True, sharding=None) -> LoweredProgram:
    # The compiler — not the user — wires the Pallas kernels in.
    ensure_kernel_patterns()
    graph = compiled.graph
    stripped = [t.name for t in graph.tasks if t.fn is None]
    if stripped:
        raise GraphError(
            f"cannot lower {graph.name}: {len(stripped)} tasks have no numeric "
            f"semantics (e.g. {stripped[0]!r}). These tasks were built from "
            "raw closures (not picklable), so their disk compile-cache entry "
            "is structural-only; build graphs with declarative OpSpecs "
            "(repro.core.ops) for executable cache entries, or recompile "
            "with an in-memory cache / cache=None before lowering.")
    # Key covers fusion decisions (via the structural hash), the flags, and
    # every routing-relevant switch (routing_state_key: the disable/force
    # escape hatches, the registry epoch, the priced backend, the
    # calibration digest, and the tuning-DB digest) plus the op-impl
    # registry epoch — flipping any of them must never serve a stale
    # program.
    key = (graph.structural_hash(), bool(jit), bool(use_registered_kernels),
           pallas_interpret_forced(), *routing_state_key(), _ops_epoch(),
           sharding.digest() if sharding is not None else "")
    if memo:
        with _LOWER_LOCK:
            hit = _LOWER_CACHE.get(key)
            if hit is not None:
                _LOWER_CACHE.move_to_end(key)
                LOWER_CACHE_STATS["hits"] += 1
        if hit is not None:
            # Mirror the cached fusion decisions onto the caller's graph so
            # post-lowering introspection (fused_group ids) behaves as if
            # the lowering had run, then share the built program.
            for g in hit.groups:
                for n in g.tasks:
                    graph.task(n).fused_group = g.gid
            _record_routing(compiled, hit.groups)
            return LoweredProgram(graph, hit.groups, hit.fn,
                                  list(hit.materialized))
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    groups = fusion_groups(graph, impl)
    if use_registered_kernels:
        route_groups(graph, groups, impl, hw=compiled.options.hw)
    if sharding is not None:
        _drop_sharded_routes(groups, sharding)
    steps = _build_steps(graph, groups, use_registered_kernels)

    outputs = [b.name for b in graph.outputs()]
    # Interior buffers of routed chains never leave the kernel — even the
    # ping-pong-planned ones the generic path would bounce through HBM.
    swallowed = {graph.task(n).writes[0].buffer
                 for g in groups for r in g.routes for n in r.tasks[:-1]}
    materialized = [b.name for b in graph.intermediates()
                    if impl.get(b.name) == "pingpong"
                    and b.name not in swallowed]

    if sharding is None:
        def program(env: dict) -> dict:
            scope = dict(env)
            for _name, f in steps:
                scope.update(f(scope))
            return {k: scope[k] for k in outputs}
    else:
        program = _sharded_program(graph, steps, outputs, sharding)

    fn = jax.jit(program) if jit else program
    out = LoweredProgram(graph, groups, fn, materialized)
    _record_routing(compiled, groups)
    if memo:
        with _LOWER_LOCK:
            LOWER_CACHE_STATS["misses"] += 1
            _LOWER_CACHE[key] = out
            _LOWER_CACHE.move_to_end(key)
            while len(_LOWER_CACHE) > _lower_cache_size():
                _LOWER_CACHE.popitem(last=False)
    return out


def _record_routing(compiled: CompiledDataflow,
                    groups: list[FusionGroup]) -> None:
    """Surface the routing decision on the design's diagnostics so it
    travels with reports, ``--profile`` tables, and exported artifacts.
    Every entry records the cost gate's verdict and the predicted cycles
    both ways (ISSUE 6), not just the winning kernel name."""
    if compiled.diagnostics is not None:
        compiled.diagnostics.group_kernels = {
            str(g.gid): {
                "kernel": g.kernel,
                "decision": g.decision,
                "predicted_routed_cycles": round(
                    g.predicted_routed_cycles, 1),
                "predicted_generic_cycles": round(
                    g.predicted_generic_cycles, 1),
                "routes": [r.to_dict() for r in g.routes],
                "rejected": [r.to_dict() for r in g.rejected],
            } for g in groups}


def lower_artifact(source, *, jit: bool = True,
                   use_registered_kernels: bool = True, memo: bool = True,
                   check_integrity: bool = True) -> LoweredProgram:
    """One-step path from an exported JSON artifact (a file path or parsed
    document — see docs/artifact_format.md) to an executable program:
    ``import_artifact`` + :func:`lower`.  The artifact must have been
    exported from a spec-carrying design; op kinds resolve against this
    process's registry."""
    from .artifact import import_artifact  # lazy: artifact stays jax-free
    return lower(import_artifact(source, check_integrity=check_integrity),
                 jit=jit, use_registered_kernels=use_registered_kernels,
                 memo=memo)


def oracle_outputs(source_graph: DataflowGraph, env: dict) -> dict:
    """Run the *un-optimized* program — the golden reference the paper's
    auto-generated testbench compares against (§VII-C)."""
    return source_graph.execute(env)


def verify_lowering(source_graph: DataflowGraph, compiled: CompiledDataflow,
                    env: dict, rtol: float = 1e-5, atol: float = 1e-5,
                    sharding=None) -> None:
    """Lowered outputs must match the un-optimized oracle.  With a
    ``sharding`` plan the multi-device lowering is checked instead; the
    default tolerances absorb the one reassociation a psum introduces
    (a tree-reduce over device partials vs the serial contraction —
    everything collective-free stays bit-identical)."""
    got = lower(compiled, jit=False, sharding=sharding)(env)
    want = oracle_outputs(source_graph, env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=rtol, atol=atol,
                                   err_msg=f"output {k} diverged after lowering")


def verify_sharding(compiled: CompiledDataflow, sharding, env: dict,
                    rtol: float = 1e-4, atol: float = 5e-5) -> None:
    """Assert the sharded lowering matches the single-device lowering on
    ``env`` within documented fp tolerance.  Two reassociations are
    expected and bounded, nothing else may differ: a psum tree-reduces
    device partials where the serial contraction sums in order, and even
    gather-only plans run matmuls at *local* shapes, where XLA may pick a
    different (equally valid) contraction order.  Defaults hold the GPT-2
    block to ~1e-5 on CPU; genuine sharding bugs (wrong shard, missing
    collective) produce O(1) errors, orders of magnitude past the gate."""
    single = lower(compiled, jit=False)(env)
    shard = lower(compiled, jit=False, sharding=sharding)(env)
    for k in single:
        np.testing.assert_allclose(
            np.asarray(shard[k]), np.asarray(single[k]), rtol=rtol,
            atol=atol,
            err_msg=f"output {k}: sharded lowering diverged beyond the "
                    f"fp-reassociation tolerance")


def verify_routing(compiled: CompiledDataflow, env: dict,
                   rtol: float = 1e-5, atol: float = 1e-5) -> LoweredProgram:
    """Assert the pattern-routed lowering matches the un-routed generic
    lowering on ``env`` — the same executable-comparison check
    :func:`verify_lowering` performs against the oracle, aimed at the
    routing layer specifically.  Returns the routed program."""
    generic = lower(compiled, jit=False, use_registered_kernels=False)
    routed = lower(compiled, jit=False, use_registered_kernels=True)
    got, want = routed(env), generic(env)
    for k in want:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(want[k]), rtol=rtol, atol=atol,
            err_msg=f"output {k}: pattern-routed kernel diverged from the "
                    f"xla-fused lowering")
    return routed
