"""Content-addressed compile cache for ``codo_opt``.

The key is ``DataflowGraph.structural_hash()`` (everything the passes read,
minus numeric closures) combined with ``CodoOptions.cache_key()``, so two
independent builds of the same model under the same options hit the same
entry — including across processes when a disk directory is configured.

Two tiers:

* **in-memory LRU** — stores full :class:`CompiledDataflow` results
  (numeric ``fn`` closures included, so lowering/verification still work on
  hits).  Both ``put`` and ``get`` clone the graph, so callers can mutate
  results (e.g. lowering assigns fusion groups) without corrupting the
  cache.
* **on-disk pickle** (optional) — survives process restarts; this is what
  makes a second ``python -m repro.core.compiler`` invocation near-free.
  Closures aren't picklable, so disk entries store a *structural* result
  (``Task.fn`` stripped).  Every pass decision, report, latency estimate
  and ``verify_violation_free`` check works on such a result; only numeric
  re-execution (``lower``/``execute``) needs a fresh compile.

Knobs: ``CODO_CACHE_SIZE`` (LRU entries, default 256) and
``CODO_CACHE_DIR`` (enables the disk tier) — read by
:func:`repro.core.compiler.default_cache`.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import threading
from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path
from typing import Any


@dataclass
class CacheStats:
    hits: int = 0            # in-memory hits
    disk_hits: int = 0
    misses: int = 0
    stores: int = 0
    evictions: int = 0
    disk_errors: int = 0

    def summary(self) -> str:
        return (f"cache: {self.hits} hits, {self.disk_hits} disk hits, "
                f"{self.misses} misses, {self.stores} stores, "
                f"{self.evictions} evictions")


def _clone(compiled: Any, *, strip_fns: bool = False) -> Any:
    """Defensive copy of a CompiledDataflow: fresh graph and buffer plan
    (``downgrade_to_pingpong`` mutates plans post-compile), plus no closures
    for the disk tier.  The remaining reports are shared — nothing mutates
    them after compilation."""
    g = compiled.graph.copy()
    if strip_fns:
        for t in g.tasks:
            t.fn = None
    bp = compiled.buffer_plan
    if bp is not None:
        bp = dataclasses.replace(bp, impl=dict(bp.impl),
                                 fifo_depth=dict(bp.fifo_depth),
                                 reasons=dict(bp.reasons))
    return dataclasses.replace(compiled, graph=g, buffer_plan=bp)


class CompileCache:
    """Thread-safe LRU of compile results, with an optional pickle tier."""

    def __init__(self, maxsize: int = 256, disk_dir: str | Path | None = None):
        self.maxsize = max(1, int(maxsize))
        self.disk_dir = Path(disk_dir) if disk_dir else None
        self.stats = CacheStats()
        self._mem: OrderedDict[str, Any] = OrderedDict()
        self._lock = threading.RLock()

    # ---- keying ----------------------------------------------------------
    @staticmethod
    def key(graph: Any, options: Any) -> str:
        return f"{graph.structural_hash()}-{options.cache_key()[:16]}"

    # ---- lookup ----------------------------------------------------------
    def _disk_path(self, key: str) -> Path | None:
        return self.disk_dir / f"{key}.pkl" if self.disk_dir else None

    def get(self, key: str) -> Any | None:
        # Clone and unpickle outside the lock: entries are immutable once
        # inserted (both put and get hand out clones), so a bare reference
        # is safe to copy concurrently.
        with self._lock:
            entry = self._mem.get(key)
            if entry is not None:
                self._mem.move_to_end(key)
                self.stats.hits += 1
        if entry is not None:
            return self._mark_hit(_clone(entry))
        path = self._disk_path(key)
        if path is not None and path.exists():
            try:
                entry = pickle.loads(path.read_bytes())
            except Exception:
                with self._lock:
                    self.stats.disk_errors += 1
            else:
                # Deliberately NOT promoted into the memory tier: disk
                # entries are fn-stripped, and the memory tier promises
                # full results (closures included).
                with self._lock:
                    self.stats.disk_hits += 1
                return self._mark_hit(_clone(entry))
        with self._lock:
            self.stats.misses += 1
        return None

    @staticmethod
    def _mark_hit(compiled: Any) -> Any:
        diag = getattr(compiled, "diagnostics", None)
        if diag is not None:
            compiled.diagnostics = dataclasses.replace(diag, cache_hit=True)
        return compiled

    # ---- store -----------------------------------------------------------
    def _insert(self, key: str, entry: Any) -> None:
        self._mem[key] = entry
        self._mem.move_to_end(key)
        while len(self._mem) > self.maxsize:
            self._mem.popitem(last=False)
            self.stats.evictions += 1

    def put(self, key: str, compiled: Any) -> None:
        # Graph copies and pickling happen before taking the lock so a
        # batch-compile thread pool doesn't serialize on the cache.
        entry = _clone(compiled)
        blob = None
        path = self._disk_path(key)
        if path is not None:
            try:
                blob = pickle.dumps(_clone(compiled, strip_fns=True))
            except Exception:
                # Unpicklable report: the memory tier still works, so
                # degrade silently but count it.
                blob = None
                with self._lock:
                    self.stats.disk_errors += 1
        with self._lock:
            self._insert(key, entry)
            self.stats.stores += 1
        if blob is not None:
            try:
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(f".{os.getpid()}.tmp")
                tmp.write_bytes(blob)
                tmp.replace(path)
            except Exception:
                with self._lock:
                    self.stats.disk_errors += 1

    # ---- maintenance -----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def clear(self, *, disk: bool = False) -> None:
        with self._lock:
            self._mem.clear()
            if disk and self.disk_dir is not None and self.disk_dir.exists():
                for p in self.disk_dir.glob("*.pkl"):
                    p.unlink(missing_ok=True)


__all__ = ["CacheStats", "CompileCache"]
