"""Content-addressed compile cache for ``codo_opt``.

The key is ``DataflowGraph.structural_hash()`` (everything the passes read,
minus numeric closures) combined with ``CodoOptions.cache_key()``, so two
independent builds of the same model under the same options hit the same
entry — including across processes when a disk directory is configured.

Two tiers:

* **in-memory LRU** — stores full :class:`CompiledDataflow` results
  (closure overrides included, so lowering/verification work on hits even
  for ad-hoc closure-built graphs).  Both ``put`` and ``get`` clone the
  graph, so callers can mutate results (e.g. lowering assigns fusion
  groups) without corrupting the cache.
* **on-disk pickle** (optional) — survives process restarts; this is what
  makes a second ``python -m repro.core.compiler`` invocation near-free.
  Tasks carry declarative :class:`~repro.core.ops.OpSpec` semantics —
  plain data — so disk entries are *fully executable* after reload:
  a cold-restart hit lowers, executes and passes ``verify_lowering``
  without recompiling.  Only raw closure overrides (not picklable) are
  stripped at this boundary; a reloaded closure-built task falls back to
  a structural result (costing/reports/``verify_violation_free`` still
  work, lowering raises).  Executable disk hits are promoted into the
  memory tier; stripped ones are not.

With ``json_mirror`` (or ``CODO_CACHE_JSON=1``) every disk store also
writes the entry's versioned JSON artifact (``<key>.json``, the
``docs/artifact_format.md`` format) next to the pickle, so the disk tier
is *inspectable*: ``python -m repro.core.compiler --import-artifact
<entry>.json`` — or any non-Python consumer — can read exactly what was
cached.  Mirroring is best-effort; closure-built entries (which cannot
serialize) are skipped silently.

Knobs: ``CODO_CACHE_SIZE`` (LRU entries, default 256), ``CODO_CACHE_DIR``
(enables the disk tier) and ``CODO_CACHE_JSON`` (JSON mirror) — read by
:func:`repro.core.compiler.default_cache`.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import re
import threading
from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path
from typing import Any

# Disk-entry file stem — what :meth:`CompileCache.key` produces:
# "<sha256 graph hash>-<16-hex options key>".  clear() only touches JSON
# files with this shape so user artifacts sharing the directory survive.
_KEY_RE = re.compile(r"^[0-9a-f]{64}-[0-9a-f]{16}$")


@dataclass
class CacheStats:
    hits: int = 0            # in-memory hits
    disk_hits: int = 0
    misses: int = 0
    stores: int = 0
    evictions: int = 0
    disk_errors: int = 0
    promotions: int = 0      # executable disk hits promoted to memory
    json_mirrors: int = 0    # artifact JSONs written next to pickles

    def summary(self) -> str:
        return (f"cache: {self.hits} hits, {self.disk_hits} disk hits, "
                f"{self.misses} misses, {self.stores} stores, "
                f"{self.evictions} evictions")


def _executable(compiled: Any) -> bool:
    """True when every task can produce a numeric fn (spec or closure) —
    i.e. the result can be lowered and executed as-is.  A stale entry
    whose spec kind is no longer registered counts as non-executable
    rather than raising."""
    try:
        return all(t.fn is not None for t in compiled.graph.tasks)
    except Exception:
        return False


def _clone(compiled: Any, *, strip_closures: bool = False) -> Any:
    """Defensive copy of a CompiledDataflow: fresh graph and buffer plan
    (``downgrade_to_pingpong`` mutates plans post-compile), with closure
    overrides dropped for pickle boundaries (``strip_closures`` — specs,
    being plain data, always survive).  The remaining reports are shared —
    nothing mutates them after compilation."""
    g = compiled.graph.copy()
    if strip_closures:
        for t in g.tasks:
            if t.fn_is_closure:
                t.fn = None
    bp = compiled.buffer_plan
    if bp is not None:
        bp = dataclasses.replace(bp, impl=dict(bp.impl),
                                 fifo_depth=dict(bp.fifo_depth),
                                 reasons=dict(bp.reasons))
    return dataclasses.replace(compiled, graph=g, buffer_plan=bp)


class CompileCache:
    """Thread-safe LRU of compile results, with an optional pickle tier."""

    def __init__(self, maxsize: int = 256, disk_dir: str | Path | None = None,
                 json_mirror: bool | None = None):
        self.maxsize = max(1, int(maxsize))
        self.disk_dir = Path(disk_dir) if disk_dir else None
        if json_mirror is None:
            json_mirror = os.environ.get("CODO_CACHE_JSON", "") \
                .lower() in ("1", "true", "yes")
        self.json_mirror = bool(json_mirror)
        self.stats = CacheStats()
        self._mem: OrderedDict[str, Any] = OrderedDict()
        self._lock = threading.RLock()

    # ---- keying ----------------------------------------------------------
    @staticmethod
    def key(graph: Any, options: Any) -> str:
        return f"{graph.structural_hash()}-{options.cache_key()[:16]}"

    # ---- lookup ----------------------------------------------------------
    def _disk_path(self, key: str) -> Path | None:
        return self.disk_dir / f"{key}.pkl" if self.disk_dir else None

    def get(self, key: str) -> Any | None:
        # Clone and unpickle outside the lock: entries are immutable once
        # inserted (both put and get hand out clones), so a bare reference
        # is safe to copy concurrently.
        with self._lock:
            entry = self._mem.get(key)
            if entry is not None:
                self._mem.move_to_end(key)
                self.stats.hits += 1
        if entry is not None:
            return self._mark_hit(_clone(entry))
        path = self._disk_path(key)
        if path is not None and path.exists():
            try:
                entry = pickle.loads(path.read_bytes())
            except Exception:
                with self._lock:
                    self.stats.disk_errors += 1
            else:
                # Declarative entries are fully executable after reload and
                # earn promotion into the memory tier.  Closure-built
                # entries came back stripped; promoting those would poison
                # the memory tier's promise of full results.
                with self._lock:
                    self.stats.disk_hits += 1
                    if _executable(entry):
                        self._insert(key, entry)
                        self.stats.promotions += 1
                return self._mark_hit(_clone(entry))
        with self._lock:
            self.stats.misses += 1
        return None

    @staticmethod
    def _mark_hit(compiled: Any) -> Any:
        diag = getattr(compiled, "diagnostics", None)
        if diag is not None:
            compiled.diagnostics = dataclasses.replace(diag, cache_hit=True)
        return compiled

    # ---- store -----------------------------------------------------------
    def _insert(self, key: str, entry: Any) -> None:
        self._mem[key] = entry
        self._mem.move_to_end(key)
        while len(self._mem) > self.maxsize:
            self._mem.popitem(last=False)
            self.stats.evictions += 1

    def put(self, key: str, compiled: Any) -> None:
        # Graph copies and pickling happen before taking the lock so a
        # batch-compile thread pool doesn't serialize on the cache.
        entry = _clone(compiled)
        blob = stripped = None
        path = self._disk_path(key)
        if path is not None:
            try:
                stripped = _clone(compiled, strip_closures=True)
                blob = pickle.dumps(stripped)
            except Exception:
                # Unpicklable report: the memory tier still works, so
                # degrade silently but count it.
                blob = None
                with self._lock:
                    self.stats.disk_errors += 1
        with self._lock:
            self._insert(key, entry)
            self.stats.stores += 1
        if blob is not None:
            try:
                path.parent.mkdir(parents=True, exist_ok=True)
                tmp = path.with_suffix(f".{os.getpid()}.tmp")
                tmp.write_bytes(blob)
                tmp.replace(path)
            except Exception:
                with self._lock:
                    self.stats.disk_errors += 1
            else:
                if self.json_mirror:
                    self._mirror_json(path, stripped)

    def _mirror_json(self, pkl_path: Path, stripped: Any) -> None:
        """Write the entry's versioned JSON artifact next to its pickle —
        the disk tier's inspectable form.  ``stripped`` is the
        closure-free clone already built for the pickle blob.
        Closure-built entries cannot serialize and are skipped (expected,
        not an error); anything else — I/O failures included — counts in
        ``stats.disk_errors`` like the pickle path."""
        from .artifact import ArtifactError, dumps, export_artifact  # lazy
        try:
            doc = export_artifact(stripped)
            jtmp = pkl_path.with_suffix(f".{os.getpid()}.json.tmp")
            jtmp.write_text(dumps(doc))
            jtmp.replace(pkl_path.with_suffix(".json"))
            with self._lock:
                self.stats.json_mirrors += 1
        except ArtifactError:
            pass                      # closure/spec-less entry: expected skip
        except Exception:
            with self._lock:
                self.stats.disk_errors += 1

    # ---- tuning-database tier (ISSUE 6) ----------------------------------
    @property
    def tuning_path(self) -> Path | None:
        """Where the disk tier keeps measured autotune results
        (``tuning.json``, a :class:`repro.core.tuning.TuningDB` document);
        ``None`` for memory-only caches."""
        return self.disk_dir / "tuning.json" if self.disk_dir else None

    def load_tuning_db(self, merge_into_default: bool = True) -> int:
        """Merge the disk tier's persisted tuning entries into the process
        tuning database (so cached measured routing decisions survive
        process restarts like cached compiles do).  Returns the number of
        entries merged; 0 when there is nothing to load."""
        path = self.tuning_path
        if path is None or not path.exists():
            return 0
        from .tuning import TuningDB, default_tuning_db
        try:
            loaded = TuningDB.load(path)
        except (OSError, ValueError, KeyError):
            with self._lock:
                self.stats.disk_errors += 1
            return 0
        if merge_into_default:
            return default_tuning_db().merge(loaded.entries.values())
        return len(loaded)

    def save_tuning_db(self, db=None) -> Path | None:
        """Persist ``db`` (the process default when ``None``) to the disk
        tier's ``tuning.json``.  No-op for memory-only caches."""
        path = self.tuning_path
        if path is None:
            return None
        from .tuning import default_tuning_db
        (db if db is not None else default_tuning_db()).save(path)
        return path

    # ---- maintenance -----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def clear(self, *, disk: bool = False) -> None:
        """Drop the memory tier and, with ``disk=True``, the cache's own
        disk files: pickles, their JSON mirrors (cache-key-named only —
        a user's hand-exported artifacts sharing the directory survive),
        and temp files orphaned by interrupted writes."""
        with self._lock:
            self._mem.clear()
            if disk and self.disk_dir is not None and self.disk_dir.exists():
                for p in self.disk_dir.glob("*.pkl"):
                    p.unlink(missing_ok=True)
                for p in self.disk_dir.glob("*.json"):
                    if _KEY_RE.match(p.stem):
                        p.unlink(missing_ok=True)
                for p in self.disk_dir.glob("*.tmp"):
                    p.unlink(missing_ok=True)


__all__ = ["CacheStats", "CompileCache"]
