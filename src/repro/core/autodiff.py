"""Graph-level autodiff: the backward pass as a second dataflow graph.

``build_backward`` walks a traced forward :class:`DataflowGraph` in
reverse topological order and, for every task, invokes the declarative
VJP rule registered for its ``OpSpec.kind`` (``core.ops.register_vjp``).
Rules emit plain spec'd tasks into a fresh graph through the builder
defined here — so the backward is a first-class dataflow graph: the same
PassManager presets (coarse/fine violation elimination, fusion,
cost-gated kernel routing) and the same compile cache apply to it
unchanged, which is the whole point — streaming reuse is typically worth
*more* in the backward, where every matmul spawns two transposed
re-reads of its forward operands.

``build_update`` ports the AdamW optimizer (``training/optimizer.py``'s
``clip_by_global_norm`` + ``adamw_update`` + ``lr_at`` arithmetic,
reproduced op-for-op) into registry ops (``sumsq``/``clip_scale``/
``lr_sched``/``adamw_step``) as a third graph, and
``build_train_graphs`` links all three: the forward copy re-marks the
backward's residual buffers as outputs so fwd/bwd share them through the
buffer/transfer planner instead of recomputing.

Everything here is jax-free at import time (rules and impls defer their
jax imports), matching the rest of ``repro.core``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from .frontend import GB
from .graph import Access, DataflowGraph, Loop, Task, full_index, idx
from .ops import OpSpec, UnknownOpError, vjp_rule

__all__ = ["AutodiffError", "BackwardBuild", "TrainGraphs", "DEFAULT_OPT",
           "build_backward", "build_train_graphs", "build_update",
           "opt_attrs"]


class AutodiffError(RuntimeError):
    """A graph (or one of its tasks) cannot be differentiated."""


# The exact field set of ``training.optimizer.OptConfig`` — duplicated as
# plain data so ``repro.core`` never imports the training package (which
# pulls jax at import time).
DEFAULT_OPT = {"lr": 3e-4, "b1": 0.9, "b2": 0.95, "eps": 1e-8,
               "weight_decay": 0.1, "clip_norm": 1.0, "warmup_steps": 100,
               "total_steps": 10000, "min_lr_frac": 0.1}


def opt_attrs(oc=None) -> dict:
    """Normalize an optimizer config (``OptConfig``, dict, or None) to the
    plain attr dict the update-graph ops consume."""
    if oc is None:
        return dict(DEFAULT_OPT)
    if isinstance(oc, dict):
        unknown = set(oc) - set(DEFAULT_OPT)
        if unknown:
            raise AutodiffError(f"unknown optimizer fields: {sorted(unknown)}")
        return {**DEFAULT_OPT, **oc}
    if dataclasses.is_dataclass(oc):
        return {**DEFAULT_OPT,
                **{k: v for k, v in dataclasses.asdict(oc).items()
                   if k in DEFAULT_OPT}}
    return {k: getattr(oc, k, v) for k, v in DEFAULT_OPT.items()}


class _GradGB(GB):
    """GB whose generated names carry a ``d<n>_`` prefix — a namespace
    disjoint from any traced forward graph's buffers, so imported
    residuals (which keep their forward names) can never collide with
    generated cotangent buffers."""

    def fresh(self, prefix: str) -> str:
        self.n += 1
        return f"d{self.n}_{prefix}"


class _BwdBuilder:
    """The builder VJP rules receive.  Wraps a :class:`GB` (delegating the
    ops GB already knows how to index) plus a generalized ``emit`` for the
    gradient ops, and imports forward buffers as shared residuals."""

    def __init__(self, gb: GB, src: DataflowGraph | None = None):
        self.gb = gb
        self.src = src
        self.residuals: list[str] = []
        self._imported: set[str] = set()

    # ---- queries ---------------------------------------------------------
    def shape(self, name: str) -> tuple[int, ...]:
        shp = self.gb.shape.get(name)
        if shp is None and self.src is not None:
            shp = tuple(self.src.buffers[name].shape)
        if shp is None:
            raise AutodiffError(f"unknown buffer {name!r}")
        return tuple(shp)

    def res(self, name: str) -> str:
        """Import forward buffer ``name`` into the backward graph (as an
        input, under the *same* name — the residual the train step wires
        from the forward run).  Idempotent."""
        if name in self._imported:
            return name
        if name in self.gb.shape:
            raise AutodiffError(
                f"residual {name!r} collides with a generated backward "
                f"buffer")
        if self.src is None or name not in self.src.buffers:
            raise AutodiffError(f"residual {name!r} not in the source graph")
        buf = self.src.buffers[name]
        self.gb.buf(name, tuple(buf.shape), "input")
        self.gb.g.buffers[name].dtype = buf.dtype
        self._imported.add(name)
        self.residuals.append(name)
        return name

    # ---- GB delegation (ops whose loop indexing GB already handles) ------
    def add(self, a, b):
        return self.gb.add(a, b)

    def mul(self, a, b):
        return self.gb.mul(a, b)

    def div(self, a, b):
        return self.gb.div(a, b)

    def divc(self, x, c):
        return self.gb.divc(x, float(c))

    def scale(self, x, s):
        return self.gb.scale(x, float(s))

    def matmul(self, a, b):
        return self.gb.matmul(a, b)

    def transpose(self, x):
        return self.gb.transpose(x)

    def mv(self, A, x, trans=False):
        return self.gb.mv(A, x, trans=trans)

    def concat(self, xs, axis=0):
        return self.gb.concat(list(xs), axis)

    def split(self, x, sizes, axis=0):
        return self.gb.split(x, sizes, axis)

    def slice(self, x, starts, sizes):
        return self.gb.slice(x, starts, sizes)

    # ---- composite helpers ----------------------------------------------
    def add_n(self, xs):
        """Left fold of :meth:`add` — accumulates cotangent contributions."""
        xs = list(xs)
        if not xs:
            raise AutodiffError("add_n of zero contributions")
        acc = xs[0]
        for x in xs[1:]:
            acc = self.add(acc, x)
        return acc

    def outer(self, u: str, v: str) -> str:
        (m,), (n,) = self.shape(u), self.shape(v)
        gb = self.gb
        out = gb.buf(gb.fresh("outer"), (m, n))
        gb.g.add_task(Task(
            gb.fresh("outer_t"), [Loop("i", m), Loop("j", n)],
            reads=[Access(u, (idx("i"),), False), Access(v, (idx("j"),), False)],
            writes=[Access(out, (idx("i"), idx("j")), True)],
            op="matmul", flops_per_iter=1.0,
            spec=OpSpec("outer", (u, v), (out,))))
        return out

    def zeros(self, shape, name=None, kind="intermediate",
              dtype="float32") -> str:
        gb = self.gb
        shape = tuple(int(s) for s in shape)
        out = gb.buf(name or gb.fresh("zeros"), shape, kind)
        dims = [f"i{k}" for k in range(len(shape))]
        gb.g.add_task(Task(
            gb.fresh("zeros_t"), [Loop(d, s) for d, s in zip(dims, shape)],
            reads=[], writes=[Access(out, full_index(dims), True)],
            op="copy", flops_per_iter=0.0,
            spec=OpSpec("zeros", (), (out,),
                        {"shape": shape, "dtype": dtype})))
        return out

    def copy_to(self, name: str, src_buf: str, kind: str = "output") -> str:
        """Identity-copy ``src_buf`` into an explicitly named buffer (the
        ``grad_<w>`` outputs)."""
        gb = self.gb
        shp = self.shape(src_buf)
        gb.buf(name, shp, kind)
        dims = [f"i{k}" for k in range(len(shp))]
        gb.g.add_task(Task(
            gb.fresh("copy_t"), [Loop(d, int(s)) for d, s in zip(dims, shp)],
            reads=[Access(src_buf, full_index(dims), False)],
            writes=[Access(name, full_index(dims), True)],
            op="copy", flops_per_iter=0.0,
            spec=OpSpec("identity", (src_buf,), (name,))))
        return name

    def ewise(self, kind, ins, attrs=None, shape=None, flops=1.0) -> str:
        return self.emit(kind, ins, (shape or self.shape(ins[0]),),
                         attrs, op="ewise", flops=flops)[0]

    # ---- generalized emitter --------------------------------------------
    @staticmethod
    def _index(shape, dims, trips):
        """Access index over the leading ``min(rank, len(dims))`` loop
        vars; size-1 dims under a non-trivial loop read with coefficient 0
        (the broadcast/reduction-carrier convention ``ssd_scan`` and the
        (1, 1) optimizer scalars use)."""
        ix = []
        for d, (v, trip) in zip(shape, zip(dims, trips)):
            ix.append(idx((v, 0)) if d == 1 and trip != 1 else idx(v))
        return tuple(ix)

    def emit(self, kind, ins, out_shapes, attrs=None, op="ewise", flops=1.0,
             loop_shape=None, out_names=None) -> list[str]:
        """One spec'd task computing ``kind`` over ``ins`` into fresh (or
        explicitly named) output buffers.  The loop nest spans
        ``loop_shape`` (default: the first output's shape); operands of
        lower rank use the leading loop vars, so reductions to (1, 1)
        carriers express as coefficient-0 writes (the same write-inside-
        reduction shape ``matmul_task`` uses)."""
        gb = self.gb
        out_shapes = [tuple(int(s) for s in shp) for shp in out_shapes]
        names = out_names or (None,) * len(out_shapes)
        outs = tuple(gb.buf(nm or gb.fresh(kind), shp)
                     for nm, shp in zip(names, out_shapes))
        trips = tuple(int(s) for s in (loop_shape or out_shapes[0]))
        dims = [f"i{k}" for k in range(len(trips))]
        reads = [Access(b, self._index(self.shape(b), dims, trips), False)
                 for b in ins]
        writes = [Access(o, self._index(gb.shape[o], dims, trips), True)
                  for o in outs]
        gb.g.add_task(Task(
            gb.fresh(f"{kind}_t"), [Loop(d, t) for d, t in zip(dims, trips)],
            reads=reads, writes=writes, op=op, flops_per_iter=float(flops),
            spec=OpSpec(kind, tuple(ins), outs, dict(attrs or {}))))
        return list(outs)


# --------------------------------------------------------------------------
# Backward construction
# --------------------------------------------------------------------------


@dataclass
class BackwardBuild:
    """``build_backward``'s result: the backward graph plus the wiring
    tables the train step needs — ``seeds`` maps each forward output to
    its cotangent-seed input, ``residuals`` lists the forward buffers the
    backward reads (shared, not recomputed), ``grads`` maps each ``wrt``
    buffer to its ``grad_<w>`` output."""

    graph: DataflowGraph
    seeds: dict[str, str]
    residuals: list[str] = field(default_factory=list)
    grads: dict[str, str] = field(default_factory=dict)


def build_backward(src: DataflowGraph, *, wrt=None,
                   name: str | None = None) -> BackwardBuild:
    """Emit the VJP of ``src`` as a new dataflow graph.

    ``wrt`` defaults to the weight buffers.  The walk visits tasks in
    reverse topological order; every output is seeded with a
    ``seed_<out>`` input, per-buffer cotangent contributions accumulate
    through pairwise adds (memoized per buffer, so multi-producer buffers
    fold once), and each ``wrt`` buffer's total lands in a ``grad_<w>``
    output (zeros when no differentiable path reaches it)."""
    src.validate()
    if wrt is None:
        wrt = [b.name for b in src.weights()]
    wrt = list(wrt)
    gb = _GradGB(name or f"{src.name}_bwd")
    b = _BwdBuilder(gb, src)
    outputs = [buf.name for buf in src.outputs()]
    if not outputs:
        raise AutodiffError(f"{src.name}: no output buffers to seed")

    seeds: dict[str, str] = {}
    cot: dict[str, list[str]] = {}
    for o in outputs:
        s = gb.buf(f"seed_{o}", tuple(src.buffers[o].shape), "input")
        gb.g.buffers[s].dtype = src.buffers[o].dtype
        seeds[o] = s
        cot[o] = [s]

    combined: dict[str, str] = {}

    def fold(buf_name: str) -> str:
        if buf_name not in combined:
            combined[buf_name] = b.add_n(cot[buf_name])
        return combined[buf_name]

    for t in reversed(src.toposort()):
        spec = t.spec
        if spec is None:
            raise AutodiffError(
                f"{src.name}: task {t.name} has no OpSpec — only "
                f"spec-carrying graphs are differentiable")
        live = {o: fold(o) for o in spec.outs if cot.get(o)}
        if not live:
            continue
        if spec.kind == "fused":
            raise AutodiffError(
                f"{src.name}: task {t.name} is a fused composite — "
                f"differentiate the pre-pass source graph, then run "
                f"forward and backward through the pass pipeline")
        try:
            rule = vjp_rule(spec.kind)
        except UnknownOpError as e:
            raise AutodiffError(f"{src.name}: task {t.name}: {e}") from None
        contrib = rule(spec, live, b)
        pairs = contrib.items() if isinstance(contrib, dict) else contrib
        for in_name, c in pairs:
            if c is not None:
                cot.setdefault(in_name, []).append(c)

    grads: dict[str, str] = {}
    for w in wrt:
        if w not in src.buffers:
            raise AutodiffError(f"{src.name}: wrt buffer {w!r} not found")
        gname = f"grad_{w}"
        if cot.get(w):
            b.copy_to(gname, fold(w))
        else:
            b.zeros(tuple(src.buffers[w].shape), name=gname, kind="output")
        grads[w] = gname

    bwd = gb.g
    bwd.validate()
    return BackwardBuild(graph=bwd, seeds=seeds,
                         residuals=list(b.residuals), grads=grads)


# --------------------------------------------------------------------------
# AdamW update graph
# --------------------------------------------------------------------------

# Names the update graph claims for itself; parameters may not collide.
_RESERVED = ("step", "new_step", "lr", "grad_norm")
_RESERVED_PREFIXES = ("grad_", "m_", "v_", "new_")


def _loop_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    """At least rank 2, so the (1, 1) scalar carriers index cleanly."""
    return shape if len(shape) >= 2 else shape + (1,) * (2 - len(shape))


def build_update(params: dict[str, tuple[int, ...]], oc=None,
                 name: str = "adamw_update") -> DataflowGraph:
    """The AdamW + global-norm-clip + LR-schedule update as one dataflow
    graph: inputs ``{w, grad_w, m_w, v_w}`` per parameter plus the (1, 1)
    ``step`` counter; outputs ``{new_w, new_m_w, new_v_w}`` plus the
    ``new_step``/``lr``/``grad_norm`` metric carriers.  Arithmetic is the
    eager ``optimizer.adamw_update`` op-for-op (square-sums accumulate in
    sorted parameter order, matching jax's dict-key tree order)."""
    opt = opt_attrs(oc)
    for w in params:
        if w in _RESERVED or any(w.startswith(p) for p in _RESERVED_PREFIXES):
            raise AutodiffError(
                f"parameter name {w!r} collides with reserved update-graph "
                f"names ({_RESERVED} and prefixes {_RESERVED_PREFIXES})")
    gb = GB(name)
    b = _BwdBuilder(gb)
    step = gb.input("step", (1, 1))

    items = sorted((w, tuple(int(s) for s in shp))
                   for w, shp in params.items())
    nsqs = []
    for w, shp in items:
        gb.input(w, shp)
        gb.input(f"grad_{w}", shp)
        gb.input(f"m_{w}", shp)
        gb.input(f"v_{w}", shp)
        nsqs.append(b.emit("sumsq", (f"grad_{w}",), ((1, 1),), op="pool",
                           flops=2.0, loop_shape=_loop_shape(shp))[0])
    total = b.add_n(nsqs)
    scale, _ = b.emit("clip_scale", (total,), ((1, 1), (1, 1)),
                      {"max_norm": float(opt["clip_norm"])},
                      out_names=(None, "grad_norm"))
    step2 = b.emit("affine", (step,), ((1, 1),), {"a": 1.0, "b": 1.0})[0]
    lr = b.emit("lr_sched", (step2,), ((1, 1),),
                {"lr": float(opt["lr"]),
                 "warmup_steps": int(opt["warmup_steps"]),
                 "total_steps": int(opt["total_steps"]),
                 "min_lr_frac": float(opt["min_lr_frac"])})[0]
    b.copy_to("new_step", step2)
    b.copy_to("lr", lr)
    adam = {"b1": float(opt["b1"]), "b2": float(opt["b2"]),
            "eps": float(opt["eps"]), "wd": float(opt["weight_decay"])}
    for w, shp in items:
        b.emit("adamw_step",
               (w, f"grad_{w}", f"m_{w}", f"v_{w}", scale, lr, step2),
               (shp, shp, shp), adam,
               out_names=(f"new_{w}", f"new_m_{w}", f"new_v_{w}"),
               loop_shape=_loop_shape(shp))
        for o in (f"new_{w}", f"new_m_{w}", f"new_v_{w}"):
            gb.mark_output(o)
    gb.mark_output("grad_norm")
    g = gb.g
    g.validate()
    return g


# --------------------------------------------------------------------------
# Linked train-step graphs
# --------------------------------------------------------------------------


@dataclass
class TrainGraphs:
    """The three linked graphs of one training step plus their wiring:
    ``forward`` is the source with the backward's residual intermediates
    re-marked as outputs (shared buffers, not recomputation), ``loss`` is
    the single forward output, and ``params``/``seeds``/``residuals``/
    ``grads`` name the buffers the step threads between phases."""

    forward: DataflowGraph
    backward: DataflowGraph
    update: DataflowGraph
    loss: str
    seeds: dict[str, str]
    residuals: list[str]
    grads: dict[str, str]
    params: list[str]
    opt: dict


def build_train_graphs(src: DataflowGraph, *, oc=None, wrt=None,
                       name: str | None = None) -> TrainGraphs:
    """Differentiate ``src`` (single output = the loss) and link
    forward/backward/AdamW-update graphs for a full training step."""
    outs = src.outputs()
    if len(outs) != 1:
        raise AutodiffError(
            f"{src.name}: a train step needs exactly one (loss) output; "
            f"got {sorted(b.name for b in outs)}")
    loss = outs[0].name
    base = name or src.name
    bb = build_backward(src, wrt=wrt, name=f"{base}_bwd")
    fwd = src.copy()
    fwd.name = f"{base}_fwd"
    for r in bb.residuals:
        if fwd.buffers[r].kind == "intermediate":
            fwd.buffers[r].kind = "output"
    params = sorted(bb.grads)
    upd = build_update({w: tuple(src.buffers[w].shape) for w in params},
                       oc, name=f"{base}_upd")
    return TrainGraphs(forward=fwd, backward=bb.graph, update=upd,
                       loss=loss, seeds=bb.seeds, residuals=bb.residuals,
                       grads=bb.grads, params=params, opt=opt_attrs(oc))
