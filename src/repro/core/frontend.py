"""Tracing frontend: plain Python functions -> CODO dataflow graphs.

This is the automation layer the paper's pitch promises (§III: the
compiler takes a *high-level description* and emits an optimized dataflow
design).  Instead of hand-assembling a :class:`~repro.core.graph.
DataflowGraph` task by task, a workload is a plain Python function over
symbolic :class:`ShapedBuffer` arguments:

.. code-block:: python

    from repro.core import frontend as F

    def model(x):                       # x: ShapedBuffer
        h = F.fc(x, 512, relu=True)
        return F.fc(h, 512) + x         # residual skip (Fig. 4a bypass)

    graph = F.trace(model, (64, 512), name="residual")

Tracing executes ``model`` once: every op call records a task — the
*same* :class:`~repro.core.ops.OpSpec` + affine ``Loop``/``Access``
structure the hand-built graphs carry, emitted through the :class:`GB`
builder — so a traced graph is structurally **identical** (same
``structural_hash``, same compile-cache key) to the equivalent hand-built
one.  Positional arguments become ``input`` buffers named after the
function's parameters; ops that need parameters (``fc``, ``conv``) declare
``weight`` buffers internally; returned buffers become ``output``s.

Every op is *polymorphic*: called on :class:`ShapedBuffer`\\ s it records a
task, called on concrete arrays it executes the registered reference
implementation eagerly.  A traced function therefore also runs as plain
Python — ``model(jnp.ones((64, 512)))`` returns numbers — which is what
``repro.api.CompiledProgram`` verifies compiled designs against.  Weights
created inside an op (eager mode has no graph to attach them to) are
deterministic functions of their *shape* (:func:`weight_init`), and the
compiled program binds the same initializer to its weight buffers, so
``codo.compile(fn)(x) == fn(x)`` holds exactly.

Graph construction stays jax-free (the module imports only numpy); eager
execution materializes registry implementations, which import jax lazily.
"""

from __future__ import annotations

import inspect
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from .graph import (Access, Buffer, DataflowGraph, Loop, Task, conv2d_task,
                    ewise_task, full_index, idx, matmul_task, pad_task,
                    pool_task)
from .ops import OpSpec, materialize


class TraceError(TypeError):
    """Raised on misuse of the tracing frontend (mixed traces, non-buffer
    returns, unknown argument specs...)."""


# --------------------------------------------------------------------------
# GB — the low-level graph builder (the vocabulary both the tracer and
# hand-built model code emit through).  Historically lived in
# repro.models.dataflow_models; it moved here so the frontend does not
# depend on the model zoo.  Every method returns the *name* of the buffer
# it produced and tracks shapes, so chained calls read like the math.
# --------------------------------------------------------------------------


class GB:
    """Graph-builder: tracks shapes, emits tasks with declarative specs."""

    def __init__(self, name: str):
        self.g = DataflowGraph(name)
        self.n = 0
        self.shape: dict[str, tuple[int, ...]] = {}

    def fresh(self, prefix: str) -> str:
        self.n += 1
        return f"{prefix}{self.n}"

    def buf(self, name: str, shape, kind="intermediate") -> str:
        self.g.buffer(name, shape, kind=kind)
        self.shape[name] = tuple(shape)
        return name

    def input(self, name: str, shape) -> str:
        return self.buf(name, shape, "input")

    def weight(self, name: str, shape) -> str:
        return self.buf(name, shape, "weight")

    def mark_output(self, name: str) -> None:
        self.g.buffers[name].kind = "output"

    # ---- CNN ops ---------------------------------------------------------

    def pad(self, x: str, p: int) -> str:
        n, c, h, w = self.shape[x]
        out = self.buf(self.fresh("pad"), (n, c, h + 2 * p, w + 2 * p))
        self.g.add_task(pad_task(
            self.fresh("padding"), out, x, n, c, h, w, p,
            spec=OpSpec("pad2d", (x,), (out,), {"pad": p})))
        return out

    def pad_pair(self, x: str, p: int) -> str:
        """Zero-pad expressed as the paper's *init/pad pair* (Fig. 4b):
        one task zero-initializes the padded canvas, a second writes the
        interior — two producers of one buffer, the MPSC violation the
        coarse pass eliminates by producer fusion."""
        n, c, h, w = self.shape[x]
        padded = (n, c, h + 2 * p, w + 2 * p)
        dtype = np.dtype(self.g.buffers[x].dtype)
        out = self.buf(self.fresh("pad"), padded)
        self.g.buffers[out].dtype = dtype    # canvas keeps the input's dtype
        init = Task(self.fresh("pad_init"),
                    loops=[Loop("n", n), Loop("c", c),
                           Loop("h", h + 2 * p), Loop("w", w + 2 * p)],
                    reads=[],
                    writes=[Access(out, full_index(["n", "c", "h", "w"]), True)],
                    op="pad", flops_per_iter=0.0,
                    spec=OpSpec("zeros", (), (out,),
                                {"shape": padded, "dtype": dtype.name}))
        fill = Task(self.fresh("pad_fill"),
                    loops=[Loop("n", n), Loop("c", c), Loop("h", h), Loop("w", w)],
                    reads=[Access(x, full_index(["n", "c", "h", "w"]), False)],
                    writes=[Access(out, full_index(["n", "c", "h", "w"]), True)],
                    op="pad", flops_per_iter=0.0,
                    spec=OpSpec("fill_interior", (x,), (out,), {"pad": p}))
        self.g.add_task(init)
        self.g.add_task(fill)
        return out

    def conv(self, x: str, co: int, k: int, stride: int = 1, pad: int = -1,
             relu: bool = True, depthwise: bool = False) -> str:
        if pad < 0:
            pad = k // 2
        if pad:
            x = self.pad(x, pad)
        n, ci, hp, wp = self.shape[x]
        oh, ow = (hp - k) // stride + 1, (wp - k) // stride + 1
        groups = ci if depthwise else 1
        co_eff = ci if depthwise else co
        wname = self.weight(self.fresh("w"),
                            (co_eff, 1 if depthwise else ci, k, k))
        out = self.buf(self.fresh("conv"), (n, co_eff, oh, ow))

        conv_spec = OpSpec("conv2d", (x, wname), (out,),
                           {"stride": stride, "groups": groups})

        if depthwise:
            t = Task(self.fresh("dwconv"),
                     loops=[Loop("n", n), Loop("c", co_eff), Loop("h", oh),
                            Loop("w", ow), Loop("kh", k), Loop("kw", k)],
                     reads=[Access(x, (idx("n"), idx("c"),
                                       idx(("h", stride), "kh"),
                                       idx(("w", stride), "kw")), False),
                            Access(wname, (idx("c"), (), idx("kh"), idx("kw")),
                                   False)],
                     writes=[Access(out, (idx("n"), idx("c"), idx("h"),
                                          idx("w")), True)],
                     op="conv", flops_per_iter=2.0, spec=conv_spec)
            self.g.add_task(t)
        else:
            self.g.add_task(conv2d_task(self.fresh("conv2d"), out, x, wname,
                                        n, co_eff, ci, oh, ow, k, k,
                                        spec=conv_spec, stride=stride))
        if relu:
            out = self.relu(out)
        return out

    def relu(self, x: str) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("relu"), shp)
        dims = ["n", "c", "h", "w"][:len(shp)] if len(shp) == 4 else None
        self.g.add_task(ewise_task(
            self.fresh("relu_t"), out, [x], shp, op="ewise",
            spec=OpSpec("relu", (x,), (out,)), dim_names=dims))
        return out

    def gelu(self, x: str) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("gelu"), shp)
        self.g.add_task(ewise_task(
            self.fresh("gelu_t"), out, [x], shp, op="ewise", flops_per_iter=8.0,
            spec=OpSpec("gelu", (x,), (out,))))
        return out

    def add(self, a: str, b: str) -> str:
        shp = self.shape[a]
        out = self.buf(self.fresh("add"), shp)
        dims = ["n", "c", "h", "w"][:len(shp)] if len(shp) == 4 else None
        self.g.add_task(ewise_task(
            self.fresh("add_t"), out, [a, b], shp, op="ewise",
            spec=OpSpec("add", (a, b), (out,)), dim_names=dims))
        return out

    def maxpool(self, x: str, k: int) -> str:
        n, c, h, w = self.shape[x]
        oh, ow = h // k, w // k
        out = self.buf(self.fresh("pool"), (n, c, oh, ow))
        self.g.add_task(pool_task(
            self.fresh("maxpool"), out, x, n, c, oh, ow, k,
            spec=OpSpec("maxpool2d", (x,), (out,), {"k": k})))
        return out

    def global_avgpool(self, x: str) -> str:
        n, c, h, w = self.shape[x]
        out = self.buf(self.fresh("gap"), (n, c))
        t = Task(self.fresh("gap_t"),
                 loops=[Loop("n", n), Loop("c", c), Loop("h", h), Loop("w", w)],
                 reads=[Access(x, full_index(["n", "c", "h", "w"]), False)],
                 writes=[Access(out, (idx("n"), idx("c")), True)],
                 op="pool", flops_per_iter=1.0,
                 spec=OpSpec("mean", (x,), (out,), {"axes": (2, 3)}))
        self.g.add_task(t)
        return out

    def mean_all(self, x: str) -> str:
        """Full mean-reduction to a (1, 1) scalar carrier — the loss head
        of traced training objectives."""
        shp = self.shape[x]
        if len(shp) < 2:
            raise TraceError(f"mean_all needs a rank>=2 operand (got {shp})")
        out = self.buf(self.fresh("loss"), (1, 1))
        dims = [f"i{k}" for k in range(len(shp))]
        t = Task(self.fresh("mean_all_t"),
                 loops=[Loop(d, int(n)) for d, n in zip(dims, shp)],
                 reads=[Access(x, full_index(dims), False)],
                 writes=[Access(out, (idx((dims[0], 0)), idx((dims[1], 0))),
                                True)],
                 op="pool", flops_per_iter=1.0,
                 spec=OpSpec("mean_all", (x,), (out,)))
        self.g.add_task(t)
        return out

    def flatten(self, x: str) -> str:
        n, c, h, w = self.shape[x]
        out = self.buf(self.fresh("flat"), (n, c * h * w))
        t = Task(self.fresh("flatten_t"),
                 loops=[Loop("n", n), Loop("c", c), Loop("h", h), Loop("w", w)],
                 reads=[Access(x, full_index(["n", "c", "h", "w"]), False)],
                 writes=[Access(out, (idx("n"),
                                      idx(("c", h * w), ("h", w), "w")), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("reshape", (x,), (out,), {"shape": (n, -1)}))
        self.g.add_task(t)
        return out

    # ---- dense ops ---------------------------------------------------------

    def fc(self, x: str, dout: str | int, relu: bool = False,
           weight: str | None = None) -> str:
        m, k = self.shape[x]
        nname = int(dout)
        wname = weight or self.weight(self.fresh("wfc"), (k, nname))
        out = self.buf(self.fresh("fc"), (m, nname))
        self.g.add_task(matmul_task(
            self.fresh("fc_t"), out, x, wname, m, nname, k,
            spec=OpSpec("matmul", (x, wname), (out,))))
        if relu:
            out = self.relu(out)
        return out

    def matmul(self, a: str, b: str) -> str:
        """2-D ``(M, K) @ (K, N)`` or batched 3-D ``(B, M, K) @ (B, K, N)``
        (one leading batch dim; the batch loop prefixes all three
        accesses)."""
        sa, sb = self.shape[a], self.shape[b]
        if len(sa) == 3 or len(sb) == 3:
            if len(sa) != 3 or len(sb) != 3:
                raise TraceError(
                    f"batched matmul needs two 3-D operands (got {sa} and "
                    f"{sb}); lift the 2-D side explicitly")
            bt, m, k = sa
            bt2, k2, n = sb
            if bt != bt2 or k != k2:
                raise TraceError(f"batched matmul shape mismatch: {sa} @ {sb}")
            out = self.buf(self.fresh("bmm"), (bt, m, n))
            self.g.add_task(matmul_task(
                self.fresh("bmm_t"), out, a, b, m, n, k, batch=bt,
                spec=OpSpec("matmul", (a, b), (out,))))
            return out
        m, k = sa
        k2, n = sb
        assert k == k2, (sa, sb)
        out = self.buf(self.fresh("mm"), (m, n))
        self.g.add_task(matmul_task(
            self.fresh("mm_t"), out, a, b, m, n, k,
            spec=OpSpec("matmul", (a, b), (out,))))
        return out

    def transpose(self, x: str) -> str:
        """2-D transpose, or a last-two-dims swap for 3-D (batched)
        operands (spec attr ``perm=(0, 2, 1)``)."""
        shp = self.shape[x]
        if len(shp) == 3:
            bt, m, n = shp
            out = self.buf(self.fresh("tr"), (bt, n, m))
            t = Task(self.fresh("transpose_t"),
                     loops=[Loop("b", bt), Loop("i", m), Loop("j", n)],
                     reads=[Access(x, (idx("b"), idx("i"), idx("j")), False)],
                     writes=[Access(out, (idx("b"), idx("j"), idx("i")), True)],
                     op="copy", flops_per_iter=0.0,
                     spec=OpSpec("transpose", (x,), (out,),
                                 {"perm": (0, 2, 1)}))
            self.g.add_task(t)
            return out
        m, n = shp
        out = self.buf(self.fresh("tr"), (n, m))
        t = Task(self.fresh("transpose_t"),
                 loops=[Loop("i", m), Loop("j", n)],
                 reads=[Access(x, (idx("i"), idx("j")), False)],
                 writes=[Access(out, (idx("j"), idx("i")), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("transpose", (x,), (out,)))
        self.g.add_task(t)
        return out

    def softmax(self, x: str) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("sm"), shp)
        self.g.add_task(ewise_task(
            self.fresh("softmax_t"), out, [x], shp, op="softmax",
            flops_per_iter=5.0,
            spec=OpSpec("softmax", (x,), (out,), {"axis": -1})))
        return out

    def scale(self, x: str, s: float) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("scale"), shp)
        # The scale factor is an OpSpec attr — plain data that enters
        # structural_signature(), so graphs differing only in `s` key the
        # compile cache apart (no const: tag needed, unlike closures).
        self.g.add_task(ewise_task(
            self.fresh("scale_t"), out, [x], shp, op="ewise",
            spec=OpSpec("scale", (x,), (out,), {"s": float(s)})))
        return out

    def mv(self, A: str, x: str, trans: bool = False) -> str:
        """y = A @ x (or A.T @ x): PolyBench building block."""
        m, k = self.shape[A]
        if trans:
            m, k = k, m
        out = self.buf(self.fresh("mv"), (m,))
        loops = [Loop("m", m), Loop("k", k)]
        a_idx = (idx("k"), idx("m")) if trans else (idx("m"), idx("k"))
        t = Task(self.fresh("mv_t"), loops,
                 reads=[Access(A, a_idx, False), Access(x, (idx("k"),), False)],
                 writes=[Access(out, (idx("m"),), True)],
                 op="matmul", flops_per_iter=2.0,
                 spec=OpSpec("mv", (A, x), (out,), {"trans": bool(trans)}))
        self.g.add_task(t)
        return out

    def load(self, x: str) -> str:
        """Explicit off-chip→on-chip stream task (the DMA 'load' node every
        HLS dataflow design starts with).  Makes downstream skip connections
        read an *intermediate* buffer, exercising the bypass pattern."""
        shp = self.shape[x]
        out = self.buf(self.fresh("ld"), shp)
        dims = ["n", "c", "h", "w"][:len(shp)] if len(shp) == 4 else None
        self.g.add_task(ewise_task(
            self.fresh("load_t"), out, [x], shp, op="copy", flops_per_iter=0.0,
            spec=OpSpec("identity", (x,), (out,)), dim_names=dims))
        return out

    def vadd(self, a: str, b: str, alpha: float = 1.0, beta: float = 1.0) -> str:
        shp = self.shape[a]
        out = self.buf(self.fresh("vadd"), shp)
        # alpha/beta are structural via OpSpec.attrs (see scale()).
        self.g.add_task(ewise_task(
            self.fresh("vadd_t"), out, [a, b], shp, op="ewise",
            spec=OpSpec("vadd", (a, b), (out,),
                        {"alpha": float(alpha), "beta": float(beta)})))
        return out

    def affine(self, x: str, a: float, b: float) -> str:
        """``a*x + b`` — scalar-operand add/sub (reflected-operator sugar)."""
        shp = self.shape[x]
        out = self.buf(self.fresh("affine"), shp)
        self.g.add_task(ewise_task(
            self.fresh("affine_t"), out, [x], shp, op="ewise",
            spec=OpSpec("affine", (x,), (out,),
                        {"a": float(a), "b": float(b)})))
        return out

    def divc(self, x: str, c: float) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("divc"), shp)
        self.g.add_task(ewise_task(
            self.fresh("divc_t"), out, [x], shp, op="ewise", flops_per_iter=4.0,
            spec=OpSpec("divc", (x,), (out,), {"c": float(c)})))
        return out

    def rdivc(self, x: str, c: float) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("rdivc"), shp)
        self.g.add_task(ewise_task(
            self.fresh("rdivc_t"), out, [x], shp, op="ewise", flops_per_iter=4.0,
            spec=OpSpec("rdivc", (x,), (out,), {"c": float(c)})))
        return out

    def div(self, a: str, b: str) -> str:
        shp = self.shape[a]
        out = self.buf(self.fresh("div"), shp)
        self.g.add_task(ewise_task(
            self.fresh("div_t"), out, [a, b], shp, op="ewise", flops_per_iter=4.0,
            spec=OpSpec("div", (a, b), (out,))))
        return out

    def mul(self, a: str, b: str) -> str:
        shp = self.shape[a]
        out = self.buf(self.fresh("mul"), shp)
        self.g.add_task(ewise_task(
            self.fresh("mul_t"), out, [a, b], shp, op="ewise",
            spec=OpSpec("mul", (a, b), (out,))))
        return out

    def const(self, value) -> str:
        """A compile-time constant array as a producer task (array-left
        operands lifted into the trace)."""
        arr = np.asarray(value)
        if arr.dtype == object:
            raise TraceError(f"cannot lift {type(value).__name__} into a "
                             "trace as a constant array")
        arr = arr.astype(np.float32) if arr.dtype.kind in "fiu" else arr
        out = self.buf(self.fresh("const"), arr.shape)
        dims = [f"d{i}" for i in range(max(arr.ndim, 1))]
        t = Task(self.fresh("const_t"),
                 loops=[Loop(d, int(n)) for d, n in
                        zip(dims, arr.shape or (1,))],
                 reads=[],
                 writes=[Access(out, full_index(dims[:arr.ndim]), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("const", (), (out,),
                             {"value": arr.tolist(), "dtype": arr.dtype.name,
                              "shape": arr.shape}))
        self.g.add_task(t)
        return out

    # ---- shape algebra -----------------------------------------------------

    def concat(self, xs: Sequence[str], axis: int = 0) -> str:
        """Concatenate along ``axis``; all other dims must agree."""
        shapes = [self.shape[x] for x in xs]
        if not xs:
            raise TraceError("concat needs at least one operand")
        rank = len(shapes[0])
        axis = axis % rank
        for s in shapes[1:]:
            if len(s) != rank or any(a != b for d, (a, b)
                                     in enumerate(zip(shapes[0], s))
                                     if d != axis):
                raise TraceError(f"concat operand shapes disagree off axis "
                                 f"{axis}: {shapes}")
        oshape = list(shapes[0])
        oshape[axis] = sum(s[axis] for s in shapes)
        out = self.buf(self.fresh("cat"), tuple(oshape))
        dims = [f"i{k}" for k in range(rank)]
        t = Task(self.fresh("concat_t"),
                 loops=[Loop(d, int(n)) for d, n in zip(dims, oshape)],
                 reads=[Access(x, full_index(dims), False) for x in xs],
                 writes=[Access(out, full_index(dims), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("concat", tuple(xs), (out,), {"axis": axis}))
        self.g.add_task(t)
        return out

    def split(self, x: str, sizes: Sequence[int], axis: int = 0) -> tuple[str, ...]:
        """Partition ``axis`` into ``sizes`` pieces (the inverse of
        :meth:`concat`); one multi-output task, one buffer per piece."""
        shp = self.shape[x]
        axis = axis % len(shp)
        sizes = tuple(int(s) for s in sizes)
        if sum(sizes) != shp[axis] or any(s <= 0 for s in sizes):
            raise TraceError(f"split sizes {sizes} do not partition axis "
                             f"{axis} of shape {shp}")
        outs = []
        for s in sizes:
            oshape = list(shp)
            oshape[axis] = s
            outs.append(self.buf(self.fresh("sp"), tuple(oshape)))
        dims = [f"i{k}" for k in range(len(shp))]
        t = Task(self.fresh("split_t"),
                 loops=[Loop(d, int(n)) for d, n in zip(dims, shp)],
                 reads=[Access(x, full_index(dims), False)],
                 writes=[Access(o, full_index(dims), True) for o in outs],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("split", (x,), tuple(outs),
                             {"axis": axis, "sizes": sizes}))
        self.g.add_task(t)
        return tuple(outs)

    def slice(self, x: str, starts: Sequence[int],
              sizes: Sequence[int]) -> str:
        """Static rectangular window ``x[starts : starts + sizes]``."""
        shp = self.shape[x]
        starts = tuple(int(s) for s in starts)
        sizes = tuple(int(s) for s in sizes)
        if len(starts) != len(shp) or len(sizes) != len(shp):
            raise TraceError(f"slice needs one (start, size) per dim of "
                             f"{shp}; got starts={starts} sizes={sizes}")
        for st, sz, n in zip(starts, sizes, shp):
            if st < 0 or sz <= 0 or st + sz > n:
                raise TraceError(f"slice window starts={starts} "
                                 f"sizes={sizes} exceeds shape {shp}")
        out = self.buf(self.fresh("slc"), sizes)
        dims = [f"i{k}" for k in range(len(shp))]
        t = Task(self.fresh("slice_t"),
                 loops=[Loop(d, int(n)) for d, n in zip(dims, sizes)],
                 reads=[Access(x, full_index(dims), False)],
                 writes=[Access(out, full_index(dims), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("slice", (x,), (out,),
                             {"starts": starts, "sizes": sizes}))
        self.g.add_task(t)
        return out

    # ---- recurrences -------------------------------------------------------

    def rglru_scan(self, a: str, b: str) -> str:
        """RG-LRU linear recurrence h_t = a_t*h_{t-1} + b_t over axis 1 of
        (B, S, D) operands — the scan-style recurrence op."""
        sa, sb = self.shape[a], self.shape[b]
        if sa != sb or len(sa) != 3:
            raise TraceError(f"rglru_scan needs matching (B, S, D) operands "
                             f"(got {sa} and {sb})")
        out = self.buf(self.fresh("scan"), sa)
        self.g.add_task(ewise_task(
            self.fresh("rglru_scan_t"), out, [a, b], sa, op="scan",
            flops_per_iter=2.0,
            spec=OpSpec("rglru_scan", (a, b), (out,))))
        return out

    def ssd_scan(self, states: str, decay: str) -> str:
        """SSD inter-chunk state recurrence over per-chunk end states
        (nc, BH, P, N) and decays (nc, BH, 1, 1); emits carried-in
        states."""
        ss, sd = self.shape[states], self.shape[decay]
        if len(ss) != 4 or len(sd) != 4 or sd[:2] != ss[:2] or sd[2:] != (1, 1):
            raise TraceError(f"ssd_scan needs (nc, BH, P, N) states and "
                             f"(nc, BH, 1, 1) decay (got {ss} and {sd})")
        out = self.buf(self.fresh("scan"), ss)
        dims = ["c", "h", "p", "n"]
        t = Task(self.fresh("ssd_scan_t"),
                 loops=[Loop(d, int(n)) for d, n in zip(dims, ss)],
                 reads=[Access(states, full_index(dims), False),
                        Access(decay, (idx("c"), idx("h"), idx(("p", 0)),
                                       idx(("n", 0))), False)],
                 writes=[Access(out, full_index(dims), True)],
                 op="scan", flops_per_iter=2.0,
                 spec=OpSpec("ssd_scan", (states, decay), (out,)))
        self.g.add_task(t)
        return out


# --------------------------------------------------------------------------
# Symbolic values
# --------------------------------------------------------------------------


@dataclass
class ShapedBuffer:
    """A symbolic tensor: shape + dtype, optionally bound to a live trace.

    Unbound instances (``tracer is None``) are *argument prototypes* —
    plain data, picklable, usable as ``codo.compile(fn, ShapedBuffer((4,
    8)))`` specs.  Bound instances flow through a traced function; every op
    applied to one records a task in the underlying graph.
    """

    shape: tuple[int, ...]
    dtype: Any = np.float32
    name: str | None = None
    tracer: "Tracer | None" = field(default=None, repr=False, compare=False)

    def __post_init__(self):
        self.shape = tuple(int(s) for s in self.shape)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    # numpy must not try to coerce a ShapedBuffer into an object array:
    # returning NotImplemented from its ufuncs hands `ndarray <op> buffer`
    # expressions to the reflected methods below.
    __array_ufunc__ = None

    # Convenience operator sugar — traced functions read like the math.
    # Scalar and array-left operands are handled by the op functions
    # (scalars become affine/divc/rdivc attrs, arrays lift to const
    # tasks), so every reflected form stays bit-exact with eager mode.
    def __add__(self, other):
        return add(self, other)

    def __radd__(self, other):
        return add(other, self)

    def __sub__(self, other):
        return sub(self, other)

    def __rsub__(self, other):
        return sub(other, self)

    def __matmul__(self, other):
        return matmul(self, other)

    def __rmatmul__(self, other):
        return matmul(other, self)

    def __mul__(self, other):
        return mul(self, other)

    def __rmul__(self, other):
        return mul(other, self)

    def __truediv__(self, other):
        return div(self, other)

    def __rtruediv__(self, other):
        return div(other, self)

    @property
    def T(self):  # noqa: N802 — numpy's spelling
        return transpose(self)

    def relu(self):
        return relu(self)


def buffer(shape: Sequence[int], dtype=np.float32,
           name: str | None = None) -> ShapedBuffer:
    """An input-argument prototype for :func:`trace` / ``codo.compile``."""
    return ShapedBuffer(tuple(shape), dtype, name)


# --------------------------------------------------------------------------
# Tracer
# --------------------------------------------------------------------------


class Tracer:
    """Records a function's op calls into a :class:`GB` builder."""

    def __init__(self, name: str):
        self.gb = GB(name)
        self.inputs: list[str] = []
        self.outputs: list[str] = []

    # ---- binding -----------------------------------------------------------
    def add_input(self, name: str, shape: Sequence[int],
                  dtype=np.float32) -> ShapedBuffer:
        self.gb.input(name, tuple(shape))
        self.gb.g.buffers[name].dtype = dtype
        self.inputs.append(name)
        return self.wrap(name)

    def wrap(self, bufname: str) -> ShapedBuffer:
        return ShapedBuffer(self.gb.shape[bufname],
                            self.gb.g.buffers[bufname].dtype,
                            name=bufname, tracer=self)

    def name_of(self, x: "ShapedBuffer") -> str:
        if not isinstance(x, ShapedBuffer) or x.tracer is None:
            raise TraceError(
                f"expected a traced ShapedBuffer, got {type(x).__name__}; "
                "inside a traced function every tensor must flow from the "
                "function's arguments")
        if x.tracer is not self:
            raise TraceError(
                f"buffer {x.name!r} belongs to a different trace "
                f"({x.tracer.gb.g.name!r}, this trace is {self.gb.g.name!r})")
        return x.name

    def finish(self, result) -> DataflowGraph:
        outs = result if isinstance(result, (tuple, list)) else (result,)
        if not outs:
            raise TraceError("traced function returned no buffers")
        for o in outs:
            name = self.name_of(o)
            if self.gb.g.buffers[name].kind == "input":
                raise TraceError(
                    f"traced function returns input {name!r} unchanged; "
                    "return a computed buffer (wrap pass-throughs in load())")
            if name in self.outputs:
                raise TraceError(f"buffer {name!r} returned more than once")
            self.gb.mark_output(name)
            self.outputs.append(name)
        self.gb.g.validate()
        return self.gb.g


def _positional_params(fn) -> list[str]:
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return []
    return [p.name for p in sig.parameters.values()
            if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                          inspect.Parameter.POSITIONAL_OR_KEYWORD)]


def trace_io(fn: Callable, *specs, name: str | None = None,
             dtype=np.float32) -> tuple[DataflowGraph, list[str], list[str]]:
    """Trace ``fn`` over argument ``specs`` (shape tuples or
    :class:`ShapedBuffer` prototypes).  Returns ``(graph, input_names,
    output_names)`` — the io lists preserve the function's argument and
    return order, which is what gives ``CompiledProgram`` its positional
    calling convention."""
    if not callable(fn):
        raise TraceError(f"trace() needs a callable, got {type(fn).__name__}")
    if not specs:
        raise TraceError("trace() needs at least one input shape, e.g. "
                         "trace(fn, (64, 512))")
    tr = Tracer(name or getattr(fn, "__name__", "traced"))
    params = _positional_params(fn)
    args = []
    for i, spec in enumerate(specs):
        if isinstance(spec, ShapedBuffer):
            shape, dt, pname = spec.shape, spec.dtype, spec.name
        elif isinstance(spec, (tuple, list)):
            shape, dt, pname = tuple(spec), dtype, None
        else:
            raise TraceError(
                f"argument spec {i} must be a shape tuple or ShapedBuffer, "
                f"got {type(spec).__name__}")
        pname = pname or (params[i] if i < len(params) else f"arg{i}")
        args.append(tr.add_input(pname, shape, dt))
    return tr.gb.g, tr.inputs[:], _finish(tr, fn, args)


def _finish(tr: Tracer, fn: Callable, args: list[ShapedBuffer]) -> list[str]:
    tr.finish(fn(*args))
    return tr.outputs[:]


def trace(fn: Callable, *specs, name: str | None = None,
          dtype=np.float32) -> DataflowGraph:
    """Trace ``fn`` into a :class:`DataflowGraph` (see :func:`trace_io`)."""
    graph, _ins, _outs = trace_io(fn, *specs, name=name, dtype=dtype)
    return graph


# --------------------------------------------------------------------------
# Deterministic eager initialization.  Weights created *inside* an op (fc,
# conv) have no graph buffer to bind against in eager mode, so their values
# are a pure function of shape: fan-in-normalized, seeded from the shape
# itself.  CompiledProgram uses the same function for unbound weight
# buffers, which is what makes `codo.compile(fn)(x) == fn(x)` exact.  Two
# weights of identical shape share values by design — acceptable for
# verification; bind real parameters via CompiledProgram.bind().
# --------------------------------------------------------------------------


def weight_init(shape: Sequence[int], dtype=np.float32) -> np.ndarray:
    shape = tuple(int(s) for s in shape)
    seed = zlib.adler32(repr(shape).encode())
    rng = np.random.default_rng(seed)
    fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else (shape[0] if shape else 1)
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (rng.standard_normal(shape) * std).astype(dtype)


def _eager(kind: str, arrays, attrs: dict | None = None):
    ins = tuple(f"in{i}" for i in range(len(arrays)))
    spec = OpSpec(kind, ins, ("out",), dict(attrs or {}))
    return materialize(spec)(dict(zip(ins, arrays)))["out"]


def _tracer_of(*values) -> Tracer | None:
    tr = None
    for v in values:
        if isinstance(v, ShapedBuffer) and v.tracer is not None:
            if tr is not None and v.tracer is not tr:
                raise TraceError("operands belong to different traces")
            tr = v.tracer
    return tr


def _as_scalar(v):
    """``v`` as a Python float if it is scalar-like (Python number, numpy
    scalar, 0-d array), else ``None``."""
    if isinstance(v, bool) or v is None:
        return None
    if isinstance(v, (int, float, np.integer, np.floating)):
        return float(v)
    if isinstance(v, np.ndarray) and v.ndim == 0:
        return float(v)
    return None


def _lift(tr: Tracer, v) -> str:
    """Buffer name of operand ``v`` inside trace ``tr``: traced buffers
    pass through, concrete arrays become ``const`` producer tasks."""
    if isinstance(v, ShapedBuffer):
        return tr.name_of(v)
    return tr.gb.const(v)


def _lift_ewise(tr: Tracer, a, b) -> tuple[str, str]:
    na, nb = _lift(tr, a), _lift(tr, b)
    sa, sb = tr.gb.shape[na], tr.gb.shape[nb]
    if sa != sb:
        raise TraceError(
            f"elementwise operands must have identical shapes inside a "
            f"trace (got {sa} and {sb}; broadcasting is not part of the "
            "dataflow IR)")
    return na, nb


# --------------------------------------------------------------------------
# The op namespace.  Each function dispatches: symbolic operands record a
# task through GB (identical structure to hand-built graphs), concrete
# arrays execute the registered reference implementation eagerly.
# --------------------------------------------------------------------------


def pad(x, p: int, pair: bool = False):
    """Zero-pad NCHW by ``p``.  ``pair=True`` emits the init/fill
    *multi-producer* form (Fig. 4b) instead of one pad task."""
    tr = _tracer_of(x)
    if tr is not None:
        emit = tr.gb.pad_pair if pair else tr.gb.pad
        return tr.wrap(emit(tr.name_of(x), p))
    # Both eager forms reduce to the same padded array (the pair's two
    # registered impls compose to exactly this).
    return _eager("pad2d", (x,), {"pad": p})


def conv(x, co: int, k: int, stride: int = 1, pad: int = -1,
         relu: bool = True, depthwise: bool = False):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.conv(tr.name_of(x), co, k, stride=stride,
                                  pad=pad, relu=relu, depthwise=depthwise))
    if pad < 0:
        pad = k // 2
    if pad:
        x = _eager("pad2d", (x,), {"pad": pad})
    ci = x.shape[1]
    groups = ci if depthwise else 1
    co_eff = ci if depthwise else co
    w = weight_init((co_eff, 1 if depthwise else ci, k, k))
    y = _eager("conv2d", (x, w), {"stride": stride, "groups": groups})
    return _eager("relu", (y,)) if relu else y


def relu(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.relu(tr.name_of(x)))
    return _eager("relu", (x,))


def gelu(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.gelu(tr.name_of(x)))
    return _eager("gelu", (x,))


def add(a, b):
    tr = _tracer_of(a, b)
    if tr is None:
        return _eager("add", (a, b))
    for x, c in ((a, _as_scalar(b)), (b, _as_scalar(a))):
        if c is not None:                    # x + c == c + x, bit-exact
            return tr.wrap(tr.gb.affine(tr.name_of(x), 1.0, c))
    return tr.wrap(tr.gb.add(*_lift_ewise(tr, a, b)))


def sub(a, b):
    tr = _tracer_of(a, b)
    if tr is None:
        return _eager("vadd", (a, b), {"alpha": 1.0, "beta": -1.0})
    c = _as_scalar(b)
    if c is not None:                        # x - c == x + (-c), bit-exact
        return tr.wrap(tr.gb.affine(tr.name_of(a), 1.0, -c))
    c = _as_scalar(a)
    if c is not None:                        # c - x == (-x) + c, bit-exact
        return tr.wrap(tr.gb.affine(tr.name_of(b), -1.0, c))
    return tr.wrap(tr.gb.vadd(*_lift_ewise(tr, a, b), alpha=1.0, beta=-1.0))


def mul(a, b):
    tr = _tracer_of(a, b)
    if tr is None:
        return _eager("mul", (a, b))
    for x, c in ((a, _as_scalar(b)), (b, _as_scalar(a))):
        if c is not None:                    # x * c == c * x, bit-exact
            return tr.wrap(tr.gb.scale(tr.name_of(x), c))
    return tr.wrap(tr.gb.mul(*_lift_ewise(tr, a, b)))


def div(a, b):
    tr = _tracer_of(a, b)
    if tr is None:
        return _eager("div", (a, b))
    c = _as_scalar(b)
    if c is not None:                        # true division, not 1/c scale
        return tr.wrap(tr.gb.divc(tr.name_of(a), c))
    c = _as_scalar(a)
    if c is not None:
        return tr.wrap(tr.gb.rdivc(tr.name_of(b), c))
    return tr.wrap(tr.gb.div(*_lift_ewise(tr, a, b)))


def vadd(a, b, alpha: float = 1.0, beta: float = 1.0):
    tr = _tracer_of(a, b)
    if tr is not None:
        return tr.wrap(tr.gb.vadd(tr.name_of(a), tr.name_of(b),
                                  alpha=alpha, beta=beta))
    return _eager("vadd", (a, b), {"alpha": float(alpha), "beta": float(beta)})


def scale(x, s: float):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.scale(tr.name_of(x), float(s)))
    return _eager("scale", (x,), {"s": float(s)})


def softmax(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.softmax(tr.name_of(x)))
    return _eager("softmax", (x,), {"axis": -1})


def matmul(a, b):
    tr = _tracer_of(a, b)
    if tr is not None:
        # Array operands (W @ x with a concrete W) lift to const tasks.
        return tr.wrap(tr.gb.matmul(_lift(tr, a), _lift(tr, b)))
    return _eager("matmul", (a, b))


def mv(A, x, trans: bool = False):
    tr = _tracer_of(A, x)
    if tr is not None:
        return tr.wrap(tr.gb.mv(tr.name_of(A), tr.name_of(x), trans=trans))
    return _eager("mv", (A, x), {"trans": bool(trans)})


def transpose(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.transpose(tr.name_of(x)))
    if getattr(x, "ndim", 2) == 3:       # batched: swap the last two dims
        return _eager("transpose", (x,), {"perm": (0, 2, 1)})
    return _eager("transpose", (x,))


def fc(x, dout: int, relu: bool = False, weight=None):
    tr = _tracer_of(x, weight if isinstance(weight, ShapedBuffer) else None)
    if tr is not None:
        wname = tr.name_of(weight) if isinstance(weight, ShapedBuffer) else weight
        return tr.wrap(tr.gb.fc(tr.name_of(x), dout, relu=relu, weight=wname))
    w = weight if weight is not None else weight_init((x.shape[1], int(dout)))
    y = _eager("matmul", (x, w))
    return _eager("relu", (y,)) if relu else y


def maxpool(x, k: int):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.maxpool(tr.name_of(x), k))
    return _eager("maxpool2d", (x,), {"k": k})


def global_avgpool(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.global_avgpool(tr.name_of(x)))
    return _eager("mean", (x,), {"axes": (2, 3)})


def flatten(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.flatten(tr.name_of(x)))
    return _eager("reshape", (x,), {"shape": (x.shape[0], -1)})


def mean_all(x):
    """Mean of every element as a (1, 1) scalar carrier — the loss head
    traced training objectives end in."""
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.mean_all(tr.name_of(x)))
    return _eager("mean_all", (x,))


def load(x):
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.load(tr.name_of(x)))
    return _eager("identity", (x,))


def concat(xs, axis: int = 0):
    """Concatenate a sequence of same-rank tensors along ``axis``."""
    xs = tuple(xs)
    tr = _tracer_of(*xs)
    if tr is not None:
        return tr.wrap(tr.gb.concat([_lift(tr, x) for x in xs], axis=axis))
    return _eager("concat", xs, {"axis": int(axis)})


def split(x, sizes, axis: int = 0):
    """Partition ``axis`` into ``len(sizes)`` pieces; inverse of
    :func:`concat`.  Returns a tuple of tensors."""
    sizes = tuple(int(s) for s in sizes)
    tr = _tracer_of(x)
    if tr is not None:
        return tuple(tr.wrap(o) for o in
                     tr.gb.split(tr.name_of(x), sizes, axis=axis))
    # Eager multi-output: _eager() is single-out, so build the spec inline.
    outs = tuple(f"out{i}" for i in range(len(sizes)))
    spec = OpSpec("split", ("in0",), outs,
                  {"axis": int(axis), "sizes": sizes})
    res = materialize(spec)({"in0": x})
    return tuple(res[o] for o in outs)


def slice_(x, starts, sizes):
    """Static window ``x[starts : starts + sizes]`` (one entry per dim)."""
    starts = tuple(int(s) for s in starts)
    sizes = tuple(int(s) for s in sizes)
    tr = _tracer_of(x)
    if tr is not None:
        return tr.wrap(tr.gb.slice(tr.name_of(x), starts, sizes))
    return _eager("slice", (x,), {"starts": starts, "sizes": sizes})


def rglru_scan(a, b):
    """Gated linear recurrence ``h_t = a_t * h_{t-1} + b_t`` along axis 1
    of ``(B, S, D)`` operands, ``h_{-1} = 0``."""
    tr = _tracer_of(a, b)
    if tr is not None:
        na, nb = _lift_ewise(tr, a, b)
        return tr.wrap(tr.gb.rglru_scan(na, nb))
    return _eager("rglru_scan", (a, b))


def ssd_scan(states, decay):
    """SSD inter-chunk recurrence: carried-in states per chunk from
    per-chunk end ``states (nc, BH, P, N)`` and ``decay (nc, BH, 1, 1)``."""
    tr = _tracer_of(states, decay)
    if tr is not None:
        return tr.wrap(tr.gb.ssd_scan(_lift(tr, states), _lift(tr, decay)))
    return _eager("ssd_scan", (states, decay))


# --------------------------------------------------------------------------
# Request coalescing (serving): rebuild a graph with a leading batch dim.
#
# The serving runtime (repro.serving.runtime) coalesces same-signature
# requests arriving within one batching window into a single execution of
# a *batched* design: every buffer that depends on an input grows a
# leading dimension of size B, every touched task a leading batch loop.
# Weights (and const-producer chains) stay unbatched — the registered op
# implementations broadcast them per batch element, exactly like jnp's
# leading-batch-dim convention for `@`, so the batched design is
# numerically identical to B independent runs.
#
# The rebuild is *clean*: all schedule state (loop parallel degrees, access
# enclosing sets, buffer impls, fusion ids) is dropped, because the batched
# graph goes back through the full codo_opt pipeline — it is a new design,
# compiled and cached under its own structural hash.
# --------------------------------------------------------------------------

# Op kinds whose registered implementations are polymorphic over a leading
# batch dimension (elementwise broadcasting, `@`'s batch semantics, or an
# explicit attr rewrite below).  Graphs using anything else — conv2d's
# fixed NCHW layout, scans with a baked-in batch axis — fall back to
# per-request execution in the runtime.
BATCHABLE_KINDS = frozenset({
    "identity", "dup", "fused", "relu", "gelu", "add", "vadd", "scale",
    "affine", "divc", "rdivc", "div", "mul", "softmax", "matmul",
    "transpose",
})


def _batched_buffers(graph: DataflowGraph) -> set[str]:
    """Buffers that (transitively) depend on an input buffer — the ones a
    leading batch dim threads through.  Weights and const-producer chains
    stay unbatched (broadcasting lifts them per batch element)."""
    batched = {b.name for b in graph.inputs()}
    for t in graph.toposort():
        if any(a.buffer in batched for a in t.reads):
            batched.update(a.buffer for a in t.writes)
    return batched


def batch_blockers(graph: DataflowGraph) -> list[str]:
    """Why :func:`batch_graph` cannot coalesce this graph (empty = it can).

    A graph batches when every task touched by the batch dim carries a
    declarative spec built only from :data:`BATCHABLE_KINDS` and every
    output depends on an input.  The returned strings are human-readable
    reasons — the serving runtime records the first one when it falls back
    to per-request execution."""
    problems: list[str] = []
    batched = _batched_buffers(graph)
    missing = [b.name for b in graph.outputs() if b.name not in batched]
    if missing:
        problems.append(f"outputs {missing} do not depend on any input")

    def _walk(spec: OpSpec):
        yield spec
        for p in spec.parts:
            yield from _walk(p)

    for t in graph.tasks:
        if not any(a.buffer in batched for a in t.accesses()):
            continue                      # untouched by the batch dim
        if t.fn_is_closure:
            problems.append(f"task {t.name}: closure numerics cannot be "
                            "re-batched (no declarative spec)")
            continue
        if t.spec is None:
            problems.append(f"task {t.name}: no numeric semantics")
            continue
        for s in _walk(t.spec):
            if s.kind not in BATCHABLE_KINDS:
                problems.append(f"task {t.name}: op kind {s.kind!r} is not "
                                "batch-polymorphic")
    return problems


def _batch_spec(spec: OpSpec, batched: set[str]) -> OpSpec:
    """Copy of ``spec`` adjusted for a leading batch dim on the operands in
    ``batched``.  Most kinds need nothing (broadcasting does the work);
    ``transpose`` perms and non-negative ``softmax`` axes shift by one.
    Fused parts propagate batched-ness through their interior names."""
    out = spec.copy()
    if out.kind == "fused":
        inner = set(batched)
        parts = []
        for part in out.parts:
            parts.append(_batch_spec(part, inner))
            if any(b in inner for b in part.ins):
                inner.update(part.outs)
        out.parts = tuple(parts)
        return out
    if not any(b in batched for b in out.ins):
        return out
    if out.kind == "transpose":
        perm = out.attrs.get("perm")
        if perm is None:                       # 2-D .T -> batched (0, 2, 1)
            out.attrs["perm"] = (0, 2, 1)
        else:
            out.attrs["perm"] = (0,) + tuple(int(p) + 1 for p in perm)
    elif out.kind == "softmax":
        axis = int(out.attrs.get("axis", -1))
        if axis >= 0:
            out.attrs["axis"] = axis + 1
    return out


def batch_graph(graph: DataflowGraph, batch: int, *,
                name: str | None = None, var: str = "rb") -> DataflowGraph:
    """A clean rebuild of ``graph`` with a leading batch dimension of size
    ``batch`` on every input-dependent buffer (weights stay shared).

    The result is a fresh, schedule-free design — compile it through
    ``codo.compile``/``codo_opt`` like any other graph; it caches under its
    own structural hash.  Raises :class:`TraceError` when
    :func:`batch_blockers` is non-empty or ``batch < 1``.
    """
    batch = int(batch)
    if batch < 1:
        raise TraceError(f"batch_graph needs batch >= 1, got {batch}")
    problems = batch_blockers(graph)
    if problems:
        raise TraceError(f"graph {graph.name!r} cannot take a leading "
                         f"batch dim: " + "; ".join(problems))
    batched = _batched_buffers(graph)
    out = DataflowGraph(name or f"{graph.name}@b{batch}")
    for b in graph.buffers.values():
        shape = ((batch,) + tuple(b.shape)) if b.name in batched \
            else tuple(b.shape)
        out.add_buffer(Buffer(b.name, shape, b.dtype, b.kind))
    for t in graph.tasks:
        loops = [Loop(l.var, l.trip) for l in t.loops]
        bvar = None
        if any(a.buffer in batched for a in t.accesses()):
            bvar = var
            used = {l.var for l in t.loops}
            while bvar in used:
                bvar += "_"
            loops = [Loop(bvar, batch)] + loops

        def _acc(a: Access) -> Access:
            index = tuple(tuple(dim) for dim in a.index)
            if a.buffer in batched:
                index = (idx(bvar),) + index
            return Access(a.buffer, index, a.is_write)

        spec = None
        if t.spec is not None:
            spec = (_batch_spec(t.spec, batched) if bvar is not None
                    else t.spec.copy())
        out.add_task(Task(
            t.name, loops, [_acc(a) for a in t.reads],
            [_acc(a) for a in t.writes], op=t.op,
            flops_per_iter=t.flops_per_iter,
            bytes_per_iter=t.bytes_per_iter, spec=spec, tags=set(t.tags)))
    out.validate()
    return out


__all__ = [
    "BATCHABLE_KINDS", "GB", "ShapedBuffer", "TraceError", "Tracer",
    "batch_blockers", "batch_graph", "buffer", "trace",
    "trace_io", "weight_init",
    # ops
    "add", "concat", "conv", "div", "fc", "flatten", "gelu",
    "global_avgpool", "load", "matmul", "maxpool", "mean_all", "mul", "mv",
    "pad", "relu", "rglru_scan", "scale", "slice_", "softmax", "split",
    "ssd_scan", "sub", "transpose", "vadd",
]
