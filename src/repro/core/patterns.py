"""Access-pattern analysis and dataflow-violation detection (paper §II-A/§IV).

Two violation classes:

*Coarse-grained* — a buffer breaks the single-producer-single-consumer rule
(SPMC / MPSC / MPMC patterns of Fig. 4).

*Fine-grained* — producer/consumer access count or order mismatch, which on
an FPGA FIFO means overflow/underflow/deadlock and on TPU means the two
tasks cannot be fused into one streaming kernel (their tile streams would
disagree).  Detected statically from the affine signatures — this replaces
the paper's days-long co-simulation with a compile-time check.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .graph import Access, DataflowGraph, Task

# Coarse violation kinds (Fig. 4 a/b/c)
SPMC = "single-producer-multi-consumer"
MPSC = "multi-producer-single-consumer"
MPMC = "multi-producer-multi-consumer"

# Fine violation kinds (§II-C, Fig. 2 Issue 1)
COUNT_MISMATCH = "access-count-mismatch"
ORDER_MISMATCH = "access-order-mismatch"
STENCIL_REREAD = "stencil-multi-read"       # consumer re-reads via a sliding window
BROADCAST_REREAD = "broadcast-re-read"      # consumer re-reads across a reduction dim
MULTI_WRITE = "reduction-multi-write"       # producer writes each element >1× (reduction)


@dataclass
class CoarseViolation:
    buffer: str
    kind: str
    producers: list[str]
    consumers: list[str]


@dataclass
class FineViolation:
    buffer: str
    kind: str
    producer: str
    consumer: str
    detail: str = ""


# --------------------------------------------------------------------------
# Per-access signature
# --------------------------------------------------------------------------


@dataclass
class AccessSig:
    """Streaming signature of one access inside one task."""

    task: str
    buffer: str
    is_write: bool
    dim_depth: tuple[int, ...]   # array-dim -> loop depth of its driving var
    dim_order: tuple[int, ...]   # array dims sorted by variation rate (outer first)
    distinct: int                # distinct elements touched
    total: int                   # total dynamic access count
    window: bool                 # overlapping multi-var dims (stencil window)
    index_vars: frozenset[str] = field(default_factory=frozenset)

    @property
    def repeats(self) -> bool:
        return self.total > self.distinct


def _dim_span(dim, trips) -> int:
    """Extent of values an affine index expression takes: sum_i (trip_i-1)*s_i + 1."""
    if not dim:
        return 1
    span = 1
    for v, s in dim:
        span += (trips[v] - 1) * abs(s)
    return span


def _dim_combos(dim, trips) -> int:
    """Number of (var...) combinations driving this dim."""
    c = 1
    for v, _s in dim:
        c *= trips[v]
    return c


def access_sig(task: Task, access: Access) -> AccessSig:
    enclosing = task.enclosing_vars(access)
    trips = {l.var: l.trip for l in task.loops}

    if access.stream_shape is not None:
        # Reuse-rewritten load region: exact-once stream over the stated
        # logical extent, ordered by the current index drivers.
        dim_depth = []
        for dim in access.index:
            ds = [task.loop_depth(v) for (v, _s) in dim if task.has_loop(v)]
            dim_depth.append(min(ds) if ds else len(task.loops))
        dim_order = tuple(int(i) for i in np.argsort(np.array(dim_depth), kind="stable"))
        distinct = int(np.prod([s for s in access.stream_shape])) \
            if access.stream_shape else 1
        return AccessSig(
            task=task.name, buffer=access.buffer, is_write=access.is_write,
            dim_depth=tuple(dim_depth), dim_order=dim_order,
            distinct=distinct, total=distinct, window=False,
            index_vars=frozenset(v for dim in access.index for (v, _s) in dim))

    window = False
    dim_depth = []
    distinct = 1
    for dim in access.index:
        live = [(v, s) for (v, s) in dim if trips.get(v, 1) > 1]
        if len(live) > 1:
            combos = _dim_combos(live, trips)
            span = _dim_span(live, trips)
            if combos > span:
                window = True        # overlapping window (conv);  stride-k pool is exact
            distinct *= span
        else:
            distinct *= _dim_span(live, trips)
        ds = [task.loop_depth(v) for (v, _s) in dim if task.has_loop(v)]
        dim_depth.append(min(ds) if ds else len(task.loops))
    dim_order = tuple(int(i) for i in np.argsort(np.array(dim_depth), kind="stable"))

    total = task.trip_product(enclosing)
    index_vars = frozenset(v for dim in access.index for (v, _s) in dim)
    return AccessSig(
        task=task.name,
        buffer=access.buffer,
        is_write=access.is_write,
        dim_depth=tuple(dim_depth),
        dim_order=dim_order,
        distinct=distinct,
        total=total,
        window=window,
        index_vars=index_vars,
    )


def index_dims(task: Task, access: Access) -> list[str]:
    """Loop vars that appear in the access index, in loop order."""
    vars_ = access.vars()
    return [l.var for l in task.loops if l.var in vars_]


def reduction_dims(task: Task, access: Access) -> list[str]:
    """Loop vars enclosing the access that do NOT appear in its index —
    the 'reduction dimensions' of §IV-B."""
    vars_ = access.vars()
    return [v for v in task.enclosing_vars(access) if v not in vars_]


def arrival_order(task: Task, access: Access) -> tuple[int, ...]:
    """Array dims in their stream-arrival order (outermost driver first),
    considering only dims that actually vary."""
    trips = {l.var: l.trip for l in task.loops}
    varying = []
    for i, dim in enumerate(access.index):
        live = [v for (v, _s) in dim if trips.get(v, 1) > 1]
        if live:
            d = min(task.loop_depth(v) for v in live if task.has_loop(v))
            varying.append((d, i))
    varying.sort()
    return tuple(i for (_d, i) in varying)


# --------------------------------------------------------------------------
# Coarse-grained detection (Fig. 4)
# --------------------------------------------------------------------------


def coarse_violations(graph: DataflowGraph) -> list[CoarseViolation]:
    out = []
    for buf in graph.buffers.values():
        if buf.kind in ("input", "weight"):
            # External inputs may fan out freely: duplication happens at the
            # off-chip boundary (each consumer DMAs its own stream).
            continue
        prods = graph.producers(buf.name)
        cons = graph.consumers(buf.name)
        np_, nc = len(prods), len(cons)
        if np_ <= 1 and nc <= 1:
            continue
        kind = SPMC if np_ <= 1 else (MPSC if nc <= 1 else MPMC)
        out.append(CoarseViolation(buf.name, kind, [t.name for t in prods],
                                   [t.name for t in cons]))
    return out


# --------------------------------------------------------------------------
# Fine-grained detection (§IV-B)
# --------------------------------------------------------------------------


def fine_violations_edge(graph: DataflowGraph, producer: Task, buffer: str,
                         consumer: Task) -> list[FineViolation]:
    """All fine-grained violations on one producer→consumer edge."""
    out: list[FineViolation] = []
    w = producer.writes_to(buffer)
    r = consumer.reads_from(buffer)
    if not w or not r:
        return out
    ws, rs = access_sig(producer, w[0]), access_sig(consumer, r[0])

    if ws.repeats:
        out.append(FineViolation(
            buffer, MULTI_WRITE, producer.name, consumer.name,
            f"producer writes {ws.total}x for {ws.distinct} elements "
            f"(reduction dims {reduction_dims(producer, w[0])})"))
    if rs.window:
        out.append(FineViolation(
            buffer, STENCIL_REREAD, producer.name, consumer.name,
            "consumer reads an overlapping window (line/window reuse buffer required)"))
    elif rs.repeats:
        out.append(FineViolation(
            buffer, BROADCAST_REREAD, producer.name, consumer.name,
            f"reads {rs.total}x for {rs.distinct} elements "
            f"(reduction dims {reduction_dims(consumer, r[0])})"))
    if not ws.repeats and not rs.repeats and not rs.window:
        if ws.distinct != rs.distinct:
            out.append(FineViolation(
                buffer, COUNT_MISMATCH, producer.name, consumer.name,
                f"writes {ws.distinct} != reads {rs.distinct}"))
        elif arrival_order(producer, w[0]) != arrival_order(consumer, r[0]):
            out.append(FineViolation(
                buffer, ORDER_MISMATCH, producer.name, consumer.name,
                f"write order {arrival_order(producer, w[0])} != "
                f"read order {arrival_order(consumer, r[0])}"))
    return out


def fine_violations(graph: DataflowGraph) -> list[FineViolation]:
    out = []
    for p, buf, c in graph.internal_edges():
        out.extend(fine_violations_edge(graph, p, buf, c))
    return out


def edge_is_fifo_compatible(graph: DataflowGraph, producer: Task, buffer: str,
                            consumer: Task) -> bool:
    return not fine_violations_edge(graph, producer, buffer, consumer)


def violation_report(graph: DataflowGraph) -> str:
    cs, fs = coarse_violations(graph), fine_violations(graph)
    lines = [f"{graph.name}: {len(cs)} coarse, {len(fs)} fine violations"]
    for v in cs:
        lines.append(f"  [coarse/{v.kind}] {v.buffer}: {v.producers} -> {v.consumers}")
    for v in fs:
        lines.append(f"  [fine/{v.kind}] {v.buffer}: {v.producer} -> {v.consumer}: {v.detail}")
    return "\n".join(lines)
