"""Dataflow-graph IR for the CODO compiler (paper §III/IV).

A :class:`DataflowGraph` is a DAG of :class:`Task` nodes connected through
named :class:`Buffer` objects.  Each task carries an *affine loop-nest
signature* — an ordered loop list plus array accesses whose index
expressions are (coefficient, loop-var) affine sums, which is exactly the
class CODO targets: "affine programs with constant loop bounds" (§VII-A).

The IR is deliberately *schedule-carrying*: passes mutate loop order,
access enclosing-sets, parallel degrees and buffer implementations in place
of the C++ source rewrites the paper performs on MLIR.  Numeric semantics
live separately in ``Task.spec`` — a declarative, picklable
:class:`~repro.core.ops.OpSpec` record from which ``Task.fn`` (the pure-jnp
implementation of the whole op) is derived on demand — so every pass is
semantics-preserving by construction and correctness is checked by
executing the lowered program against the un-optimized oracle.  Raw
closures are still accepted (``Task(..., fn=lambda env: ...)``) for ad-hoc
graphs, but they cannot cross pickle boundaries (disk cache, process
pools); see ``repro/core/ops.py`` for the registry contract.

Two IR features carry the paper's fine-grained machinery:

* ``Access.enclosing`` — the set of loops that dynamically enclose the
  access.  Fig. 5's reduction rewriting hoists a FIFO write *out* of the
  reduction loops: here that is ``write.enclosing = index_dims``.  Fig. 7's
  post-reuse code has *sibling* loop regions (a load region and a compute
  region inside one task); ``enclosing`` expresses "this access runs under
  loops {n,h,w,ci} only" even when the task's nest also has ``co``.
* stride-carrying index expressions — ``input[(h,1),(kh,1)]`` models
  ``input[h+kh]`` (a conv window), ``input[(oh,2),(kh,1)]`` models a
  stride-2 pooling window.  Spans/overlap are computed from these.
"""

from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from .ops import OpSpec, materialize

# --------------------------------------------------------------------------
# Loops and accesses
# --------------------------------------------------------------------------


@dataclass
class Loop:
    """One loop of a task's nest.  ``var`` names are unique per task."""

    var: str
    trip: int
    # scheduling state (written by core.schedule / core.reuse)
    parallel: int = 1          # unroll / vector-lane degree
    tile: int = 0              # tile size from inter-task propagation (0 = untiled)
    ring: str = "free"         # reuse.py classification: outer|fifo|reduction|free

    def copy(self) -> "Loop":
        return dataclasses.replace(self)


# One array-dim index expression: affine sum of (var, stride) terms.
# () is a constant dim;  (("h",1),("kh",1)) is input[h+kh].
IndexExpr = tuple[tuple[str, int], ...]


def idx(*terms) -> IndexExpr:
    """idx("h") -> (("h",1),);  idx(("oh",2),"kh") -> (("oh",2),("kh",1))."""
    out = []
    for t in terms:
        if isinstance(t, str):
            out.append((t, 1))
        else:
            out.append((t[0], int(t[1])))
    return tuple(out)


@dataclass
class Access:
    """A read or write of ``buffer`` inside a task's loop nest."""

    buffer: str
    index: tuple[IndexExpr, ...]
    is_write: bool
    # Loop vars that dynamically enclose this access.  None = all of the
    # task's loops.  Set by fine-grained rewriting / reuse generation.
    enclosing: tuple[str, ...] | None = None
    # Logical per-dim stream extent override.  After reuse rewriting, the
    # load region consumes the *input* extent (e.g. the padded 34×34 rows)
    # exactly once even though the compute loops span the output extent;
    # Fig. 7's sibling-region structure.  None = derive from index/trips.
    stream_shape: tuple[int, ...] | None = None

    def vars(self) -> set[str]:
        return {v for dim in self.index for (v, _s) in dim}

    def copy(self) -> "Access":
        return dataclasses.replace(
            self,
            index=tuple(tuple(term for term in dim) for dim in self.index),
            enclosing=None if self.enclosing is None else tuple(self.enclosing),
            stream_shape=None if self.stream_shape is None else tuple(self.stream_shape),
        )


# --------------------------------------------------------------------------
# Buffers
# --------------------------------------------------------------------------

# Buffer communication implementations (paper §V-A).
FIFO = "fifo"          # streaming, element granularity  -> TPU: fused through VMEM
PINGPONG = "pingpong"  # double-buffered block           -> TPU: HBM materialization
UNDECIDED = "undecided"


@dataclass
class Buffer:
    name: str
    shape: tuple[int, ...]
    dtype: Any = np.float32
    kind: str = "intermediate"  # input | weight | intermediate | output
    impl: str = UNDECIDED       # FIFO / PINGPONG, set by buffers.py
    fifo_depth: int = 0         # elements, set when impl == FIFO
    hbm_channel: int = -1       # set by offchip.py for off-chip buffers
    burst_len: int = 0          # elements per burst, set by offchip.py

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * np.dtype(self.dtype).itemsize

    def copy(self) -> "Buffer":
        return dataclasses.replace(self)


# --------------------------------------------------------------------------
# Tasks
# --------------------------------------------------------------------------


@dataclass
class Task:
    """A computational node: one loop nest with reads/writes.

    Numeric semantics: ``fn(env) -> {buf: array}`` (``env`` maps buffer
    names to arrays) is a *derived property*.  The durable representation
    is ``spec`` — a declarative :class:`~repro.core.ops.OpSpec` the op
    registry materializes into a jnp callable on demand — which survives
    pickling (disk compile cache, process-pool batch compiles).  A raw
    closure passed as ``fn=`` takes precedence but is stripped at every
    pickle boundary.  Passes never change numeric semantics — they change
    the *schedule metadata* that the cost model and lowering consume (when
    an access is retargeted to a duplicated buffer, :meth:`retarget`
    renames the spec's operands — pure data — or wraps a closure with an
    env-aliasing shim, see coarse.py).
    """

    name: str
    loops: list[Loop]
    reads: list[Access]
    writes: list[Access]
    op: str = "generic"            # conv | matmul | ewise | pad | pool | norm | softmax ...
    flops_per_iter: float = 1.0
    bytes_per_iter: float = 0.0    # extra non-edge traffic per innermost iteration
    fn: Callable[[dict], dict] | None = None   # closure override (see property below)
    spec: OpSpec | None = None     # declarative numeric semantics (picklable)
    # --- schedule state -----------------------------------------------------
    fused_group: int = -1          # fusion-group id assigned by lowering
    stage: int = -1                # pipeline stage (pipeline.py)
    reduction_rewritten: bool = False
    reuse_buffers: dict = field(default_factory=dict)  # name -> shape tuple (reuse.py)
    tags: set = field(default_factory=set)

    # --- loop helpers ---------------------------------------------------------
    def loop(self, var: str) -> Loop:
        for l in self.loops:
            if l.var == var:
                return l
        raise KeyError(f"{self.name}: no loop {var!r}")

    def has_loop(self, var: str) -> bool:
        return any(l.var == var for l in self.loops)

    def loop_depth(self, var: str) -> int:
        for i, l in enumerate(self.loops):
            if l.var == var:
                return i
        raise KeyError(f"{self.name}: no loop {var!r}")

    def trip_product(self, vars_: Sequence[str] | None = None) -> int:
        if vars_ is None:
            loops = self.loops
        else:
            vs = set(vars_)
            loops = [l for l in self.loops if l.var in vs]
        return int(np.prod([l.trip for l in loops])) if loops else 1

    @property
    def total_iters(self) -> int:
        return self.trip_product()

    @property
    def flops(self) -> float:
        return self.flops_per_iter * self.total_iters

    # --- access helpers -------------------------------------------------------
    def accesses(self, buffer: str | None = None) -> list[Access]:
        acc = self.reads + self.writes
        if buffer is not None:
            acc = [a for a in acc if a.buffer == buffer]
        return acc

    def writes_to(self, buffer: str) -> list[Access]:
        return [a for a in self.writes if a.buffer == buffer]

    def reads_from(self, buffer: str) -> list[Access]:
        return [a for a in self.reads if a.buffer == buffer]

    def enclosing_vars(self, a: Access) -> list[str]:
        """Loop vars enclosing access ``a``, in loop-nest order."""
        if a.enclosing is None:
            return [l.var for l in self.loops]
        enc = set(a.enclosing)
        return [l.var for l in self.loops if l.var in enc]

    # --- numeric semantics ----------------------------------------------------
    @property
    def fn_is_closure(self) -> bool:
        """True when a raw closure override is attached (not picklable)."""
        return self._fn is not None

    def retarget(self, alias: dict[str, str]) -> None:
        """Rename the numeric semantics' buffer operands (old -> new).

        With a declarative spec this is a pure data rename; a closure
        override is wrapped with the :func:`retarget_fn` env-aliasing shim.
        """
        if self.spec is not None:
            self.spec = self.spec.renamed(alias)
        if self._fn is not None:
            self._fn = retarget_fn(self._fn, alias)

    def copy(self) -> "Task":
        return dataclasses.replace(
            self,
            loops=[l.copy() for l in self.loops],
            reads=[a.copy() for a in self.reads],
            writes=[a.copy() for a in self.writes],
            fn=self._fn,
            spec=self.spec.copy() if self.spec is not None else None,
            reuse_buffers=dict(self.reuse_buffers),
            tags=set(self.tags),
        )


def _task_fn_get(self: Task) -> Callable[[dict], dict] | None:
    """``Task.fn``: the closure override if set, else the registry
    materialization of ``spec``, else None."""
    if self._fn is not None:
        return self._fn
    if self.spec is not None:
        return materialize(self.spec)
    return None


def _task_fn_set(self: Task, value: Callable[[dict], dict] | None) -> None:
    self._fn = value


# ``fn`` is a derived property: the dataclass-generated __init__ still
# accepts ``fn=`` (its assignment routes through the setter into ``_fn``),
# so closure-based construction keeps working, while spec-carrying tasks
# re-derive their callable after any pickle round-trip.
Task.fn = property(_task_fn_get, _task_fn_set)


# --------------------------------------------------------------------------
# JSON views (docs/artifact_format.md).  Index expressions serialize as
# [[var, stride], ...] per array dim; None distinguishes "unset" from an
# empty tuple for ``enclosing``/``stream_shape``.
# --------------------------------------------------------------------------


def _index_to_json(index: tuple[IndexExpr, ...]) -> list:
    return [[[v, s] for (v, s) in dim] for dim in index]


def _index_from_json(doc) -> tuple[IndexExpr, ...]:
    return tuple(tuple((str(v), int(s)) for (v, s) in dim) for dim in doc)


def _access_to_dict(a: Access) -> dict:
    return {
        "buffer": a.buffer,
        "index": _index_to_json(a.index),
        "is_write": a.is_write,
        "enclosing": None if a.enclosing is None else list(a.enclosing),
        "stream_shape": None if a.stream_shape is None else list(a.stream_shape),
    }


def _access_from_dict(doc: dict) -> Access:
    enc = doc.get("enclosing")
    ss = doc.get("stream_shape")
    return Access(
        buffer=doc["buffer"],
        index=_index_from_json(doc["index"]),
        is_write=bool(doc["is_write"]),
        enclosing=None if enc is None else tuple(str(v) for v in enc),
        stream_shape=None if ss is None else tuple(int(s) for s in ss),
    )


def _buffer_to_dict(b: Buffer) -> dict:
    return {
        "name": b.name, "shape": list(b.shape),
        "dtype": np.dtype(b.dtype).name, "kind": b.kind, "impl": b.impl,
        "fifo_depth": b.fifo_depth, "hbm_channel": b.hbm_channel,
        "burst_len": b.burst_len,
    }


def _buffer_from_dict(doc: dict) -> Buffer:
    return Buffer(
        name=doc["name"], shape=tuple(int(s) for s in doc["shape"]),
        dtype=np.dtype(doc.get("dtype", "float32")),
        kind=doc.get("kind", "intermediate"),
        impl=doc.get("impl", UNDECIDED),
        fifo_depth=int(doc.get("fifo_depth", 0)),
        hbm_channel=int(doc.get("hbm_channel", -1)),
        burst_len=int(doc.get("burst_len", 0)),
    )


def _task_to_dict(t: Task) -> dict:
    return {
        "name": t.name,
        "loops": [{"var": l.var, "trip": l.trip, "parallel": l.parallel,
                   "tile": l.tile, "ring": l.ring} for l in t.loops],
        "reads": [_access_to_dict(a) for a in t.reads],
        "writes": [_access_to_dict(a) for a in t.writes],
        "op": t.op,
        "flops_per_iter": t.flops_per_iter,
        "bytes_per_iter": t.bytes_per_iter,
        "fused_group": t.fused_group,
        "stage": t.stage,
        "reduction_rewritten": t.reduction_rewritten,
        "reuse_buffers": {k: list(v) for k, v in t.reuse_buffers.items()},
        "tags": sorted(t.tags),
        "spec": t.spec.to_dict() if t.spec is not None else None,
    }


def _task_from_dict(doc: dict) -> Task:
    spec = doc.get("spec")
    return Task(
        name=doc["name"],
        loops=[Loop(l["var"], int(l["trip"]), int(l.get("parallel", 1)),
                    int(l.get("tile", 0)), l.get("ring", "free"))
               for l in doc["loops"]],
        reads=[_access_from_dict(a) for a in doc.get("reads", ())],
        writes=[_access_from_dict(a) for a in doc.get("writes", ())],
        op=doc.get("op", "generic"),
        flops_per_iter=float(doc.get("flops_per_iter", 1.0)),
        bytes_per_iter=float(doc.get("bytes_per_iter", 0.0)),
        spec=None if spec is None else OpSpec.from_dict(spec),
        fused_group=int(doc.get("fused_group", -1)),
        stage=int(doc.get("stage", -1)),
        reduction_rewritten=bool(doc.get("reduction_rewritten", False)),
        reuse_buffers={k: tuple(int(s) for s in v)
                       for k, v in doc.get("reuse_buffers", {}).items()},
        tags=set(doc.get("tags", ())),
    )


def retarget_fn(fn: Callable[[dict], dict], alias: dict[str, str]) -> Callable[[dict], dict]:
    """Wrap a task fn so that buffer renames stay numerically transparent.

    ``alias`` maps *old* buffer name -> *new* buffer name.  Reads of the old
    name look up the new one; writes of the old name are emitted under the
    new one.
    """

    def wrapped(env: dict) -> dict:
        shadow = dict(env)
        for old, new in alias.items():
            if new in env:
                shadow[old] = env[new]
        out = fn(shadow)
        renamed = {}
        for k, v in out.items():
            renamed[alias.get(k, k)] = v
        return renamed

    return wrapped


# --------------------------------------------------------------------------
# Graph
# --------------------------------------------------------------------------


class GraphError(RuntimeError):
    pass


class DataflowGraph:
    """Topologically-ordered task DAG + buffer table."""

    def __init__(self, name: str = "graph"):
        self.name = name
        self.tasks: list[Task] = []
        self.buffers: dict[str, Buffer] = {}

    # --- construction -----------------------------------------------------
    def add_buffer(self, buf: Buffer) -> Buffer:
        if buf.name in self.buffers:
            raise GraphError(f"duplicate buffer {buf.name!r}")
        self.buffers[buf.name] = buf
        return buf

    def buffer(
        self, name: str, shape: Sequence[int], dtype=np.float32, kind: str = "intermediate"
    ) -> Buffer:
        return self.add_buffer(Buffer(name, tuple(int(s) for s in shape), dtype, kind))

    def add_task(self, task: Task) -> Task:
        for a in task.accesses():
            if a.buffer not in self.buffers:
                raise GraphError(f"{task.name}: unknown buffer {a.buffer!r}")
        if any(t.name == task.name for t in self.tasks):
            raise GraphError(f"duplicate task {task.name!r}")
        self.tasks.append(task)
        return task

    def remove_task(self, name: str) -> None:
        self.tasks = [t for t in self.tasks if t.name != name]

    # --- topology -----------------------------------------------------------
    def producers(self, buffer: str) -> list[Task]:
        return [t for t in self.tasks if t.writes_to(buffer)]

    def consumers(self, buffer: str) -> list[Task]:
        return [t for t in self.tasks if t.reads_from(buffer)]

    def task(self, name: str) -> Task:
        for t in self.tasks:
            if t.name == name:
                return t
        raise KeyError(name)

    def edges(self) -> list[tuple[Task, str, Task]]:
        """(producer, buffer, consumer) triples."""
        out = []
        for buf in self.buffers.values():
            for p in self.producers(buf.name):
                for c in self.consumers(buf.name):
                    out.append((p, buf.name, c))
        return out

    def internal_edges(self) -> list[tuple[Task, str, Task]]:
        return [(p, b, c) for (p, b, c) in self.edges()
                if self.buffers[b].kind not in ("input", "weight")]

    def inputs(self) -> list[Buffer]:
        return [b for b in self.buffers.values() if b.kind == "input"]

    def weights(self) -> list[Buffer]:
        return [b for b in self.buffers.values() if b.kind == "weight"]

    def outputs(self) -> list[Buffer]:
        return [b for b in self.buffers.values() if b.kind == "output"]

    def intermediates(self) -> list[Buffer]:
        return [b for b in self.buffers.values() if b.kind == "intermediate"]

    # --- validation -----------------------------------------------------------
    def toposort(self) -> list[Task]:
        """Topological order by buffer dependencies; raises on cycles."""
        prod_of: dict[str, list[str]] = {}
        for t in self.tasks:
            for a in t.writes:
                prod_of.setdefault(a.buffer, []).append(t.name)
        indeg = {t.name: 0 for t in self.tasks}
        succ: dict[str, list[str]] = {t.name: [] for t in self.tasks}
        for t in self.tasks:
            for a in t.reads:
                for p in prod_of.get(a.buffer, []):
                    if p != t.name:
                        succ[p].append(t.name)
                        indeg[t.name] += 1
        order, queue = [], sorted([t.name for t in self.tasks if indeg[t.name] == 0],
                                  key=lambda n: [t.name for t in self.tasks].index(n))
        while queue:
            n = queue.pop(0)
            order.append(n)
            for s in succ[n]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    queue.append(s)
        if len(order) != len(self.tasks):
            raise GraphError(f"{self.name}: cycle detected in dataflow graph")
        by_name = {t.name: t for t in self.tasks}
        return [by_name[n] for n in order]

    def validate(self) -> None:
        self.toposort()
        for t in self.tasks:
            vars_ = {l.var for l in t.loops}
            if len(vars_) != len(t.loops):
                raise GraphError(f"{t.name}: duplicate loop vars")
            for a in t.accesses():
                missing = a.vars() - vars_
                if missing:
                    raise GraphError(f"{t.name}: access {a.buffer} uses unknown vars {missing}")
                buf = self.buffers[a.buffer]
                if len(a.index) != len(buf.shape):
                    raise GraphError(
                        f"{t.name}: access rank {len(a.index)} != buffer {a.buffer} rank"
                        f" {len(buf.shape)}"
                    )
                if a.enclosing is not None:
                    bad = set(a.enclosing) - vars_
                    if bad:
                        raise GraphError(f"{t.name}: enclosing uses unknown vars {bad}")

    def copy(self) -> "DataflowGraph":
        g = DataflowGraph(self.name)
        g.buffers = {k: v.copy() for k, v in self.buffers.items()}
        g.tasks = [t.copy() for t in self.tasks]
        return g

    # --- JSON serialization ---------------------------------------------------
    # The graph side of the portable-artifact format: a language-neutral
    # dict covering everything ``structural_signature()`` covers (so a
    # round-trip preserves the structural hash) *except* closure ``fn``
    # overrides, which cannot serialize — spec-carrying graphs round-trip
    # executable.  Versioning/validation live in ``repro.core.artifact``;
    # the field-by-field contract is docs/artifact_format.md.
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "buffers": [_buffer_to_dict(b) for b in self.buffers.values()],
            "tasks": [_task_to_dict(t) for t in self.tasks],
        }

    @classmethod
    def from_dict(cls, doc: dict) -> "DataflowGraph":
        g = cls(doc["name"])
        for b in doc.get("buffers", ()):
            g.add_buffer(_buffer_from_dict(b))
        for t in doc.get("tasks", ()):
            g.add_task(_task_from_dict(t))
        g.validate()
        return g

    # --- content addressing ---------------------------------------------------
    def structural_signature(self) -> tuple:
        """Canonical nested-tuple view of everything the compiler's passes
        read: loop nests, accesses, buffer table, schedule state — plus each
        task's declarative ``spec`` (kind, operands, attrs).  Closure
        ``fn`` overrides are deliberately excluded — closures don't affect
        any pass decision, and two builds of the same model produce equal
        signatures even though their lambdas differ.

        Spec-carrying tasks are fully covered: semantic constants live in
        ``OpSpec.attrs``, which enters the signature, so graphs differing
        only in (say) a scale factor never collide in the compile cache.
        Contract for closure-based builders: any semantic constant that
        lives *only* in a closure must also appear in the structure —
        conventionally a ``const:...`` entry in ``Task.tags``."""

        def acc_sig(a: Access) -> tuple:
            return (a.buffer, a.index, a.is_write, a.enclosing, a.stream_shape)

        bufs = tuple(sorted(
            (b.name, b.shape, np.dtype(b.dtype).str, b.kind, b.impl,
             b.fifo_depth, b.hbm_channel, b.burst_len)
            for b in self.buffers.values()))
        tasks = tuple(
            (t.name,
             tuple((l.var, l.trip, l.parallel, l.tile, l.ring) for l in t.loops),
             tuple(acc_sig(a) for a in t.reads),
             tuple(acc_sig(a) for a in t.writes),
             t.op, float(t.flops_per_iter), float(t.bytes_per_iter),
             t.fused_group, t.stage, t.reduction_rewritten,
             tuple(sorted((k, tuple(v)) for k, v in t.reuse_buffers.items())),
             tuple(sorted(t.tags)),
             t.spec.signature() if t.spec is not None else None)
            for t in self.tasks)
        return (self.name, bufs, tasks)

    def structural_hash(self) -> str:
        """Stable content hash (hex) of :meth:`structural_signature` —
        identical across processes (sha256, not the salted builtin hash), so
        it can key an on-disk compile cache."""
        import hashlib
        payload = repr(self.structural_signature()).encode()
        return hashlib.sha256(payload).hexdigest()

    # --- execution (oracle path) ----------------------------------------------
    def execute(self, env: dict[str, Any]) -> dict[str, Any]:
        """Run every task's ``fn`` in topo order.  Pure; used as the oracle
        and as the body the lowering jit-compiles."""
        env = dict(env)
        for t in self.toposort():
            fn = t.fn
            if fn is None:
                raise GraphError(
                    f"{t.name}: no numeric semantics attached (neither a "
                    f"declarative spec nor a closure fn)")
            out = fn(env)
            env.update(out)
        return {b.name: env[b.name] for b in self.outputs()}

    def summary(self) -> str:
        lines = [f"graph {self.name}: {len(self.tasks)} tasks, {len(self.buffers)} buffers"]
        for t in self.tasks:
            nest = " ".join(f"{l.var}:{l.trip}" + (f"*{l.parallel}" if l.parallel > 1 else "")
                            for l in t.loops)
            rs = ",".join(sorted({a.buffer for a in t.reads}))
            ws = ",".join(sorted({a.buffer for a in t.writes}))
            lines.append(f"  {t.name:<28s} [{t.op:<8s}] ({nest}) {rs} -> {ws}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Task constructors — the vocabulary model builders use.
# --------------------------------------------------------------------------

_uid = itertools.count()


def _fresh(prefix: str) -> str:
    return f"{prefix}_{next(_uid)}"


def full_index(dims: Sequence[str]) -> tuple[IndexExpr, ...]:
    return tuple(idx(d) for d in dims)


def ewise_task(
    name: str,
    out: str,
    ins: Sequence[str],
    shape: Sequence[int],
    fn: Callable[[dict], dict] | None = None,
    op: str = "ewise",
    flops_per_iter: float = 1.0,
    dim_names: Sequence[str] | None = None,
    spec: OpSpec | None = None,
) -> Task:
    dims = list(dim_names) if dim_names else [f"i{k}" for k in range(len(shape))]
    loops = [Loop(d, int(s)) for d, s in zip(dims, shape)]
    reads = [Access(b, full_index(dims), False) for b in ins]
    writes = [Access(out, full_index(dims), True)]
    return Task(name, loops, reads, writes, op=op, flops_per_iter=flops_per_iter,
                fn=fn, spec=spec)


def matmul_task(
    name: str,
    out: str,
    lhs: str,
    rhs: str,
    m: int,
    n: int,
    k: int,
    fn: Callable[[dict], dict] | None = None,
    batch: int = 0,
    spec: OpSpec | None = None,
) -> Task:
    """out[m,n] += lhs[m,k] * rhs[k,n]; the write sits inside the k
    reduction — the canonical access-count-mismatch producer Fig. 5
    rewrites — and the lhs read repeats across n — the broadcast re-read
    the reuse pass caches."""
    loops, out_idx, l_idx, r_idx = [], [], [], []
    if batch:
        loops.append(Loop("b", batch))
        out_idx.append(idx("b")); l_idx.append(idx("b")); r_idx.append(idx("b"))
    loops += [Loop("m", m), Loop("n", n), Loop("k", k)]
    out_idx += [idx("m"), idx("n")]
    l_idx += [idx("m"), idx("k")]
    r_idx += [idx("k"), idx("n")]
    reads = [Access(lhs, tuple(l_idx), False), Access(rhs, tuple(r_idx), False)]
    writes = [Access(out, tuple(out_idx), True)]  # enclosed by k: violation
    return Task(name, loops, reads, writes, op="matmul", flops_per_iter=2.0,
                fn=fn, spec=spec)


def conv2d_task(
    name: str,
    out: str,
    inp: str,
    weight: str,
    n: int,
    co: int,
    ci: int,
    h: int,
    w: int,
    kh: int,
    kw: int,
    fn: Callable[[dict], dict] | None = None,
    stride: int = 1,
    spec: OpSpec | None = None,
) -> Task:
    """NCHW conv over a pre-padded input of ((h-1)*stride+kh, ...)."""
    loops = [Loop("n", n), Loop("co", co), Loop("h", h), Loop("w", w),
             Loop("ci", ci), Loop("kh", kh), Loop("kw", kw)]
    reads = [
        Access(inp, (idx("n"), idx("ci"), idx(("h", stride), "kh"), idx(("w", stride), "kw")),
               False),
        Access(weight, (idx("co"), idx("ci"), idx("kh"), idx("kw")), False),
    ]
    writes = [Access(out, (idx("n"), idx("co"), idx("h"), idx("w")), True)]
    return Task(name, loops, reads, writes, op="conv", flops_per_iter=2.0,
                fn=fn, spec=spec)


def pad_task(
    name: str,
    out: str,
    inp: str,
    n: int,
    c: int,
    h: int,
    w: int,
    pad: int,
    fn: Callable[[dict], dict] | None = None,
    spec: OpSpec | None = None,
) -> Task:
    """Zero-pad: writes (h+2p, w+2p).  Written in the paper's
    motivating-example loop order (c, h, w) — a deliberate order mismatch
    with the conv consumer which arrives after reuse rewriting."""
    loops = [Loop("n", n), Loop("c", c), Loop("h", h + 2 * pad), Loop("w", w + 2 * pad)]
    reads = [Access(inp, full_index(["n", "c", "h", "w"]), False)]
    writes = [Access(out, full_index(["n", "c", "h", "w"]), True)]
    return Task(name, loops, reads, writes, op="pad", flops_per_iter=0.0,
                fn=fn, spec=spec)


def pool_task(
    name: str,
    out: str,
    inp: str,
    n: int,
    c: int,
    oh: int,
    ow: int,
    k: int,
    fn: Callable[[dict], dict] | None = None,
    op: str = "pool",
    spec: OpSpec | None = None,
) -> Task:
    """k×k pool with stride k: the Fig. 5 reduction producer (write inside
    the window loops) plus a windowed read."""
    loops = [Loop("n", n), Loop("c", c), Loop("oh", oh), Loop("ow", ow),
             Loop("kh", k), Loop("kw", k)]
    reads = [Access(inp, (idx("n"), idx("c"), idx(("oh", k), "kh"), idx(("ow", k), "kw")),
                    False)]
    writes = [Access(out, (idx("n"), idx("c"), idx("oh"), idx("ow")), True)]
    return Task(name, loops, reads, writes, op=op, flops_per_iter=1.0,
                fn=fn, spec=spec)


def reduce_task(
    name: str,
    out: str,
    inp: str,
    keep: Sequence[int],
    shape: Sequence[int],
    fn: Callable[[dict], dict] | None = None,
    op: str = "reduce",
    dim_names: Sequence[str] | None = None,
    spec: OpSpec | None = None,
) -> Task:
    """Generic reduction keeping dims ``keep`` of ``shape``."""
    dims = list(dim_names) if dim_names else [f"r{k}" for k in range(len(shape))]
    loops = [Loop(d, int(s)) for d, s in zip(dims, shape)]
    reads = [Access(inp, full_index(dims), False)]
    out_idx = tuple(idx(dims[i]) for i in keep)
    writes = [Access(out, out_idx, True)]
    return Task(name, loops, reads, writes, op=op, flops_per_iter=1.0,
                fn=fn, spec=spec)


def copy_task(name: str, out: str, inp: str, shape: Sequence[int],
              fn: Callable[[dict], dict] | None = None,
              spec: OpSpec | None = None) -> Task:
    return ewise_task(name, out, [inp], shape, fn=fn, op="copy",
                      flops_per_iter=0.0, spec=spec)
