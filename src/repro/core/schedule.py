"""Automated dataflow scheduling (paper §VI): resource-aware,
bottleneck-centric DSE in three stages, then inter-task propagation.

* **PA — initial parallelism allocation**: estimate every task's latency at
  degree 1 with the profiling-based model, allocate degrees proportional to
  latency (min degree 1), then scale all degrees up preserving ratios until
  the unit budget or per-task caps bind.
* **UP — upscaling**: while a bottleneck loop is ≥ n× slower than the
  fastest, double its degree (the paper's minimum unroll granularity is 2,
  hence n = 2.0) until stable or iteration limit.
* **DP — downscaling**: a task n× faster than the longest has been
  over-optimized; halve its degree while it stays under the bottleneck
  latency, reclaiming units.  Optional (users may disable for max perf).
* **Inter-task optimization**: parallelizing a FIFO-indexed loop changes
  the stream's element order/rate, so the chosen degree is propagated to
  the FIFO peer's matching loop.  Unresolvable conflicts downgrade the edge
  to ping-pong (§VI's A-B-C-D example), preserving the upstream FIFO chain.

Degrees are realized on concrete loops respecting reuse.py's safety rings:
``reduction``/``free`` loops first (green — always legal), then ``fifo``
loops (orange — legal with peer coordination), never ``outer`` (red).

The same engine assigns **pipeline stages** (for the multi-chip pipeline
executor): contiguous topo segments balanced by scheduled latency.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from .buffers import BufferPlan, downgrade_to_pingpong
from .costmodel import V5E, GraphCost, HwParams, graph_latency, task_cost
from .graph import FIFO, DataflowGraph, Task
from .patterns import fine_violations
from .reuse import parallel_safety

N_BALANCE = 2.0          # the paper's empirically-set balancing threshold
UP_ITER_LIMIT = 32
_POW2 = [2 ** k for k in range(16)]

# Pipeline declaration consumed by passes.default_passes().
PASS_INFO = {
    "name": "schedule",
    "result_attr": "schedule_report",
    "option_flag": "scheduling",
    "invalidates": (),
    "description": "automated dataflow scheduling (PA/UP/DP + inter-task, §VI)",
}


@dataclass
class ScheduleReport:
    stage_latencies: dict[str, float] = field(default_factory=dict)   # after each stage
    degrees: dict[str, int] = field(default_factory=dict)
    propagated: list[str] = field(default_factory=list)
    downgraded: list[str] = field(default_factory=list)
    units_used: int = 0
    up_iters: int = 0

    def summary(self) -> str:
        lat = " -> ".join(f"{k}:{v:,.0f}" for k, v in self.stage_latencies.items())
        return (f"schedule: {lat}; units={self.units_used}, "
                f"{len(self.propagated)} propagated, {len(self.downgraded)} downgraded")

    # ---- JSON serialization (docs/artifact_format.md `schedule`) ---------
    def to_dict(self) -> dict:
        return {"stage_latencies": dict(self.stage_latencies),
                "degrees": dict(self.degrees),
                "propagated": list(self.propagated),
                "downgraded": list(self.downgraded),
                "units_used": self.units_used, "up_iters": self.up_iters}

    @classmethod
    def from_dict(cls, doc: dict) -> "ScheduleReport":
        # Canonical JSON sorts object keys, so restore the semantic
        # base → PA → UP → DP → final stage order on the way in.
        raw = doc.get("stage_latencies", {})
        order = [k for k in ("base", "PA", "UP", "DP", "final") if k in raw]
        order += [k for k in raw if k not in order]
        return cls(
            stage_latencies={k: float(raw[k]) for k in order},
            degrees={k: int(v) for k, v in doc.get("degrees", {}).items()},
            propagated=list(doc.get("propagated", ())),
            downgraded=list(doc.get("downgraded", ())),
            units_used=int(doc.get("units_used", 0)),
            up_iters=int(doc.get("up_iters", 0)))


# --------------------------------------------------------------------------
# Degree realization on loops
# --------------------------------------------------------------------------


def parallelizable_loops(task: Task) -> list:
    """Loops legal to parallelize, green ring first (Fig. 7 guidance)."""
    greens = [l for l in task.loops if parallel_safety(task, l.var) == "free"
              and l.trip > 1]
    oranges = [l for l in task.loops if parallel_safety(task, l.var) == "coordinate"
               and l.trip > 1]
    return greens + oranges


def max_task_degree(task: Task) -> int:
    cap = 1
    for l in parallelizable_loops(task):
        cap *= l.trip
    return cap


def apply_degree(task: Task, degree: int) -> int:
    """Distribute ``degree`` over legal loops innermost-first (powers of 2,
    clipped to trip counts).  Returns the realized degree."""
    for l in task.loops:
        l.parallel = 1
    remaining = max(1, degree)
    realized = 1
    for l in reversed(parallelizable_loops(task)):
        if remaining <= 1:
            break
        d = 1
        while d * 2 <= min(remaining, l.trip):
            d *= 2
        l.parallel = d
        realized *= d
        remaining //= d
    return realized


# --------------------------------------------------------------------------
# Stage 1: PA
# --------------------------------------------------------------------------


def initial_allocation(graph: DataflowGraph, hw: HwParams, budget: int,
                       max_degree: int) -> dict[str, int]:
    base = {t.name: task_cost(graph, t, hw).latency for t in graph.tasks}
    lmin = max(min(base.values()), 1.0)
    # proportional degrees, min 1 (paper: "in proportion to their latencies,
    # setting the smallest degree to 1")
    prop = {n: max(1.0, lat / lmin) for n, lat in base.items()}
    caps = {t.name: min(max_degree, max_task_degree(t)) for t in graph.tasks}

    # gradually scale up preserving ratios until the budget or caps bind
    def realized(scale: float) -> dict[str, int]:
        out = {}
        for n, p in prop.items():
            d = 2 ** int(math.floor(math.log2(max(1.0, p * scale))))
            out[n] = int(min(d, caps[n]))
        return out

    scale = 1.0
    best = realized(scale)
    # scale *down* first if the raw proportional allocation already blows
    # the budget (highly imbalanced graphs), preserving the ratios
    while sum(best.values()) > budget and scale > 2 ** -24:
        scale /= 2
        best = realized(scale)
    while True:
        trial = realized(scale * 2)
        if sum(trial.values()) > budget or trial == best:
            break
        best, scale = trial, scale * 2
        if scale > 2 ** 24:
            break
    return best


# --------------------------------------------------------------------------
# Stage 2 / 3: UP & DP
# --------------------------------------------------------------------------


def _evaluate(graph: DataflowGraph, degrees: dict[str, int], hw: HwParams,
              plan: BufferPlan | None) -> GraphCost:
    for t in graph.tasks:
        apply_degree(t, degrees[t.name])
    return graph_latency(graph, hw, plan)


def upscale(graph: DataflowGraph, degrees: dict[str, int], hw: HwParams,
            plan: BufferPlan | None, budget: int, max_degree: int,
            n: float = N_BALANCE) -> int:
    caps = {t.name: min(max_degree, max_task_degree(t)) for t in graph.tasks}
    iters = 0
    for iters in range(1, UP_ITER_LIMIT + 1):
        gc = _evaluate(graph, degrees, hw, plan)
        lat = {k: c.latency for k, c in gc.costs.items()}
        lmin = min(lat.values())
        # bottleneck loops at least n× slower than the fastest
        hot = sorted((k for k in lat if lat[k] >= n * lmin and
                      degrees[k] * 2 <= caps[k]),
                     key=lambda k: -lat[k])
        if not hot or sum(degrees.values()) >= budget:
            break
        k = hot[0]
        if sum(degrees.values()) - degrees[k] + degrees[k] * 2 > budget:
            break
        degrees[k] *= 2
    return iters


def downscale(graph: DataflowGraph, degrees: dict[str, int], hw: HwParams,
              plan: BufferPlan | None, n: float = N_BALANCE) -> None:
    changed = True
    while changed:
        changed = False
        gc = _evaluate(graph, degrees, hw, plan)
        lat = {k: c.latency for k, c in gc.costs.items()}
        lmax = max(lat.values())
        for k in sorted(lat, key=lambda k: lat[k]):
            if degrees[k] <= 1:
                continue
            if lat[k] * n <= lmax:
                # halving at most doubles this task's latency; legal while
                # it stays under the bottleneck
                if lat[k] * 2.0 <= lmax:
                    degrees[k] //= 2
                    changed = True


# --------------------------------------------------------------------------
# Inter-task optimization (§VI last part)
# --------------------------------------------------------------------------


def _edge_dim_peer(graph: DataflowGraph, p: Task, buf: str, c: Task
                   ) -> list[tuple[str, str]]:
    """(producer_var, consumer_var) pairs driving the same buffer dim."""
    w = p.writes_to(buf)[0]
    r = c.reads_from(buf)[0]
    pairs = []
    for dw, dr in zip(w.index, r.index):
        pv = [v for (v, _s) in dw if p.has_loop(v) and p.loop(v).trip > 1]
        cv = [v for (v, _s) in dr if c.has_loop(v) and c.loop(v).trip > 1]
        if len(pv) == 1 and len(cv) == 1:
            pairs.append((pv[0], cv[0]))
    return pairs


def propagate_intertask(graph: DataflowGraph, plan: BufferPlan,
                        report: ScheduleReport, budget: int | None = None
                        ) -> None:
    """Propagate fifo-loop parallel degrees across FIFO edges; downgrade on
    conflict.  The *bottleneck* side of the edge keeps its degree and the
    peer adopts it — coordination must never de-parallelize the critical
    task (raising a cheap peer costs few units; report records overruns)."""
    from .costmodel import task_cost

    for _round in range(8):
        changed = False
        for p, buf, c in graph.internal_edges():
            if plan.impl.get(buf) != FIFO:
                continue
            for pv, cv in _edge_dim_peer(graph, p, buf, c):
                pl, cl = p.loop(pv), c.loop(cv)
                if pl.ring != "fifo" and cl.ring != "fifo":
                    continue
                if pl.parallel == cl.parallel:
                    continue
                bottleneck_is_p = (task_cost(graph, p).latency
                                   >= task_cost(graph, c).latency)
                target = pl.parallel if bottleneck_is_p else cl.parallel
                for (t, l) in ((p, pl), (c, cl)):
                    if l.parallel == target:
                        continue
                    if parallel_safety(t, l.var) == "unsafe" or target > l.trip:
                        downgrade_to_pingpong(graph, plan, buf,
                                              f"inter-task conflict on {l.var}")
                        report.downgraded.append(buf)
                        break
                else:
                    pl.parallel = cl.parallel = target
                    report.propagated.append(f"{buf}:{pv}->{cv}={target}")
                    changed = True
        if not changed:
            break


# --------------------------------------------------------------------------
# Pipeline-stage assignment (feeds core/pipeline.py)
# --------------------------------------------------------------------------


def assign_stages(graph: DataflowGraph, hw: HwParams, num_stages: int) -> list[list[str]]:
    """Contiguous topo segments with balanced scheduled latency."""
    order = graph.toposort()
    lats = [task_cost(graph, t, hw).latency for t in order]
    total = sum(lats)
    target = total / max(1, num_stages)
    stages: list[list[str]] = [[] for _ in range(num_stages)]
    acc, si = 0.0, 0
    for t, lat in zip(order, lats):
        if acc > target * (si + 1) and si < num_stages - 1:
            si += 1
        stages[si].append(t.name)
        t.stage = si
        acc += lat
    return stages


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def autoschedule(graph: DataflowGraph, plan: BufferPlan | None = None,
                 hw: HwParams = V5E, budget: int | None = None,
                 max_degree: int = 4096, n: float = N_BALANCE,
                 enable_up: bool = True, enable_dp: bool = True,
                 ) -> ScheduleReport:
    budget = budget if budget is not None else hw.max_units
    report = ScheduleReport()

    base = _evaluate(graph, {t.name: 1 for t in graph.tasks}, hw, plan)
    report.stage_latencies["base"] = base.total_cycles

    degrees = initial_allocation(graph, hw, budget, max_degree)
    pa = _evaluate(graph, degrees, hw, plan)
    report.stage_latencies["PA"] = pa.total_cycles

    if enable_up:
        report.up_iters = upscale(graph, degrees, hw, plan, budget, max_degree, n)
        up = _evaluate(graph, degrees, hw, plan)
        report.stage_latencies["UP"] = up.total_cycles

    if enable_dp:
        downscale(graph, degrees, hw, plan, n)
        dp = _evaluate(graph, degrees, hw, plan)
        report.stage_latencies["DP"] = dp.total_cycles

    if plan is not None:
        propagate_intertask(graph, plan, report, budget)
        # re-run correctness detection after structural changes (§VI:
        # "reinvoke our correctness passes")
        leftover = fine_violations(graph)
        for v in leftover:
            if plan.impl.get(v.buffer) == FIFO:
                downgrade_to_pingpong(graph, plan, v.buffer,
                                      f"post-schedule violation {v.kind}")
                report.downgraded.append(v.buffer)

    final = graph_latency(graph, hw, plan)
    report.stage_latencies["final"] = final.total_cycles
    report.degrees = {t.name: max(1, int(__import__('numpy').prod([l.parallel for l in t.loops])))
                      for t in graph.tasks}
    report.units_used = sum(report.degrees.values())
    return report
