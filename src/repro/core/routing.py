"""Pattern-directed kernel routing for fusion groups (paper §VII-C).

The lowering forms *fusion groups* — maximal FIFO-connected task sets whose
intermediates never round-trip through HBM.  This module decides **which
implementation executes each group**: a hand-written Pallas streaming
kernel when the group contains a producer→consumer chain matching a
registered :class:`KernelPattern`, the generic ``xla-fused`` composition
otherwise.  HIDA and FLOWER both map fused dataflow nodes onto specialized
implementations the same way — pattern match first, fall back second —
and it is where the measured latency wins come from.

Pattern language
----------------

A pattern is a tuple of items matched against a *chain* of tasks (each
task's output feeding exactly the next task) inside one fusion group:

* ``"matmul"``   — exactly one task whose ``Task.op`` is ``matmul``;
* ``"*ewise"``   — zero or more consecutive ``ewise`` tasks (wildcard).

``("matmul", "*ewise", "matmul")`` therefore matches a bare matmul→matmul
chain as well as matmul→gelu→matmul.  Chains must be exclusive: every
interior buffer is single-consumer and not a graph output, so replacing
the matched tasks with one kernel step that emits only the final buffer
is always sound.  Interior edges may be FIFO *or* ping-pong: a ping-pong
edge means the generic path must materialize the intermediate in HBM
(broadcast/stencil re-read), and absorbing that round-trip into the
kernel's VMEM working set is exactly the §VII-C win.

Feasibility
-----------

Matching is structural; whether a *specific* group instance can use the
kernel (shapes, dtypes, strides, VMEM footprint) is the pattern's
``feasible(graph, tasks)`` guard — pure graph analysis, so this module
stays importable without jax (the artifact exporter records routing
decisions jax-free).  The ``factory(graph, group, tasks)`` that builds the
executable step is only called from the lowering and may import jax.

Cost gate (ISSUE 6)
-------------------

A structural match is necessary but not sufficient: each matched chain is
priced both ways by the cost model (:func:`repro.core.costmodel.
estimate_chain`) and routed only on predicted win — small chains whose
dispatch overhead would dominate, and patterns the calibration says lose
on this backend (the CPU softmaxmm tail), fall back to generic XLA.  A
measured :class:`~repro.core.tuning.TuningDB` verdict beats the
predictor when one exists for the chain's structural signature.

Escape hatches
--------------

``CODO_DISABLE_PALLAS=1`` disables all routing — every group falls back
to ``xla-fused``.  ``CODO_FORCE_PALLAS=1`` routes every structural match
regardless of the gate's prediction (disable wins over force).  Both
flags — plus the registry epoch, the backend, the calibration digest,
and the tuning-DB digest — enter the lowering memo key via
:func:`routing_state_key`, so toggling any of them never serves a stale
program.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from .graph import DataflowGraph, Task

XLA_FUSED = "xla-fused"
WILDCARD = "*"


def _truthy(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in (
        "1", "true", "yes", "on")


def pallas_disabled() -> bool:
    """The ``CODO_DISABLE_PALLAS`` escape hatch: truthy values route every
    fusion group to the generic ``xla-fused`` path."""
    return _truthy("CODO_DISABLE_PALLAS")


def pallas_forced() -> bool:
    """The ``CODO_FORCE_PALLAS`` override: truthy values route every
    structural match regardless of the cost gate's prediction (useful for
    A/B measurement and for exercising kernels on shapes the gate would
    reject).  :func:`pallas_disabled` wins when both are set."""
    return _truthy("CODO_FORCE_PALLAS")


def pallas_interpret_forced() -> bool:
    """``CODO_PALLAS_INTERPRET=1`` forces routed kernels to run the real
    Pallas body in interpret mode on non-TPU hosts (the CI numerics path).
    Routing-relevant: enters the lowering memo key like the disable flag."""
    return _truthy("CODO_PALLAS_INTERPRET")


@dataclass(frozen=True)
class KernelPattern:
    """One routable kernel: a name, an op pattern, a jax-free feasibility
    guard, and a factory building the executable step.

    ``factory(graph, group, tasks)`` returns an ``env -> {out: array}``
    callable (it may import jax lazily); returning ``None`` declines the
    match at build time (treated like an infeasible guard).  Patterns
    whose kernels take tile/block parameters declare a ``tiles(graph,
    tasks)`` candidate enumerator and accept the winning candidate as a
    ``tile=`` keyword on the factory (``None`` = kernel default).
    """

    name: str
    pattern: tuple[str, ...]
    factory: Callable[[DataflowGraph, Any, list[Task]], Callable | None]
    feasible: Callable[[DataflowGraph, list[Task]], bool] | None = None
    description: str = ""
    tiles: Callable[[DataflowGraph, list[Task]], list[dict | None]] | None = None
    # Recurrence kernels (rglru/ssd chunked scans) replace ONE task whose
    # generic form is sequential — for them a single-task "chain" is the
    # whole point, so they opt out of the >=2-task floor below.
    allow_single: bool = False

    def __post_init__(self):
        if not self.pattern:
            raise ValueError(f"pattern {self.name!r} is empty")
        if self.pattern[0].startswith(WILDCARD):
            raise ValueError(
                f"pattern {self.name!r} cannot start with a wildcard item "
                f"({self.pattern[0]!r}) — anchors would be ambiguous")


@dataclass
class RoutedKernel:
    """One gate decision inside a fusion group: a structurally matched
    chain, the cost model's verdict on it, and — when the decision came
    from the tuning database — the measured numbers.  Chains whose
    ``decision`` is in :data:`ROUTED_DECISIONS` execute as the registered
    kernel; the rest stay on the generic path (recorded for the
    diagnostics/--profile predicted-vs-measured table)."""

    kernel: str                  # KernelPattern.name
    tasks: list[str]             # matched chain, dataflow order
    decision: str = "predicted-win"
    predicted_routed_cycles: float = 0.0
    predicted_generic_cycles: float = 0.0
    tile: dict | None = None     # tuned blocking (None = kernel default)
    measured_speedup: float | None = None   # generic/routed, tuning DB

    @property
    def routed(self) -> bool:
        return self.decision in ROUTED_DECISIONS

    def to_dict(self) -> dict:
        out = {"kernel": self.kernel, "tasks": list(self.tasks),
               "decision": self.decision,
               "predicted_routed_cycles": round(
                   self.predicted_routed_cycles, 1),
               "predicted_generic_cycles": round(
                   self.predicted_generic_cycles, 1)}
        if self.tile is not None:
            out["tile"] = dict(self.tile)
        if self.measured_speedup is not None:
            out["measured_speedup"] = round(self.measured_speedup, 4)
        return out


# Decisions that put a chain on the kernel path; everything else
# ("predicted-loss", "tuned-generic") stays generic.
ROUTED_DECISIONS = frozenset({"predicted-win", "forced", "tuned"})


# --------------------------------------------------------------------------
# Registry.  Ordered by registration; first matching pattern wins at every
# anchor task.  The epoch bumps on every (re-)registration so memoized
# lowerings built against an older registry are never served.
# --------------------------------------------------------------------------

_PATTERNS: dict[str, KernelPattern] = {}
_EPOCH = 0
_WIRED = False


def ensure_kernel_patterns() -> None:
    """Best-effort one-time registration of the shipped kernel patterns
    (``repro.kernels.register_all``).  Called by every routing consumer —
    lowering, ``route_plan``, the artifact exporter/importer — so the
    compiler, not the user, wires the kernels in.  jax-less environments
    degrade to an empty registry (everything ``xla-fused``)."""
    global _WIRED
    if _WIRED:
        return
    _WIRED = True
    try:
        from .. import kernels
        kernels.register_all()
    except ImportError:                      # pragma: no cover — stub builds
        pass


def register_kernel_pattern(pattern: KernelPattern) -> KernelPattern:
    global _EPOCH
    _PATTERNS[pattern.name] = pattern
    _EPOCH += 1
    return pattern


def registered_patterns() -> list[KernelPattern]:
    return list(_PATTERNS.values())


def routing_epoch() -> int:
    return _EPOCH


def clear_kernel_patterns() -> None:
    """Testing hook: drop every registered pattern (bumps the epoch so
    memoized lowerings notice)."""
    global _EPOCH
    _PATTERNS.clear()
    _EPOCH += 1


# --------------------------------------------------------------------------
# Matching
# --------------------------------------------------------------------------


def _sole_output(task: Task) -> str | None:
    outs = {a.buffer for a in task.writes}
    return next(iter(outs)) if len(outs) == 1 else None


def _chain_next(graph: DataflowGraph, members: set[str], impl: dict[str, str],
                task: Task) -> Task | None:
    """The unique task that streams ``task``'s output onward, or ``None``.

    The edge qualifies only when the intermediate can disappear into a
    kernel: one output buffer, not a graph output, read by exactly one
    consumer — which must be in the same group.  The buffer's planned impl
    does not matter: a FIFO intermediate folds into the kernel's VMEM
    stream, and a ping-pong one (a broadcast/stencil re-read the generic
    path must materialize in HBM) is absorbed by the kernel's on-chip
    working set — that HBM round-trip removed is where the kernel wins
    (e.g. the softmax·matmul tail never materializes the probabilities).
    """
    buf = _sole_output(task)
    if buf is None:
        return None
    if graph.buffers[buf].kind == "output":
        return None
    consumers = graph.consumers(buf)
    if len(consumers) != 1 or consumers[0].name not in members:
        return None
    return consumers[0]


def _match_chain(graph: DataflowGraph, members: set[str],
                 impl: dict[str, str], start: Task,
                 pattern: Sequence[str]) -> list[Task] | None:
    """Match ``pattern`` against the chain anchored at ``start``.

    Wildcard items are greedy with backtracking-by-construction: a
    ``"*op"`` consumes chain tasks of that op until the next literal item
    matches (the wildcard op and the following literal op are distinct in
    every registered pattern, so greediness is exact, not heuristic).
    """
    matched: list[Task] = []
    cur: Task | None = start
    items = list(pattern)
    for i, item in enumerate(items):
        if item.startswith(WILDCARD):
            want = item[1:]
            while cur is not None and cur.op == want:
                matched.append(cur)
                cur = _chain_next(graph, members, impl, cur)
            continue
        if cur is None or cur.op != item:
            return None
        matched.append(cur)
        if i + 1 < len(items):
            cur = _chain_next(graph, members, impl, cur)
    if len(matched) > 1 and not _chain_is_exclusive(matched):
        return None
    return matched


def _chain_is_exclusive(tasks: list[Task]) -> bool:
    """Interior buffers must reach each successor only as its single
    streamed (chain) operand.  A task that reads the chain value through
    a *second* operand slot (``p @ p``) or reaches back to an earlier
    interior buffer cannot be replaced by a kernel that never emits the
    interiors — the generic path handles those graphs instead."""
    outs = [_sole_output(t) for t in tasks[:-1]]
    interior = set(outs)
    for i, t in enumerate(tasks):
        chain_in = outs[i - 1] if i > 0 else None
        reads = [a.buffer for a in t.reads]
        if chain_in is not None and reads.count(chain_in) > 1:
            return False
        if any(b in interior and b != chain_in for b in reads):
            return False
    return True


def match_group(graph: DataflowGraph, group_tasks: Sequence[str],
                impl: dict[str, str], *,
                patterns: Sequence[KernelPattern] | None = None,
                ) -> list[tuple[KernelPattern, list[Task]]]:
    """All non-overlapping pattern matches inside one fusion group.

    Two phases, purely structural (no jax, no kernel construction):
    every (anchor, pattern) pair is first matched independently, then
    candidates claim tasks **longest chain first** (ties: anchor topo
    order, then pattern registration order).  Longest-first is what lets
    a wide pattern supersede narrower ones over the same tasks — e.g.
    ``flashattn.mha`` takes ``matmul→scale→softmax→matmul`` whole even
    though ``streamfuse.mmchain`` could claim the score matmul from the
    projection anchor and ``streamfuse.softmaxmm`` could claim the tail.
    The result is returned in anchor topo order.
    """
    pats = list(patterns) if patterns is not None else registered_patterns()
    if not pats:
        return []
    members = set(group_tasks)
    candidates: list[tuple[int, int, int, KernelPattern, list[Task]]] = []
    for a_idx, name in enumerate(group_tasks):
        anchor = graph.task(name)
        for p_idx, pat in enumerate(pats):
            tasks = _match_chain(graph, members, impl, anchor, pat.pattern)
            min_len = 1 if pat.allow_single else 2
            if not tasks or len(tasks) < min_len:
                continue            # single-task "chains" stay with XLA
            if pat.feasible is not None and not pat.feasible(graph, tasks):
                continue
            candidates.append((-len(tasks), a_idx, p_idx, pat, tasks))
    claimed: set[str] = set()
    out: list[tuple[int, KernelPattern, list[Task]]] = []
    for neg_len, a_idx, _p_idx, pat, tasks in sorted(
            candidates, key=lambda c: c[:3]):
        if any(t.name in claimed for t in tasks):
            continue
        claimed.update(t.name for t in tasks)
        out.append((a_idx, pat, tasks))
    return [(pat, tasks) for _a, pat, tasks in sorted(out,
                                                      key=lambda c: c[0])]


def decide_route(graph: DataflowGraph, tasks: list[Task],
                 pattern: KernelPattern, *, hw=None, params=None,
                 db=None) -> RoutedKernel:
    """The cost gate for one structurally matched chain.

    Precedence: a measured :class:`~repro.core.tuning.TuningDB` entry for
    the chain's signature on this backend/hardware (``tuned`` /
    ``tuned-generic``), then the ``CODO_FORCE_PALLAS`` override
    (``forced``), then the predictor (``predicted-win`` /
    ``predicted-loss``).  The predicted cycles are recorded on the result
    either way.
    """
    from .costmodel import V5E, estimate_chain, routing_backend
    from .tuning import chain_signature, default_tuning_db
    hw = hw if hw is not None else V5E
    est = estimate_chain(graph, tasks, pattern.name, hw, params)
    route = RoutedKernel(pattern.name, [t.name for t in tasks],
                         predicted_routed_cycles=est.routed_cycles,
                         predicted_generic_cycles=est.generic_cycles)
    if db is None:
        db = default_tuning_db()
    rec = db.lookup(chain_signature(graph, tasks), routing_backend(), hw.name)
    if rec is not None:
        route.decision = "tuned" if rec.choice == "pallas" else "tuned-generic"
        route.tile = dict(rec.tile) if rec.tile else None
        route.measured_speedup = rec.speedup
    elif pallas_forced():
        route.decision = "forced"
    else:
        route.decision = "predicted-win" if est.win else "predicted-loss"
    return route


def route_groups(graph: DataflowGraph, groups, impl: dict[str, str], *,
                 enabled: bool | None = None, hw=None, params=None,
                 db=None) -> None:
    """Annotate each :class:`~repro.core.lowering.FusionGroup` in
    ``groups`` with its routing decision: cost-gate-accepted chains in
    ``routes``, gate-rejected structural matches in ``rejected``, and the
    group-level predicted cycles both ways.

    ``enabled=None`` consults :func:`pallas_disabled`.  jax-free: only the
    lowering turns the resulting decisions into executable steps.
    """
    from .costmodel import V5E, routing_params, task_cost
    if enabled is None:
        enabled = not pallas_disabled()
    if params is None and enabled:
        params = routing_params()
    hw_ = hw if hw is not None else V5E
    for g in groups:
        g.routes, g.rejected = [], []
        g.kernel = XLA_FUSED
        g.decision = "disabled" if not enabled else "generic"
        chained: set[str] = set()
        if enabled and g.tasks:
            for pat, tasks in match_group(graph, g.tasks, impl):
                route = decide_route(graph, tasks, pat, hw=hw,
                                     params=params, db=db)
                (g.routes if route.routed else g.rejected).append(route)
                if route.routed:
                    chained.update(route.tasks)
        # Group-level estimate: unmatched/rejected tasks run generically
        # on both sides; accepted chains contribute their two estimates.
        rest = sum(task_cost(graph, graph.task(n), hw_).latency
                   for n in g.tasks if n not in chained)
        g.predicted_generic_cycles = rest + sum(
            r.predicted_generic_cycles for r in g.routes)
        g.predicted_routed_cycles = rest + sum(
            r.predicted_routed_cycles for r in g.routes)
        if g.routes:
            g.kernel = "pallas:" + "+".join(r.kernel for r in g.routes)
            g.decision = "routed"


def route_plan(graph: DataflowGraph, impl: dict[str, str], *,
               enabled: bool | None = None, hw=None, params=None,
               db=None) -> list[dict]:
    """The per-group routing table for a compiled design, as plain data
    (what the artifact exporter and the CLI ``--profile`` report).  Group
    membership mirrors ``lowering.fusion_groups`` without mutating task
    ``fused_group`` ids; the cost gate (and tuning DB) apply exactly as in
    :func:`route_groups`."""
    from .artifact import _fifo_groups  # jax-free, same grouping
    from .costmodel import routing_params
    ensure_kernel_patterns()
    if enabled is None:
        enabled = not pallas_disabled()
    if params is None and enabled:
        params = routing_params()
    plan = []
    for gid, names in enumerate(_fifo_groups(graph, impl)):
        routes: list[RoutedKernel] = []
        rejected: list[RoutedKernel] = []
        if enabled and names:
            for pat, tasks in match_group(graph, names, impl):
                route = decide_route(graph, tasks, pat, hw=hw,
                                     params=params, db=db)
                (routes if route.routed else rejected).append(route)
        kernel = ("pallas:" + "+".join(r.kernel for r in routes)
                  if routes else XLA_FUSED)
        plan.append({"gid": gid, "tasks": list(names), "kernel": kernel,
                     "routes": [r.to_dict() for r in routes],
                     "rejected": [r.to_dict() for r in rejected]})
    return plan


def routing_state_key() -> tuple:
    """Every process-global switch a routing decision can depend on — the
    lowering memo key ingredient.  Covers the disable/force escape
    hatches, the pattern-registry epoch, the priced backend, the active
    calibration constants, and the tuning-database contents: flipping any
    of them must never serve a stale program."""
    from .costmodel import routing_backend, routing_params
    from .tuning import default_tuning_db
    backend = routing_backend()
    return (pallas_disabled(), pallas_forced(), routing_epoch(), backend,
            routing_params(backend).digest(), default_tuning_db().digest())


__all__ = ["KernelPattern", "ROUTED_DECISIONS", "RoutedKernel", "XLA_FUSED",
           "clear_kernel_patterns", "decide_route", "ensure_kernel_patterns",
           "match_group", "pallas_disabled", "pallas_forced",
           "pallas_interpret_forced", "register_kernel_pattern",
           "registered_patterns", "route_groups", "route_plan",
           "routing_epoch", "routing_state_key"]
