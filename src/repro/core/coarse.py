"""Coarse-grained dataflow-violation elimination (paper §IV-A, Alg. 1, Fig. 4).

HLS dataflow regions (and, equally, fusable streaming kernels on TPU)
require every internal buffer to have exactly one producer and one
consumer.  This pass rewrites the graph until that invariant holds:

* **SPMC** (Fig. 4a, residual/bypass patterns): insert a duplicator node
  ``Node1'`` that reads the buffer once and streams one private copy per
  consumer.
* **MPSC** (Fig. 4b, init/pad pairs): fuse the producers into one node
  (merge semantics — earlier writes are staged and merged into the last
  write), or serialize through a merge node when fusion is illegal.
* **MPMC** (Fig. 4c): fuse/merge the producers first, then the remaining
  SPMC is handled by duplication on the next fixpoint iteration.

All rewrites keep numeric semantics intact declaratively: duplicators are
``OpSpec("dup")`` nodes, fused producers are ``OpSpec("fused")`` composites
of the producers' specs, and consumer rewires are pure-data operand renames
(:meth:`repro.core.graph.Task.retarget`).  Tasks carrying raw closures fall
back to the legacy env-aliasing shims — correct, but such graphs lose
executability at pickle boundaries (disk cache, process pools).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .graph import Access, Buffer, DataflowGraph, Loop, Task, full_index
from .ops import OpSpec
from .patterns import MPMC, MPSC, SPMC, coarse_violations

_MAX_ITERS = 64

# Pipeline declaration consumed by passes.default_passes().
PASS_INFO = {
    "name": "coarse",
    "result_attr": "coarse_report",
    "option_flag": "coarse",
    "invalidates": (),
    "description": "coarse-grained violation elimination (Alg. 1, Fig. 4)",
}


@dataclass
class CoarseReport:
    duplicators_inserted: list[str] = field(default_factory=list)
    fusions: list[str] = field(default_factory=list)
    merges: list[str] = field(default_factory=list)
    iterations: int = 0

    def merge(self, other: "CoarseReport") -> "CoarseReport":
        """Fold a re-run's report into this one (invalidation re-runs)."""
        self.duplicators_inserted += other.duplicators_inserted
        self.fusions += other.fusions
        self.merges += other.merges
        self.iterations += other.iterations
        return self

    def summary(self) -> str:
        return (f"coarse: {len(self.duplicators_inserted)} duplicators, "
                f"{len(self.fusions)} fusions, {len(self.merges)} merges "
                f"({self.iterations} iters)")


# --------------------------------------------------------------------------
# SPMC: duplicator insertion (Fig. 4a)
# --------------------------------------------------------------------------


def _insert_duplicator(graph: DataflowGraph, buffer: str, report: CoarseReport) -> None:
    buf = graph.buffers[buffer]
    consumers = graph.consumers(buffer)
    producers = graph.producers(buffer)

    # Duplicator loop order follows the producer's write arrival order so
    # the producer→duplicator edge is FIFO-clean by construction.
    dims = [f"d{k}" for k in range(len(buf.shape))]
    if producers:
        w = producers[0].writes_to(buffer)[0]
        trips = {l.var: l.trip for l in producers[0].loops}
        order = []
        for i, dim in enumerate(w.index):
            live = [v for (v, _s) in dim if trips.get(v, 1) > 1]
            d = (min(producers[0].loop_depth(v) for v in live)
                 if live else len(producers[0].loops) + i)
            order.append((d, i))
        order.sort()
        loop_dims = [dims[i] for (_d, i) in order]
    else:
        loop_dims = list(dims)
    loops = [Loop(d, int(buf.shape[dims.index(d)])) for d in loop_dims]

    copies = []
    for k, c in enumerate(consumers):
        dup_name = f"{buffer}__dup{k}"
        graph.add_buffer(Buffer(dup_name, buf.shape, buf.dtype, "intermediate"))
        copies.append((c, dup_name))

    node = Task(
        name=f"dup_{buffer}",
        loops=loops,
        reads=[Access(buffer, full_index(dims), False)],
        writes=[Access(d, full_index(dims), True) for (_c, d) in copies],
        op="copy",
        flops_per_iter=0.0,
        spec=OpSpec("dup", (buffer,), tuple(d for (_c, d) in copies)),
    )
    node.tags.add("coarse-duplicator")
    graph.add_task(node)
    report.duplicators_inserted.append(node.name)

    # Rewire each consumer to its private copy (pure data rename).
    for c, dup_name in copies:
        for a in c.reads:
            if a.buffer == buffer:
                a.buffer = dup_name
        c.retarget({buffer: dup_name})


# --------------------------------------------------------------------------
# MPSC: producer fusion / merge (Fig. 4b)
# --------------------------------------------------------------------------


def _outer_domain(task: Task, buffer: str) -> tuple:
    """(trip,...) of the loops indexing the written buffer — the 'outer
    iteration domain' fusion legality test of §IV-A."""
    w = task.writes_to(buffer)[0]
    vars_ = w.vars()
    return tuple(l.trip for l in task.loops if l.var in vars_)


def _has_carried_dep(producers: list[Task], buffer: str) -> bool:
    """A later producer reading the same buffer it writes (accumulation)
    is a loop-carried dependency across the fusion candidates."""
    for t in producers[1:]:
        if t.reads_from(buffer):
            return True
    return False


def _fuse_producers(graph: DataflowGraph, buffer: str, report: CoarseReport) -> None:
    producers = [t for t in graph.toposort() if t.writes_to(buffer)]
    fusable = (
        len({_outer_domain(t, buffer) for t in producers}) == 1
        and not _has_carried_dep(producers, buffer)
    )

    last = producers[-1]
    name = f"fuse_{buffer}"

    # Declarative fusion when every producer is spec-carrying; otherwise a
    # closure composition (which strips at pickle boundaries).
    fused_spec = fused_fn = None
    if all(t.spec is not None and not t.fn_is_closure for t in producers):
        fused_spec = OpSpec("fused", parts=tuple(t.spec for t in producers))
    else:
        fns = tuple(t.fn for t in producers)

        def fused_fn(env, _fns=fns):
            out: dict = {}
            scope = dict(env)
            for f in _fns:
                r = f(scope)
                scope.update(r)
                out.update(r)
            return out

    # Representative loop nest: the last writer's (the merge target).  Reads
    # are the union of all producers' reads minus the fused buffer itself.
    reads, seen = [], set()
    for t in producers:
        for a in t.reads:
            if a.buffer == buffer:
                continue  # staged internally ("temporarily stored ... merged")
            key = (a.buffer, a.index)
            if key not in seen:
                seen.add(key)
                reads.append(a.copy())
    writes, wseen = [], set()
    for t in producers:
        for a in t.writes:
            key = a.buffer
            if key not in wseen:
                wseen.add(key)
                writes.append(a.copy())

    fused = Task(
        name=name,
        loops=[l.copy() for l in last.loops],
        reads=reads,
        writes=writes,
        op=last.op,
        flops_per_iter=sum(t.flops for t in producers) / max(1, last.total_iters),
        fn=fused_fn,
        spec=fused_spec,
    )
    fused.tags.add("coarse-fused")
    if not fusable:
        # Differing inner structure / carried deps: the paper inserts extra
        # control logic; we keep the fused node but flag it so the scheduler
        # treats it as non-parallelizable on the merged dims.
        fused.tags.add("fused-control")
        report.merges.append(name)
    else:
        report.fusions.append(name)

    for t in producers:
        graph.remove_task(t.name)
    graph.add_task(fused)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def eliminate_coarse(graph: DataflowGraph) -> CoarseReport:
    """Fixpoint application of Alg. 1 over all buffers."""
    report = CoarseReport()
    for it in range(_MAX_ITERS):
        violations = coarse_violations(graph)
        report.iterations = it
        if not violations:
            break
        v = violations[0]
        if v.kind == SPMC:
            _insert_duplicator(graph, v.buffer, report)
        elif v.kind in (MPSC, MPMC):
            _fuse_producers(graph, v.buffer, report)
            # MPMC becomes SPMC after producer fusion; next iteration
            # inserts the duplicator.
        graph.validate()
    else:
        raise RuntimeError(f"coarse elimination did not converge on {graph.name}")
    return report
