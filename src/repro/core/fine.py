"""Fine-grained dataflow-violation elimination (paper §IV-B, Figs. 5-6).

Two transformations make producer/consumer streams FIFO-compatible:

1. **Reduction operation rewriting** (Fig. 5) — when a write (or read)
   sits inside loops that do not appear in its index ("reduction dims"),
   the element is touched once per reduction iteration: an access-count
   mismatch that deadlocks a FIFO.  The rewrite (a) moves reduction dims
   innermost, (b) accumulates into a temporary, and (c) emits the FIFO
   access exactly once per element *as early as possible* — in IR terms the
   access's ``enclosing`` set shrinks to its index dims.  On TPU this is
   precisely the VMEM-scratch accumulator of a blocked matmul / online
   softmax: the k-loop accumulates in registers/VMEM and the tile is
   emitted once.

2. **Permutation map generation** (Fig. 6) — when producer and consumer
   stream the same elements in different orders, the *reference* loop (the
   compute-bottleneck task) keeps its order and the *target* loop is
   permuted to match, via a dim→depth map on both sides (Steps 1-4).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .graph import DataflowGraph, Task
from .patterns import (BROADCAST_REREAD, MULTI_WRITE, ORDER_MISMATCH,
                       access_sig, arrival_order, fine_violations,
                       index_dims, reduction_dims)

_MAX_ITERS = 200

# Pipeline declaration consumed by passes.default_passes().
PASS_INFO = {
    "name": "fine",
    "result_attr": "fine_report",
    "option_flag": "fine",
    "invalidates": (),
    "description": "fine-grained violation elimination (Figs. 5-6)",
}


@dataclass
class PermutationMap:
    """Fig. 6's depth→depth map, recorded for the report/tests."""

    target: str
    reference: str
    buffer: str
    depth_map: dict[int, int]


@dataclass
class FineReport:
    reductions_rewritten: list[str] = field(default_factory=list)
    permutations: list[PermutationMap] = field(default_factory=list)
    unresolved: list[str] = field(default_factory=list)
    iterations: int = 0

    def merge(self, other: "FineReport") -> "FineReport":
        """Fold a re-run's report into this one.  A re-run happens when a
        later pass (reuse) invalidates fine's guarantees; the re-run's
        ``unresolved`` list is the authoritative final state."""
        self.reductions_rewritten += other.reductions_rewritten
        self.permutations += other.permutations
        self.unresolved = other.unresolved
        self.iterations += other.iterations
        return self

    def summary(self) -> str:
        return (f"fine: {len(self.reductions_rewritten)} reductions rewritten, "
                f"{len(self.permutations)} loops permuted, "
                f"{len(self.unresolved)} unresolved ({self.iterations} iters)")


# --------------------------------------------------------------------------
# 1) Reduction operation rewriting (Fig. 5)
# --------------------------------------------------------------------------


def rewrite_reduction_write(task: Task, buffer: str) -> bool:
    """Hoist the write to ``buffer`` out of its reduction dims."""
    w = task.writes_to(buffer)[0]
    red = reduction_dims(task, w)
    if not red:
        return False
    idx = set(index_dims(task, w))
    red_set = set(red)
    # (a) index dims keep relative order and move outward; reduction dims
    #     move innermost (the shaded region of Fig. 5).
    task.loops = ([l for l in task.loops if l.var in idx]
                  + [l for l in task.loops if l.var not in idx and l.var not in red_set]
                  + [l for l in task.loops if l.var in red_set])
    for l in task.loops:
        if l.var in red_set:
            l.ring = "reduction"
    # (b)+(c): accumulate into a temp, emit once per element, just-in-time.
    w.enclosing = tuple(index_dims(task, w))
    task.reuse_buffers.setdefault(f"acc_{buffer}", (1,))
    task.reduction_rewritten = True
    task.tags.add("reduction-rewritten")
    return True


def rewrite_reduction_read(task: Task, buffer: str) -> bool:
    """Dual of the write rewrite: a consumer that re-reads each element
    across reduction dims is rewritten to read once into a temporary and
    reuse it (the Fig. 5 consumer side / broadcast-operand caching)."""
    r = task.reads_from(buffer)[0]
    red = reduction_dims(task, r)
    if not red:
        return False
    r.enclosing = tuple(index_dims(task, r))
    task.reuse_buffers.setdefault(f"cache_{buffer}", (1,))
    task.tags.add("read-cached")
    return True


# --------------------------------------------------------------------------
# 2) Permutation map generation (Fig. 6)
# --------------------------------------------------------------------------


def _intensity(task: Task) -> float:
    """Reference-loop selection metric: trip counts × computational
    intensity (§IV-B-2)."""
    return task.flops + 0.001 * task.total_iters


def _driver_var(task: Task, dim) -> str | None:
    trips = {l.var: l.trip for l in task.loops}
    live = [v for (v, _s) in dim if trips.get(v, 1) > 1]
    if not live:
        return None
    return min(live, key=lambda v: task.loop_depth(v))


def generate_permutation(graph: DataflowGraph, reference: Task, target: Task,
                         buffer: str) -> PermutationMap | None:
    """Permute ``target``'s loop nest so its access order on ``buffer``
    matches ``reference``'s (Fig. 6 Steps 1-4)."""
    ref_acc = (reference.writes_to(buffer) or reference.reads_from(buffer))[0]
    tgt_acc = (target.writes_to(buffer) or target.reads_from(buffer))[0]

    # Step 1: dim -> loop-depth maps on both sides.
    ref_order = arrival_order(reference, ref_acc)      # array dims, arrival order
    tgt_drivers = {}
    for i, dim in enumerate(tgt_acc.index):
        v = _driver_var(target, dim)
        if v is not None:
            tgt_drivers[i] = v
    # Step 2 (tiling size 1 to align depth sets) is an identity on trip
    # counts; the depth alignment falls out of re-sorting below.
    desired = [tgt_drivers[i] for i in ref_order if i in tgt_drivers]
    if len(set(desired)) != len(desired):
        return None  # one var drives two dims: not a pure permutation
    red = {l.var for l in target.loops if l.ring == "reduction"}
    if red & set(desired):
        # A rewritten reduction (Fig. 5) keeps its reduction dims innermost
        # — the hoisted write emits each element once after the accumulator
        # drains.  Hoisting such a loop outward to chase a neighbour's
        # stream order would silently undo that rewrite (backward graphs
        # hit this: weight-grad matmuls contract over the sequence dim and
        # ask their operands for a genuinely reversed order).  Decline; the
        # edge stays ping-pong.
        return None

    # Step 3: depth→depth map.
    old_depths = {v: target.loop_depth(v) for v in desired}
    depth_map = {old_depths[v]: k for k, v in enumerate(desired)}

    # Step 4: permute the nest — desired vars first in arrival order, the
    # remaining loops (reduction dims etc.) keep relative order after them.
    head = [target.loop(v) for v in desired]
    tail = [l for l in target.loops if l.var not in set(desired)]
    target.loops = head + tail
    target.tags.add("permuted")
    return PermutationMap(target.name, reference.name, buffer, depth_map)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def eliminate_fine(graph: DataflowGraph) -> FineReport:
    """Fixpoint: rewrite reductions first (count repair), then permute loop
    orders toward the bottleneck reference (order repair).  Violations that
    survive (STENCIL_REREAD before reuse generation, genuine count
    mismatches) are left for buffers.py to downgrade to ping-pong."""
    report = FineReport()
    for it in range(_MAX_ITERS):
        report.iterations = it
        vs = fine_violations(graph)
        if not vs:
            break
        progressed = False
        for v in vs:
            if v.kind == MULTI_WRITE:
                t = graph.task(v.producer)
                if rewrite_reduction_write(t, v.buffer):
                    report.reductions_rewritten.append(f"{t.name}:{v.buffer}")
                    progressed = True
                    break
            elif v.kind == BROADCAST_REREAD:
                t = graph.task(v.consumer)
                if rewrite_reduction_read(t, v.buffer):
                    report.reductions_rewritten.append(f"{t.name}:{v.buffer}(read)")
                    progressed = True
                    break
            elif v.kind == ORDER_MISMATCH:
                p, c = graph.task(v.producer), graph.task(v.consumer)
                if "permuted" in p.tags and "permuted" in c.tags:
                    continue  # both already aligned to references; unresolvable here
                ref, tgt = (p, c) if _intensity(p) >= _intensity(c) else (c, p)
                if "permuted" in tgt.tags or "reuse-rewritten" in tgt.tags:
                    ref, tgt = tgt, ref   # never un-permute an aligned task
                if "permuted" in tgt.tags or "reuse-rewritten" in tgt.tags:
                    continue
                pm = generate_permutation(graph, ref, tgt, v.buffer)
                if pm is not None:
                    report.permutations.append(pm)
                    progressed = True
                    break
        if not progressed:
            break
    report.unresolved = [f"{v.kind}:{v.buffer}({v.producer}->{v.consumer})"
                         for v in fine_violations(graph)]
    return report
