"""Declarative op registry: ``Task`` numerics as data, not closures.

Historically every :class:`~repro.core.graph.Task` carried its numeric
semantics as an opaque Python closure (``fn(env) -> {buf: array}``).  That
worked, but closures are not picklable, so

* on-disk compile-cache entries came back *stripped* — a cold-restart hit
  could be costed and verified but never lowered or executed, and
* the batch ablation driver was confined to thread pools — a ``Task``
  could not cross a process boundary.

This module replaces closures with an :class:`OpSpec` — a plain-data
record of *which* op a task computes (operand buffer names, output buffer
names, and attributes like stride/padding/axis) — plus a registry that
materializes the matching jnp implementation on demand.  ``Task.fn`` is
now a derived property: tasks that carry a spec re-derive their callable
after a pickle round-trip, in any process.

OpSpec fields
-------------

``kind``
    Registry key naming the implementation (``"conv2d"``, ``"matmul"``,
    ``"relu"``, ...).  Distinct from ``Task.op``, which is the *pattern
    class* the passes reason about (a ``"conv2d"`` and a ``"dwconv2d"``
    spec are both ``op="conv"`` tasks).
``ins``
    Operand buffer names, positional.  ``env[ins[i]]`` is the i-th input
    array at execution time.
``outs``
    Output buffer names.  The implementation returns ``{out: array}`` for
    every name in ``outs``.
``attrs``
    Plain-data attributes (ints, floats, bools, strings, tuples thereof):
    stride, padding, reduction axes, scale factors...  Attributes are part
    of :meth:`signature` and therefore of
    ``DataflowGraph.structural_signature()`` — a semantic constant that
    lives in an attr automatically keys the compile cache, so two graphs
    differing only in, say, a scale factor never collide.
``parts``
    Sub-specs for the composite ``"fused"`` kind (the coarse pass merges
    multi-producer violations by fusing producers; the fused node's
    semantics are the parts run in sequence).

Pickling contract
-----------------

An ``OpSpec`` must contain only plain data: strings, numbers, bools, and
(nested) tuples/dicts of those, plus child ``OpSpec`` records in
``parts``.  Never close over arrays, modules, or callables — the whole
point is that ``pickle.dumps(spec)`` round-trips across interpreters and
that :meth:`signature` is a stable content address.  Implementations
(registered callables) stay in *code*, keyed by ``kind``: unpickling a
spec in a fresh process finds the implementation in the registry of that
process, so ships of spec'd graphs between processes only require both
sides to import the same version of this module.

Registering a new op
--------------------

.. code-block:: python

    from repro.core.ops import OpSpec, register_op

    @register_op("axpy")
    def _axpy(spec, env):
        import jax.numpy as jnp  # lazy: keep repro.core importable sans jax
        a = spec.attrs.get("a", 1.0)
        x, y = (env[b] for b in spec.ins)
        return {spec.outs[0]: a * x + y}

    # builders then attach: Task(..., spec=OpSpec("axpy", (x, y), (out,),
    #                                             {"a": 2.0}))

Implementations take ``(spec, env)`` and return a dict mapping *every*
name in ``spec.outs`` to its array.  jax imports belong *inside* the
implementation body — graph construction and the whole compile pipeline
must stay importable (and process-pool-spawnable) without pulling in jax.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------


class UnknownOpError(KeyError):
    """Raised when a spec names a kind with no registered implementation."""


def _plain(value: Any) -> Any:
    """Canonical plain-data view of an attr value (lists -> tuples)."""
    if isinstance(value, (list, tuple)):
        return tuple(_plain(v) for v in value)
    return value


@dataclass
class OpSpec:
    """Declarative numeric semantics of one task — see the module docstring
    for the field-by-field contract."""

    kind: str
    ins: tuple[str, ...] = ()
    outs: tuple[str, ...] = ()
    attrs: dict[str, Any] = field(default_factory=dict)
    parts: tuple["OpSpec", ...] = ()

    def __post_init__(self):
        self.ins = tuple(self.ins)
        self.outs = tuple(self.outs)
        self.parts = tuple(self.parts)
        self.attrs = {k: _plain(v) for k, v in dict(self.attrs).items()}

    # ---- data plumbing ---------------------------------------------------
    def renamed(self, alias: dict[str, str]) -> "OpSpec":
        """Pure-data buffer rename: every operand/output name found in
        ``alias`` maps old -> new, recursively through ``parts``.  This is
        the declarative replacement for ``retarget_fn``'s env-aliasing
        closure shim."""
        return OpSpec(
            self.kind,
            tuple(alias.get(b, b) for b in self.ins),
            tuple(alias.get(b, b) for b in self.outs),
            dict(self.attrs),
            tuple(p.renamed(alias) for p in self.parts),
        )

    def copy(self) -> "OpSpec":
        return dataclasses.replace(
            self, attrs=dict(self.attrs),
            parts=tuple(p.copy() for p in self.parts))

    def buffers(self) -> set[str]:
        out = set(self.ins) | set(self.outs)
        for p in self.parts:
            out |= p.buffers()
        return out

    # ---- content addressing ----------------------------------------------
    def signature(self) -> tuple:
        """Canonical nested-tuple view: feeds
        ``DataflowGraph.structural_signature()`` so op semantics —
        including attr constants — key the compile cache."""
        return (self.kind, self.ins, self.outs,
                tuple(sorted((k, repr(v)) for k, v in self.attrs.items())),
                tuple(p.signature() for p in self.parts))

    # ---- JSON serialization (docs/artifact_format.md `spec` object) ------
    def to_dict(self) -> dict:
        """Language-neutral JSON view.  Tuples inside ``attrs`` become JSON
        arrays; :meth:`from_dict` restores them through ``__post_init__``'s
        canonicalization, so ``from_dict(to_dict(s)).signature() ==
        s.signature()`` holds for every spec obeying the plain-data
        contract."""
        out: dict = {"kind": self.kind, "ins": list(self.ins),
                     "outs": list(self.outs), "attrs": dict(self.attrs)}
        if self.parts:
            out["parts"] = [p.to_dict() for p in self.parts]
        return out

    @classmethod
    def from_dict(cls, doc: dict) -> "OpSpec":
        return cls(doc["kind"], tuple(doc.get("ins", ())),
                   tuple(doc.get("outs", ())), dict(doc.get("attrs", {})),
                   tuple(cls.from_dict(p) for p in doc.get("parts", ())))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

# kind -> implementation(spec, env) -> {out buffer: array}
_REGISTRY: dict[str, Callable[[OpSpec, dict], dict]] = {}

# Bumped on every registration so memoized *materialized* programs (e.g.
# the lower() cache) can detect that an implementation changed underneath
# them and rebuild instead of serving stale numerics.
_EPOCH = 0


def registry_epoch() -> int:
    return _EPOCH


def register_op(kind: str):
    """Decorator: register ``fn(spec, env) -> {out: array}`` under ``kind``.
    Re-registration replaces (kernels may override reference impls)."""

    def deco(fn: Callable[[OpSpec, dict], dict]):
        global _EPOCH
        _REGISTRY[kind] = fn
        _EPOCH += 1
        return fn

    return deco


def registered_ops() -> list[str]:
    return sorted(_REGISTRY)


def op_impl(kind: str) -> Callable[[OpSpec, dict], dict]:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise UnknownOpError(
            f"no implementation registered for op kind {kind!r}; "
            f"registered: {registered_ops()}") from None


def materialize(spec: OpSpec) -> Callable[[dict], dict]:
    """Build the executable ``env -> {out: array}`` callable for ``spec``.

    Raises :class:`UnknownOpError` eagerly (at materialization, not first
    call) so a stale spec fails loudly when a cache entry is reloaded."""
    impl = op_impl(spec.kind)

    def fn(env: dict) -> dict:
        return impl(spec, env)

    fn.spec = spec  # introspection/debugging: which spec produced this fn
    return fn


# --------------------------------------------------------------------------
# Reference implementations (lazy jax imports: the registry itself — and
# everything that builds or compiles graphs — must import without jax).
# --------------------------------------------------------------------------


@register_op("identity")
def _identity(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]]}


@register_op("dup")
def _dup(spec: OpSpec, env: dict) -> dict:
    """Coarse-pass duplicator: one private stream copy per consumer."""
    src = env[spec.ins[0]]
    return {o: src for o in spec.outs}


@register_op("fused")
def _fused(spec: OpSpec, env: dict) -> dict:
    """Coarse-pass producer fusion: run ``parts`` in order, each seeing the
    accumulated scope (earlier writes staged and merged, per Fig. 4b)."""
    out: dict = {}
    scope = dict(env)
    for part in spec.parts:
        r = materialize(part)(scope)
        scope.update(r)
        out.update(r)
    return out


@register_op("zeros")
def _zeros(spec: OpSpec, env: dict) -> dict:
    """Init half of the Fig. 4b init/pad multi-producer pair: a zeroed
    canvas of ``attrs['shape']`` (no operands)."""
    import jax.numpy as jnp
    import numpy as np
    dtype = np.dtype(spec.attrs.get("dtype", "float32"))
    return {spec.outs[0]: jnp.zeros(tuple(int(s) for s in spec.attrs["shape"]),
                                    dtype)}


@register_op("fill_interior")
def _fill_interior(spec: OpSpec, env: dict) -> dict:
    """Fill half of the init/pad pair: writes the interior of the canvas
    the init producer staged under this spec's own output name (both in
    ``graph.execute``'s accumulating env and in the coarse pass's fused
    ``parts`` scope)."""
    import jax.numpy as jnp
    p = int(spec.attrs["pad"])
    x = env[spec.ins[0]]
    canvas = env.get(spec.outs[0])
    if canvas is None:
        n, c, h, w = x.shape
        canvas = jnp.zeros((n, c, h + 2 * p, w + 2 * p), x.dtype)
    return {spec.outs[0]:
            canvas.at[:, :, p:p + x.shape[2], p:p + x.shape[3]].set(x)}


@register_op("pad2d")
def _pad2d(spec: OpSpec, env: dict) -> dict:
    import jax.numpy as jnp
    p = int(spec.attrs["pad"])
    return {spec.outs[0]: jnp.pad(env[spec.ins[0]],
                                  ((0, 0), (0, 0), (p, p), (p, p)))}


@register_op("conv2d")
def _conv2d(spec: OpSpec, env: dict) -> dict:
    import jax
    s = int(spec.attrs.get("stride", 1))
    g = int(spec.attrs.get("groups", 1))
    y = jax.lax.conv_general_dilated(
        env[spec.ins[0]], env[spec.ins[1]], (s, s), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=g)
    return {spec.outs[0]: y}


@register_op("relu")
def _relu(spec: OpSpec, env: dict) -> dict:
    import jax.numpy as jnp
    return {spec.outs[0]: jnp.maximum(env[spec.ins[0]], 0)}


@register_op("gelu")
def _gelu(spec: OpSpec, env: dict) -> dict:
    import jax
    return {spec.outs[0]: jax.nn.gelu(env[spec.ins[0]])}


@register_op("add")
def _add(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] + env[spec.ins[1]]}


@register_op("vadd")
def _vadd(spec: OpSpec, env: dict) -> dict:
    a = float(spec.attrs.get("alpha", 1.0))
    b = float(spec.attrs.get("beta", 1.0))
    return {spec.outs[0]: a * env[spec.ins[0]] + b * env[spec.ins[1]]}


@register_op("scale")
def _scale(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] * float(spec.attrs["s"])}


@register_op("affine")
def _affine(spec: OpSpec, env: dict) -> dict:
    """``a*x + b`` — the scalar-operand form of add/sub (reflected-operator
    sugar).  With ``a`` in {1, -1} the result is bit-exact with the eager
    ``x + b`` / ``b - x`` expressions it stands in for."""
    a = float(spec.attrs.get("a", 1.0))
    b = float(spec.attrs.get("b", 0.0))
    x = env[spec.ins[0]]
    return {spec.outs[0]: (x if a == 1.0 else (-x if a == -1.0 else a * x)) + b}


@register_op("divc")
def _divc(spec: OpSpec, env: dict) -> dict:
    """``x / c`` — true division (not scale-by-reciprocal), so traced and
    eager results agree to the last ulp."""
    return {spec.outs[0]: env[spec.ins[0]] / float(spec.attrs["c"])}


@register_op("rdivc")
def _rdivc(spec: OpSpec, env: dict) -> dict:
    """``c / x`` — the scalar-left reflected division."""
    return {spec.outs[0]: float(spec.attrs["c"]) / env[spec.ins[0]]}


@register_op("div")
def _div(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] / env[spec.ins[1]]}


@register_op("mul")
def _mul(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] * env[spec.ins[1]]}


@register_op("const")
def _const(spec: OpSpec, env: dict) -> dict:
    """Materialize a compile-time constant array (array-left operands
    lifted into a trace).  The value lives in ``attrs`` as nested tuples —
    plain data, so it enters the structural signature and pickles."""
    import jax.numpy as jnp
    import numpy as np
    return {spec.outs[0]: jnp.asarray(np.array(
        spec.attrs["value"], dtype=np.dtype(spec.attrs.get("dtype",
                                                           "float32"))))}


@register_op("softmax")
def _softmax(spec: OpSpec, env: dict) -> dict:
    import jax
    axis = int(spec.attrs.get("axis", -1))
    return {spec.outs[0]: jax.nn.softmax(env[spec.ins[0]], axis)}


@register_op("matmul")
def _matmul(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] @ env[spec.ins[1]]}


@register_op("mv")
def _mv(spec: OpSpec, env: dict) -> dict:
    A = env[spec.ins[0]]
    if spec.attrs.get("trans", False):
        A = A.T
    return {spec.outs[0]: A @ env[spec.ins[1]]}


@register_op("transpose")
def _transpose(spec: OpSpec, env: dict) -> dict:
    """2-D transpose by default; an optional ``perm`` attr generalizes to
    any rank (the frontend emits ``perm=(0, 2, 1)`` for batched operands)."""
    x = env[spec.ins[0]]
    perm = spec.attrs.get("perm")
    if perm is not None:
        return {spec.outs[0]: x.transpose(tuple(int(p) for p in perm))}
    return {spec.outs[0]: x.T}


@register_op("maxpool2d")
def _maxpool2d(spec: OpSpec, env: dict) -> dict:
    import jax
    import jax.numpy as jnp
    k = int(spec.attrs["k"])
    y = jax.lax.reduce_window(env[spec.ins[0]], -jnp.inf, jax.lax.max,
                              (1, 1, k, k), (1, 1, k, k), "VALID")
    return {spec.outs[0]: y}


@register_op("mean")
def _mean(spec: OpSpec, env: dict) -> dict:
    axes = tuple(int(a) for a in spec.attrs["axes"])
    return {spec.outs[0]: env[spec.ins[0]].mean(axis=axes)}


@register_op("reshape")
def _reshape(spec: OpSpec, env: dict) -> dict:
    shape = tuple(int(s) for s in spec.attrs["shape"])
    return {spec.outs[0]: env[spec.ins[0]].reshape(shape)}


@register_op("concat")
def _concat(spec: OpSpec, env: dict) -> dict:
    import jax.numpy as jnp
    axis = int(spec.attrs.get("axis", 0))
    return {spec.outs[0]: jnp.concatenate([env[b] for b in spec.ins],
                                          axis=axis)}


@register_op("split")
def _split(spec: OpSpec, env: dict) -> dict:
    """Multi-output inverse of concat: ``sizes`` partitions ``axis``.
    Pure indexing, so it stays tracer-safe under jit."""
    axis = int(spec.attrs.get("axis", 0))
    x = env[spec.ins[0]]
    out, off = {}, 0
    for o, s in zip(spec.outs, spec.attrs["sizes"]):
        ix = [slice(None)] * x.ndim
        ix[axis] = slice(off, off + int(s))
        out[o] = x[tuple(ix)]
        off += int(s)
    return out


@register_op("slice")
def _slice(spec: OpSpec, env: dict) -> dict:
    """Static rectangular window: ``starts``/``sizes`` per dimension."""
    x = env[spec.ins[0]]
    ix = tuple(slice(int(st), int(st) + int(sz))
               for st, sz in zip(spec.attrs["starts"], spec.attrs["sizes"]))
    return {spec.outs[0]: x[ix]}


@register_op("rglru_scan")
def _rglru_scan(spec: OpSpec, env: dict) -> dict:
    """RG-LRU linear recurrence h_t = a_t * h_{t-1} + b_t over axis 1 of
    (B, S, D) operands, h_{-1} = 0.  This is the *generic* sequential
    definition (``lax.scan``); the routed ``rglru.scan`` kernel replaces
    it with the chunked Pallas stream."""
    import jax
    import jax.numpy as jnp
    a = jnp.asarray(env[spec.ins[0]])
    b = jnp.asarray(env[spec.ins[1]])

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                         (jnp.swapaxes(a, 0, 1), jnp.swapaxes(b, 0, 1)))
    return {spec.outs[0]: jnp.swapaxes(hs, 0, 1)}


@register_op("ssd_scan")
def _ssd_scan(spec: OpSpec, env: dict) -> dict:
    """Mamba-2 SSD inter-chunk state recurrence over per-chunk end states
    (nc, BH, P, N) and scalar decays (nc, BH, 1, 1): emits the state
    carried *into* each chunk (h_0 = 0).  Generic sequential definition;
    the routed ``ssd.scan`` kernel is the chunked Pallas stream."""
    import jax
    import jax.numpy as jnp
    states = jnp.asarray(env[spec.ins[0]])
    decay = jnp.asarray(env[spec.ins[1]])

    def step(h, inp):
        st, dec = inp
        return h * dec + st, h

    h0 = jnp.zeros(states.shape[1:], states.dtype)
    _, prevs = jax.lax.scan(step, h0, (states, decay))
    return {spec.outs[0]: prevs}


__all__ = ["OpSpec", "UnknownOpError", "materialize", "op_impl",
           "register_op", "registered_ops"]
