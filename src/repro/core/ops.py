"""Declarative op registry: ``Task`` numerics as data, not closures.

Historically every :class:`~repro.core.graph.Task` carried its numeric
semantics as an opaque Python closure (``fn(env) -> {buf: array}``).  That
worked, but closures are not picklable, so

* on-disk compile-cache entries came back *stripped* — a cold-restart hit
  could be costed and verified but never lowered or executed, and
* the batch ablation driver was confined to thread pools — a ``Task``
  could not cross a process boundary.

This module replaces closures with an :class:`OpSpec` — a plain-data
record of *which* op a task computes (operand buffer names, output buffer
names, and attributes like stride/padding/axis) — plus a registry that
materializes the matching jnp implementation on demand.  ``Task.fn`` is
now a derived property: tasks that carry a spec re-derive their callable
after a pickle round-trip, in any process.

OpSpec fields
-------------

``kind``
    Registry key naming the implementation (``"conv2d"``, ``"matmul"``,
    ``"relu"``, ...).  Distinct from ``Task.op``, which is the *pattern
    class* the passes reason about (a ``"conv2d"`` and a ``"dwconv2d"``
    spec are both ``op="conv"`` tasks).
``ins``
    Operand buffer names, positional.  ``env[ins[i]]`` is the i-th input
    array at execution time.
``outs``
    Output buffer names.  The implementation returns ``{out: array}`` for
    every name in ``outs``.
``attrs``
    Plain-data attributes (ints, floats, bools, strings, tuples thereof):
    stride, padding, reduction axes, scale factors...  Attributes are part
    of :meth:`signature` and therefore of
    ``DataflowGraph.structural_signature()`` — a semantic constant that
    lives in an attr automatically keys the compile cache, so two graphs
    differing only in, say, a scale factor never collide.
``parts``
    Sub-specs for the composite ``"fused"`` kind (the coarse pass merges
    multi-producer violations by fusing producers; the fused node's
    semantics are the parts run in sequence).

Pickling contract
-----------------

An ``OpSpec`` must contain only plain data: strings, numbers, bools, and
(nested) tuples/dicts of those, plus child ``OpSpec`` records in
``parts``.  Never close over arrays, modules, or callables — the whole
point is that ``pickle.dumps(spec)`` round-trips across interpreters and
that :meth:`signature` is a stable content address.  Implementations
(registered callables) stay in *code*, keyed by ``kind``: unpickling a
spec in a fresh process finds the implementation in the registry of that
process, so ships of spec'd graphs between processes only require both
sides to import the same version of this module.

Registering a new op
--------------------

.. code-block:: python

    from repro.core.ops import OpSpec, register_op

    @register_op("axpy")
    def _axpy(spec, env):
        import jax.numpy as jnp  # lazy: keep repro.core importable sans jax
        a = spec.attrs.get("a", 1.0)
        x, y = (env[b] for b in spec.ins)
        return {spec.outs[0]: a * x + y}

    # builders then attach: Task(..., spec=OpSpec("axpy", (x, y), (out,),
    #                                             {"a": 2.0}))

Implementations take ``(spec, env)`` and return a dict mapping *every*
name in ``spec.outs`` to its array.  jax imports belong *inside* the
implementation body — graph construction and the whole compile pipeline
must stay importable (and process-pool-spawnable) without pulling in jax.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable

# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------


class UnknownOpError(KeyError):
    """Raised when a spec names a kind with no registered implementation."""


def _plain(value: Any) -> Any:
    """Canonical plain-data view of an attr value (lists -> tuples)."""
    if isinstance(value, (list, tuple)):
        return tuple(_plain(v) for v in value)
    return value


@dataclass
class OpSpec:
    """Declarative numeric semantics of one task — see the module docstring
    for the field-by-field contract."""

    kind: str
    ins: tuple[str, ...] = ()
    outs: tuple[str, ...] = ()
    attrs: dict[str, Any] = field(default_factory=dict)
    parts: tuple["OpSpec", ...] = ()

    def __post_init__(self):
        self.ins = tuple(self.ins)
        self.outs = tuple(self.outs)
        self.parts = tuple(self.parts)
        self.attrs = {k: _plain(v) for k, v in dict(self.attrs).items()}

    # ---- data plumbing ---------------------------------------------------
    def renamed(self, alias: dict[str, str]) -> "OpSpec":
        """Pure-data buffer rename: every operand/output name found in
        ``alias`` maps old -> new, recursively through ``parts``.  This is
        the declarative replacement for ``retarget_fn``'s env-aliasing
        closure shim."""
        return OpSpec(
            self.kind,
            tuple(alias.get(b, b) for b in self.ins),
            tuple(alias.get(b, b) for b in self.outs),
            dict(self.attrs),
            tuple(p.renamed(alias) for p in self.parts),
        )

    def copy(self) -> "OpSpec":
        return dataclasses.replace(
            self, attrs=dict(self.attrs),
            parts=tuple(p.copy() for p in self.parts))

    def buffers(self) -> set[str]:
        out = set(self.ins) | set(self.outs)
        for p in self.parts:
            out |= p.buffers()
        return out

    # ---- content addressing ----------------------------------------------
    def signature(self) -> tuple:
        """Canonical nested-tuple view: feeds
        ``DataflowGraph.structural_signature()`` so op semantics —
        including attr constants — key the compile cache."""
        return (self.kind, self.ins, self.outs,
                tuple(sorted((k, repr(v)) for k, v in self.attrs.items())),
                tuple(p.signature() for p in self.parts))

    # ---- JSON serialization (docs/artifact_format.md `spec` object) ------
    def to_dict(self) -> dict:
        """Language-neutral JSON view.  Tuples inside ``attrs`` become JSON
        arrays; :meth:`from_dict` restores them through ``__post_init__``'s
        canonicalization, so ``from_dict(to_dict(s)).signature() ==
        s.signature()`` holds for every spec obeying the plain-data
        contract."""
        out: dict = {"kind": self.kind, "ins": list(self.ins),
                     "outs": list(self.outs), "attrs": dict(self.attrs)}
        if self.parts:
            out["parts"] = [p.to_dict() for p in self.parts]
        return out

    @classmethod
    def from_dict(cls, doc: dict) -> "OpSpec":
        return cls(doc["kind"], tuple(doc.get("ins", ())),
                   tuple(doc.get("outs", ())), dict(doc.get("attrs", {})),
                   tuple(cls.from_dict(p) for p in doc.get("parts", ())))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

# kind -> implementation(spec, env) -> {out buffer: array}
_REGISTRY: dict[str, Callable[[OpSpec, dict], dict]] = {}

# Bumped on every registration so memoized *materialized* programs (e.g.
# the lower() cache) can detect that an implementation changed underneath
# them and rebuild instead of serving stale numerics.
_EPOCH = 0


def registry_epoch() -> int:
    return _EPOCH


def register_op(kind: str):
    """Decorator: register ``fn(spec, env) -> {out: array}`` under ``kind``.
    Re-registration replaces (kernels may override reference impls)."""

    def deco(fn: Callable[[OpSpec, dict], dict]):
        global _EPOCH
        _REGISTRY[kind] = fn
        _EPOCH += 1
        return fn

    return deco


def registered_ops() -> list[str]:
    return sorted(_REGISTRY)


def op_impl(kind: str) -> Callable[[OpSpec, dict], dict]:
    try:
        return _REGISTRY[kind]
    except KeyError:
        raise UnknownOpError(
            f"no implementation registered for op kind {kind!r}; "
            f"registered: {registered_ops()}") from None


def materialize(spec: OpSpec) -> Callable[[dict], dict]:
    """Build the executable ``env -> {out: array}`` callable for ``spec``.

    Raises :class:`UnknownOpError` eagerly (at materialization, not first
    call) so a stale spec fails loudly when a cache entry is reloaded."""
    impl = op_impl(spec.kind)

    def fn(env: dict) -> dict:
        return impl(spec, env)

    fn.spec = spec  # introspection/debugging: which spec produced this fn
    return fn


# --------------------------------------------------------------------------
# Reference implementations (lazy jax imports: the registry itself — and
# everything that builds or compiles graphs — must import without jax).
# --------------------------------------------------------------------------


@register_op("identity")
def _identity(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]]}


@register_op("dup")
def _dup(spec: OpSpec, env: dict) -> dict:
    """Coarse-pass duplicator: one private stream copy per consumer."""
    src = env[spec.ins[0]]
    return {o: src for o in spec.outs}


@register_op("fused")
def _fused(spec: OpSpec, env: dict) -> dict:
    """Coarse-pass producer fusion: run ``parts`` in order, each seeing the
    accumulated scope (earlier writes staged and merged, per Fig. 4b)."""
    out: dict = {}
    scope = dict(env)
    for part in spec.parts:
        r = materialize(part)(scope)
        scope.update(r)
        out.update(r)
    return out


@register_op("zeros")
def _zeros(spec: OpSpec, env: dict) -> dict:
    """Init half of the Fig. 4b init/pad multi-producer pair: a zeroed
    canvas of ``attrs['shape']`` (no operands)."""
    import jax.numpy as jnp
    import numpy as np
    dtype = np.dtype(spec.attrs.get("dtype", "float32"))
    return {spec.outs[0]: jnp.zeros(tuple(int(s) for s in spec.attrs["shape"]),
                                    dtype)}


@register_op("fill_interior")
def _fill_interior(spec: OpSpec, env: dict) -> dict:
    """Fill half of the init/pad pair: writes the interior of the canvas
    the init producer staged under this spec's own output name (both in
    ``graph.execute``'s accumulating env and in the coarse pass's fused
    ``parts`` scope)."""
    import jax.numpy as jnp
    p = int(spec.attrs["pad"])
    x = env[spec.ins[0]]
    canvas = env.get(spec.outs[0])
    if canvas is None:
        n, c, h, w = x.shape
        canvas = jnp.zeros((n, c, h + 2 * p, w + 2 * p), x.dtype)
    return {spec.outs[0]:
            canvas.at[:, :, p:p + x.shape[2], p:p + x.shape[3]].set(x)}


@register_op("pad2d")
def _pad2d(spec: OpSpec, env: dict) -> dict:
    import jax.numpy as jnp
    p = int(spec.attrs["pad"])
    return {spec.outs[0]: jnp.pad(env[spec.ins[0]],
                                  ((0, 0), (0, 0), (p, p), (p, p)))}


@register_op("conv2d")
def _conv2d(spec: OpSpec, env: dict) -> dict:
    import jax
    s = int(spec.attrs.get("stride", 1))
    g = int(spec.attrs.get("groups", 1))
    y = jax.lax.conv_general_dilated(
        env[spec.ins[0]], env[spec.ins[1]], (s, s), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=g)
    return {spec.outs[0]: y}


@register_op("relu")
def _relu(spec: OpSpec, env: dict) -> dict:
    import jax.numpy as jnp
    return {spec.outs[0]: jnp.maximum(env[spec.ins[0]], 0)}


@register_op("gelu")
def _gelu(spec: OpSpec, env: dict) -> dict:
    import jax
    return {spec.outs[0]: jax.nn.gelu(env[spec.ins[0]])}


@register_op("add")
def _add(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] + env[spec.ins[1]]}


@register_op("vadd")
def _vadd(spec: OpSpec, env: dict) -> dict:
    a = float(spec.attrs.get("alpha", 1.0))
    b = float(spec.attrs.get("beta", 1.0))
    return {spec.outs[0]: a * env[spec.ins[0]] + b * env[spec.ins[1]]}


@register_op("scale")
def _scale(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] * float(spec.attrs["s"])}


@register_op("affine")
def _affine(spec: OpSpec, env: dict) -> dict:
    """``a*x + b`` — the scalar-operand form of add/sub (reflected-operator
    sugar).  With ``a`` in {1, -1} the result is bit-exact with the eager
    ``x + b`` / ``b - x`` expressions it stands in for."""
    a = float(spec.attrs.get("a", 1.0))
    b = float(spec.attrs.get("b", 0.0))
    x = env[spec.ins[0]]
    return {spec.outs[0]: (x if a == 1.0 else (-x if a == -1.0 else a * x)) + b}


@register_op("divc")
def _divc(spec: OpSpec, env: dict) -> dict:
    """``x / c`` — true division (not scale-by-reciprocal), so traced and
    eager results agree to the last ulp."""
    return {spec.outs[0]: env[spec.ins[0]] / float(spec.attrs["c"])}


@register_op("rdivc")
def _rdivc(spec: OpSpec, env: dict) -> dict:
    """``c / x`` — the scalar-left reflected division."""
    return {spec.outs[0]: float(spec.attrs["c"]) / env[spec.ins[0]]}


@register_op("div")
def _div(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] / env[spec.ins[1]]}


@register_op("mul")
def _mul(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] * env[spec.ins[1]]}


@register_op("const")
def _const(spec: OpSpec, env: dict) -> dict:
    """Materialize a compile-time constant array (array-left operands
    lifted into a trace).  The value lives in ``attrs`` as nested tuples —
    plain data, so it enters the structural signature and pickles."""
    import jax.numpy as jnp
    import numpy as np
    return {spec.outs[0]: jnp.asarray(np.array(
        spec.attrs["value"], dtype=np.dtype(spec.attrs.get("dtype",
                                                           "float32"))))}


@register_op("softmax")
def _softmax(spec: OpSpec, env: dict) -> dict:
    import jax
    axis = int(spec.attrs.get("axis", -1))
    return {spec.outs[0]: jax.nn.softmax(env[spec.ins[0]], axis)}


@register_op("matmul")
def _matmul(spec: OpSpec, env: dict) -> dict:
    return {spec.outs[0]: env[spec.ins[0]] @ env[spec.ins[1]]}


@register_op("mv")
def _mv(spec: OpSpec, env: dict) -> dict:
    A = env[spec.ins[0]]
    if spec.attrs.get("trans", False):
        A = A.T
    return {spec.outs[0]: A @ env[spec.ins[1]]}


@register_op("transpose")
def _transpose(spec: OpSpec, env: dict) -> dict:
    """2-D transpose by default; an optional ``perm`` attr generalizes to
    any rank (the frontend emits ``perm=(0, 2, 1)`` for batched operands)."""
    x = env[spec.ins[0]]
    perm = spec.attrs.get("perm")
    if perm is not None:
        return {spec.outs[0]: x.transpose(tuple(int(p) for p in perm))}
    return {spec.outs[0]: x.T}


@register_op("maxpool2d")
def _maxpool2d(spec: OpSpec, env: dict) -> dict:
    import jax
    import jax.numpy as jnp
    k = int(spec.attrs["k"])
    y = jax.lax.reduce_window(env[spec.ins[0]], -jnp.inf, jax.lax.max,
                              (1, 1, k, k), (1, 1, k, k), "VALID")
    return {spec.outs[0]: y}


@register_op("mean")
def _mean(spec: OpSpec, env: dict) -> dict:
    axes = tuple(int(a) for a in spec.attrs["axes"])
    return {spec.outs[0]: env[spec.ins[0]].mean(axis=axes)}


@register_op("reshape")
def _reshape(spec: OpSpec, env: dict) -> dict:
    shape = tuple(int(s) for s in spec.attrs["shape"])
    return {spec.outs[0]: env[spec.ins[0]].reshape(shape)}


@register_op("concat")
def _concat(spec: OpSpec, env: dict) -> dict:
    import jax.numpy as jnp
    axis = int(spec.attrs.get("axis", 0))
    return {spec.outs[0]: jnp.concatenate([env[b] for b in spec.ins],
                                          axis=axis)}


@register_op("split")
def _split(spec: OpSpec, env: dict) -> dict:
    """Multi-output inverse of concat: ``sizes`` partitions ``axis``.
    Pure indexing, so it stays tracer-safe under jit."""
    axis = int(spec.attrs.get("axis", 0))
    x = env[spec.ins[0]]
    out, off = {}, 0
    for o, s in zip(spec.outs, spec.attrs["sizes"]):
        ix = [slice(None)] * x.ndim
        ix[axis] = slice(off, off + int(s))
        out[o] = x[tuple(ix)]
        off += int(s)
    return out


@register_op("slice")
def _slice(spec: OpSpec, env: dict) -> dict:
    """Static rectangular window: ``starts``/``sizes`` per dimension."""
    x = env[spec.ins[0]]
    ix = tuple(slice(int(st), int(st) + int(sz))
               for st, sz in zip(spec.attrs["starts"], spec.attrs["sizes"]))
    return {spec.outs[0]: x[ix]}


def _rglru_reference(a, b):
    """RG-LRU recurrence h_t = a_t * h_{t-1} + b_t over axis 1 of (B, S, D)
    operands, h_{-1} = 0 — shared by the generic impl and its VJP (the VJP
    always differentiates this reference, so re-registering the forward
    with a kernel cannot change gradient semantics)."""
    import jax
    import jax.numpy as jnp

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(a[:, 0]),
                         (jnp.swapaxes(a, 0, 1), jnp.swapaxes(b, 0, 1)))
    return jnp.swapaxes(hs, 0, 1)


def _ssd_reference(states, decay):
    """SSD inter-chunk recurrence over (nc, BH, P, N) end states and
    (nc, BH, 1, 1) decays: the state carried *into* each chunk, h_0 = 0.
    Shared by the generic impl and its VJP."""
    import jax
    import jax.numpy as jnp

    def step(h, inp):
        st, dec = inp
        return h * dec + st, h

    h0 = jnp.zeros(states.shape[1:], states.dtype)
    _, prevs = jax.lax.scan(step, h0, (states, decay))
    return prevs


@register_op("rglru_scan")
def _rglru_scan(spec: OpSpec, env: dict) -> dict:
    """RG-LRU linear recurrence h_t = a_t * h_{t-1} + b_t over axis 1 of
    (B, S, D) operands, h_{-1} = 0.  This is the *generic* sequential
    definition (``lax.scan``); the routed ``rglru.scan`` kernel replaces
    it with the chunked Pallas stream."""
    import jax.numpy as jnp
    return {spec.outs[0]: _rglru_reference(jnp.asarray(env[spec.ins[0]]),
                                           jnp.asarray(env[spec.ins[1]]))}


@register_op("ssd_scan")
def _ssd_scan(spec: OpSpec, env: dict) -> dict:
    """Mamba-2 SSD inter-chunk state recurrence over per-chunk end states
    (nc, BH, P, N) and scalar decays (nc, BH, 1, 1): emits the state
    carried *into* each chunk (h_0 = 0).  Generic sequential definition;
    the routed ``ssd.scan`` kernel is the chunked Pallas stream."""
    import jax.numpy as jnp
    return {spec.outs[0]: _ssd_reference(jnp.asarray(env[spec.ins[0]]),
                                         jnp.asarray(env[spec.ins[1]]))}


# --------------------------------------------------------------------------
# Gradient + optimizer op implementations (ISSUE 10).  Same contract as
# everything above: plain-data specs, lazy jax imports, ``(spec, env) ->
# {out: array}``.  These are the vocabulary the autodiff pass
# (core/autodiff.py) emits backward and AdamW-update graphs in — all of
# them first-class registry ops, so the backward graph pickles, caches,
# and reloads exactly like a forward graph.
# --------------------------------------------------------------------------


@register_op("mean_all")
def _mean_all(spec: OpSpec, env: dict) -> dict:
    """Full reduction to a (1, 1) scalar carrier — the loss head."""
    return {spec.outs[0]: env[spec.ins[0]].mean().reshape(1, 1)}


@register_op("bcast")
def _bcast(spec: OpSpec, env: dict) -> dict:
    """Broadcast to ``attrs['shape']`` (scalar carriers flatten first)."""
    import jax.numpy as jnp
    shape = tuple(int(s) for s in spec.attrs["shape"])
    x = env[spec.ins[0]]
    if x.size == 1:
        x = x.reshape(())
    return {spec.outs[0]: jnp.broadcast_to(x, shape)}


@register_op("outer")
def _outer(spec: OpSpec, env: dict) -> dict:
    """Rank-1 outer product ``a ⊗ b`` — the matrix grad of ``mv``."""
    a, b = env[spec.ins[0]], env[spec.ins[1]]
    return {spec.outs[0]: a[:, None] * b[None, :]}


@register_op("relu_grad")
def _relu_grad(spec: OpSpec, env: dict) -> dict:
    g, x = env[spec.ins[0]], env[spec.ins[1]]
    return {spec.outs[0]: g * (x > 0).astype(g.dtype)}


@register_op("gelu_grad")
def _gelu_grad(spec: OpSpec, env: dict) -> dict:
    """Exact (tanh-approx) gelu VJP via jax's own rule, so registry-vs-jax
    gradient parity is bit-tight."""
    import jax
    g, x = env[spec.ins[0]], env[spec.ins[1]]
    _, vjp = jax.vjp(jax.nn.gelu, x)
    return {spec.outs[0]: vjp(g)[0]}


@register_op("softmax_grad")
def _softmax_grad(spec: OpSpec, env: dict) -> dict:
    """``y * (g - sum(g*y, axis))`` with ``y`` the forward softmax output."""
    axis = int(spec.attrs.get("axis", -1))
    g, y = env[spec.ins[0]], env[spec.ins[1]]
    return {spec.outs[0]: y * (g - (g * y).sum(axis=axis, keepdims=True))}


@register_op("conv2d_input_grad")
def _conv2d_input_grad(spec: OpSpec, env: dict) -> dict:
    """Cotangent wrt the conv input: jax.vjp of the (linear) conv at a
    zero input — exact, and stays in lockstep with the forward lowering."""
    import jax
    import jax.numpy as jnp
    s = int(spec.attrs.get("stride", 1))
    groups = int(spec.attrs.get("groups", 1))
    x_shape = tuple(int(v) for v in spec.attrs["x_shape"])
    g, w = env[spec.ins[0]], env[spec.ins[1]]

    def fwd(x):
        return jax.lax.conv_general_dilated(
            x, w, (s, s), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=groups)

    _, vjp = jax.vjp(fwd, jnp.zeros(x_shape, g.dtype))
    return {spec.outs[0]: vjp(g)[0]}


@register_op("conv2d_weight_grad")
def _conv2d_weight_grad(spec: OpSpec, env: dict) -> dict:
    import jax
    import jax.numpy as jnp
    s = int(spec.attrs.get("stride", 1))
    groups = int(spec.attrs.get("groups", 1))
    w_shape = tuple(int(v) for v in spec.attrs["w_shape"])
    g, x = env[spec.ins[0]], env[spec.ins[1]]

    def fwd(w):
        return jax.lax.conv_general_dilated(
            x, w, (s, s), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=groups)

    _, vjp = jax.vjp(fwd, jnp.zeros(w_shape, g.dtype))
    return {spec.outs[0]: vjp(g)[0]}


@register_op("maxpool2d_grad")
def _maxpool2d_grad(spec: OpSpec, env: dict) -> dict:
    """Scatter the cotangent to each window's argmax (jax.vjp of the
    forward reduce_window at the *actual* input)."""
    import jax
    import jax.numpy as jnp
    k = int(spec.attrs["k"])
    g, x = env[spec.ins[0]], env[spec.ins[1]]

    def fwd(z):
        return jax.lax.reduce_window(z, -jnp.inf, jax.lax.max,
                                     (1, 1, k, k), (1, 1, k, k), "VALID")

    _, vjp = jax.vjp(fwd, x)
    return {spec.outs[0]: vjp(g)[0]}


@register_op("slice_grad")
def _slice_grad(spec: OpSpec, env: dict) -> dict:
    """Zero-embed the window cotangent back into the source shape."""
    import jax.numpy as jnp
    g = env[spec.ins[0]]
    x_shape = tuple(int(v) for v in spec.attrs["x_shape"])
    ix = tuple(slice(int(st), int(st) + int(sz))
               for st, sz in zip(spec.attrs["starts"], spec.attrs["sizes"]))
    return {spec.outs[0]: jnp.zeros(x_shape, g.dtype).at[ix].set(g)}


@register_op("mean_grad")
def _mean_grad(spec: OpSpec, env: dict) -> dict:
    """Spread ``g / count`` uniformly over the reduced axes."""
    import jax.numpy as jnp
    g = env[spec.ins[0]]
    x_shape = tuple(int(v) for v in spec.attrs["x_shape"])
    axes = tuple(int(a) for a in spec.attrs["axes"])
    count = 1
    for a in axes:
        count *= x_shape[a]
    return {spec.outs[0]: jnp.broadcast_to(
        jnp.expand_dims(g / count, axes), x_shape)}


@register_op("rglru_scan_grad")
def _rglru_scan_grad(spec: OpSpec, env: dict) -> dict:
    """(da, db) of the RG-LRU recurrence — jax.vjp of the shared
    sequential reference (itself a reverse scan)."""
    import jax
    g, a, b = (env[n] for n in spec.ins)
    _, vjp = jax.vjp(_rglru_reference, a, b)
    da, db = vjp(g)
    return {spec.outs[0]: da, spec.outs[1]: db}


@register_op("ssd_scan_grad")
def _ssd_scan_grad(spec: OpSpec, env: dict) -> dict:
    """(dstates, ddecay) of the SSD inter-chunk recurrence."""
    import jax
    g, states, decay = (env[n] for n in spec.ins)
    _, vjp = jax.vjp(_ssd_reference, states, decay)
    ds, dd = vjp(g)
    return {spec.outs[0]: ds, spec.outs[1]: dd}


@register_op("sumsq")
def _sumsq(spec: OpSpec, env: dict) -> dict:
    """f32 sum of squares to a (1, 1) carrier (global-norm partials —
    matches ``optimizer.clip_by_global_norm``'s per-leaf term)."""
    import jax.numpy as jnp
    x = env[spec.ins[0]]
    return {spec.outs[0]:
            jnp.sum(jnp.square(x.astype(jnp.float32))).reshape(1, 1)}


@register_op("clip_scale")
def _clip_scale(spec: OpSpec, env: dict) -> dict:
    """Global-norm clip factor from the summed squares: outs are
    ``(scale, norm)``, both (1, 1) carriers."""
    import jax.numpy as jnp
    max_norm = float(spec.attrs["max_norm"])
    norm = jnp.sqrt(env[spec.ins[0]])
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return {spec.outs[0]: scale, spec.outs[1]: norm}


@register_op("lr_sched")
def _lr_sched(spec: OpSpec, env: dict) -> dict:
    """Warmup + cosine decay, the exact ``optimizer.lr_at`` arithmetic.
    The input is the *already incremented* step (a (1, 1) f32 carrier),
    matching ``adamw_update``'s ``lr_at(state['step'] + 1, oc)`` call."""
    import jax.numpy as jnp
    a = spec.attrs
    lr0 = float(a["lr"])
    warm_n = float(a["warmup_steps"])
    total = float(a["total_steps"])
    frac = float(a["min_lr_frac"])
    step = env[spec.ins[0]].reshape(()).astype(jnp.float32)
    warm = lr0 * (step + 1.0) / max(warm_n, 1.0)
    prog = jnp.clip((step - warm_n) / max(total - warm_n, 1.0), 0.0, 1.0)
    cos = lr0 * (frac + (1.0 - frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
    return {spec.outs[0]:
            jnp.where(step < warm_n, warm, cos).reshape(1, 1)}


@register_op("adamw_step")
def _adamw_step(spec: OpSpec, env: dict) -> dict:
    """One decoupled-weight-decay Adam update for a single parameter —
    the exact per-leaf arithmetic of ``optimizer.adamw_update`` with the
    global clip ``scale`` and scheduled ``lr`` as (1, 1) operands.
    ins: (p, g, m, v, scale, lr, step2); outs: (p2, m2, v2)."""
    import jax.numpy as jnp
    a = spec.attrs
    b1, b2 = float(a["b1"]), float(a["b2"])
    eps, wd = float(a["eps"]), float(a["wd"])
    p, g, m, v, scale, lr, step2 = (env[n] for n in spec.ins)
    f32 = jnp.float32
    g32 = g.astype(f32) * scale.reshape(())
    step_f = step2.reshape(()).astype(f32)
    p32 = p.astype(f32)
    m2 = b1 * m.astype(f32) + (1.0 - b1) * g32
    v2 = b2 * v.astype(f32) + (1.0 - b2) * g32 * g32
    mh = m2 / (1.0 - b1 ** step_f)
    vh = v2 / (1.0 - b2 ** step_f)
    delta = mh / (jnp.sqrt(vh) + eps) + wd * p32
    p2 = p32 - lr.reshape(()) * delta
    return {spec.outs[0]: p2.astype(p.dtype),
            spec.outs[1]: m2, spec.outs[2]: v2}


# --------------------------------------------------------------------------
# VJP rules (ISSUE 10): kind -> rule, the same registry discipline as the
# implementations — rules live in *code* keyed by kind, while everything
# they emit is plain OpSpec *data* in a second DataflowGraph, so the
# backward pickles/caches/reloads like any forward graph and the whole
# pass pipeline (fusion, routing, caching) applies to it unchanged.
#
# A rule takes ``(spec, g, b)``:
#
# ``spec``
#     the forward task's OpSpec;
# ``g``
#     {out buffer -> cotangent buffer name} for the *live* outputs only
#     (outputs on a path to the loss; at least one, or the task is
#     skipped entirely);
# ``b``
#     the backward-graph builder (``core.autodiff`` passes its
#     ``_BwdBuilder``): ``b.shape(name)`` reports a buffer's shape,
#     ``b.res(name)`` imports a forward buffer as a shared residual, the
#     op helpers (``b.add/mul/scale/matmul/...``) emit spec'd tasks and
#     return the produced buffer name.
#
# Rules return the input cotangents either as ``{in buffer: cot buffer}``
# or as a ``[(in buffer, cot buffer)]`` pair list.  The pair list is
# *required* whenever one buffer can appear in several operand slots
# (``mul(x, x)``, ``matmul(x, x)``, ...): each pair accumulates
# separately, a dict would silently drop one term.
# --------------------------------------------------------------------------

# kind -> rule(spec, g, b) -> {in: cot} | [(in, cot)]
_VJP_REGISTRY: dict[str, Callable] = {}


def register_vjp(kind: str):
    """Decorator registering a VJP rule for ``kind`` (replace-on-repeat,
    like :func:`register_op`; does *not* bump the registry epoch — rules
    never change already-materialized forward numerics)."""

    def deco(fn: Callable):
        _VJP_REGISTRY[kind] = fn
        return fn

    return deco


def has_vjp(kind: str) -> bool:
    return kind in _VJP_REGISTRY


def differentiable_ops() -> list[str]:
    return sorted(_VJP_REGISTRY)


def vjp_rule(kind: str) -> Callable:
    try:
        return _VJP_REGISTRY[kind]
    except KeyError:
        raise UnknownOpError(
            f"no VJP rule registered for op kind {kind!r}; differentiable "
            f"kinds: {differentiable_ops()}") from None


@register_vjp("identity")
def _identity_vjp(spec, g, b):
    return {spec.ins[0]: g[spec.outs[0]]}


@register_vjp("dup")
def _dup_vjp(spec, g, b):
    return {spec.ins[0]: b.add_n([g[o] for o in spec.outs if o in g])}


@register_vjp("zeros")
def _zeros_vjp(spec, g, b):
    return {}


@register_vjp("const")
def _const_vjp(spec, g, b):
    return {}


def _pad_window_vjp(spec, g, b):
    p = int(spec.attrs["pad"])
    x_shape = b.shape(spec.ins[0])
    starts = (0, 0) + (p,) * (len(x_shape) - 2)
    return {spec.ins[0]: b.slice(g[spec.outs[0]], starts, x_shape)}


register_vjp("pad2d")(_pad_window_vjp)
register_vjp("fill_interior")(_pad_window_vjp)


@register_vjp("conv2d")
def _conv2d_vjp(spec, g, b):
    go = g[spec.outs[0]]
    x, w = spec.ins
    base = {"stride": int(spec.attrs.get("stride", 1)),
            "groups": int(spec.attrs.get("groups", 1))}
    dx = b.emit("conv2d_input_grad", (go, b.res(w)), (b.shape(x),),
                dict(base, x_shape=b.shape(x)), op="conv",
                flops=2.0)[0]
    dw = b.emit("conv2d_weight_grad", (go, b.res(x)), (b.shape(w),),
                dict(base, w_shape=b.shape(w)), op="conv",
                flops=2.0)[0]
    return {x: dx, w: dw}


@register_vjp("relu")
def _relu_vjp(spec, g, b):
    return {spec.ins[0]: b.ewise(
        "relu_grad", (g[spec.outs[0]], b.res(spec.ins[0])))}


@register_vjp("gelu")
def _gelu_vjp(spec, g, b):
    return {spec.ins[0]: b.ewise(
        "gelu_grad", (g[spec.outs[0]], b.res(spec.ins[0])), flops=12.0)}


@register_vjp("add")
def _add_vjp(spec, g, b):
    go = g[spec.outs[0]]
    return [(spec.ins[0], go), (spec.ins[1], go)]


@register_vjp("vadd")
def _vadd_vjp(spec, g, b):
    go = g[spec.outs[0]]
    al = float(spec.attrs.get("alpha", 1.0))
    be = float(spec.attrs.get("beta", 1.0))
    return [(spec.ins[0], go if al == 1.0 else b.scale(go, al)),
            (spec.ins[1], go if be == 1.0 else b.scale(go, be))]


@register_vjp("scale")
def _scale_vjp(spec, g, b):
    return {spec.ins[0]: b.scale(g[spec.outs[0]], float(spec.attrs["s"]))}


@register_vjp("affine")
def _affine_vjp(spec, g, b):
    a = float(spec.attrs.get("a", 1.0))
    go = g[spec.outs[0]]
    return {spec.ins[0]: go if a == 1.0 else b.scale(go, a)}


@register_vjp("divc")
def _divc_vjp(spec, g, b):
    return {spec.ins[0]: b.divc(g[spec.outs[0]], float(spec.attrs["c"]))}


@register_vjp("rdivc")
def _rdivc_vjp(spec, g, b):
    # d(c/x)/dx = -c/x^2 = -y^2/c with y the forward output residual.
    go = g[spec.outs[0]]
    c = float(spec.attrs["c"])
    y = b.res(spec.outs[0])
    return {spec.ins[0]: b.scale(b.mul(go, b.mul(y, y)), -1.0 / c)}


@register_vjp("div")
def _div_vjp(spec, g, b):
    go = g[spec.outs[0]]
    y = b.res(spec.outs[0])
    den = b.res(spec.ins[1])
    da = b.div(go, den)
    db = b.scale(b.div(b.mul(go, y), den), -1.0)
    return [(spec.ins[0], da), (spec.ins[1], db)]


@register_vjp("mul")
def _mul_vjp(spec, g, b):
    go = g[spec.outs[0]]
    xa, xb = spec.ins
    if xa == xb:
        return [(xa, b.scale(b.mul(go, b.res(xa)), 2.0))]
    return [(xa, b.mul(go, b.res(xb))), (xb, b.mul(go, b.res(xa)))]


@register_vjp("matmul")
def _matmul_vjp(spec, g, b):
    go = g[spec.outs[0]]
    A, B = spec.ins
    dA = b.matmul(go, b.transpose(b.res(B)))
    dB = b.matmul(b.transpose(b.res(A)), go)
    return [(A, dA), (B, dB)]


@register_vjp("mv")
def _mv_vjp(spec, g, b):
    go = g[spec.outs[0]]
    A, x = spec.ins
    trans = bool(spec.attrs.get("trans", False))
    if trans:                       # y = A.T @ x
        dA = b.outer(b.res(x), go)
        dx = b.mv(b.res(A), go, trans=False)
    else:                           # y = A @ x
        dA = b.outer(go, b.res(x))
        dx = b.mv(b.res(A), go, trans=True)
    return [(A, dA), (x, dx)]


@register_vjp("transpose")
def _transpose_vjp(spec, g, b):
    # Both emitted perms (2-D T, batched (0, 2, 1)) are self-inverse.
    return {spec.ins[0]: b.transpose(g[spec.outs[0]])}


@register_vjp("reshape")
def _reshape_vjp(spec, g, b):
    x_shape = b.shape(spec.ins[0])
    return {spec.ins[0]: b.emit("reshape", (g[spec.outs[0]],), (x_shape,),
                                {"shape": x_shape}, op="copy", flops=0.0)[0]}


@register_vjp("concat")
def _concat_vjp(spec, g, b):
    go = g[spec.outs[0]]
    if len(spec.ins) == 1:
        return [(spec.ins[0], go)]
    axis = int(spec.attrs.get("axis", 0))
    sizes = tuple(b.shape(i)[axis] for i in spec.ins)
    return list(zip(spec.ins, b.split(go, sizes, axis)))


@register_vjp("split")
def _split_vjp(spec, g, b):
    axis = int(spec.attrs.get("axis", 0))
    pieces = [g[o] if o in g else b.zeros(b.shape(o)) for o in spec.outs]
    return {spec.ins[0]: b.concat(pieces, axis)}


@register_vjp("slice")
def _slice_vjp(spec, g, b):
    x_shape = b.shape(spec.ins[0])
    attrs = {"starts": tuple(int(s) for s in spec.attrs["starts"]),
             "sizes": tuple(int(s) for s in spec.attrs["sizes"]),
             "x_shape": x_shape}
    return {spec.ins[0]: b.emit("slice_grad", (g[spec.outs[0]],),
                                (x_shape,), attrs, op="copy", flops=0.0)[0]}


@register_vjp("softmax")
def _softmax_vjp(spec, g, b):
    axis = int(spec.attrs.get("axis", -1))
    return {spec.ins[0]: b.ewise(
        "softmax_grad", (g[spec.outs[0]], b.res(spec.outs[0])),
        {"axis": axis}, flops=4.0)}


@register_vjp("maxpool2d")
def _maxpool2d_vjp(spec, g, b):
    x = spec.ins[0]
    x_shape = b.shape(x)
    return {x: b.emit("maxpool2d_grad", (g[spec.outs[0]], b.res(x)),
                      (x_shape,), {"k": int(spec.attrs["k"])},
                      op="pool")[0]}


@register_vjp("mean")
def _mean_vjp(spec, g, b):
    x_shape = b.shape(spec.ins[0])
    axes = tuple(int(a) for a in spec.attrs["axes"])
    return {spec.ins[0]: b.emit("mean_grad", (g[spec.outs[0]],), (x_shape,),
                                {"axes": axes, "x_shape": x_shape})[0]}


@register_vjp("mean_all")
def _mean_all_vjp(spec, g, b):
    x_shape = b.shape(spec.ins[0])
    count = 1
    for s in x_shape:
        count *= int(s)
    scaled = b.divc(g[spec.outs[0]], float(count))
    return {spec.ins[0]: b.emit("bcast", (scaled,), (x_shape,),
                                {"shape": x_shape}, op="copy", flops=0.0)[0]}


@register_vjp("rglru_scan")
def _rglru_scan_vjp(spec, g, b):
    a, bb = spec.ins
    da, db = b.emit("rglru_scan_grad",
                    (g[spec.outs[0]], b.res(a), b.res(bb)),
                    (b.shape(a), b.shape(bb)), op="scan", flops=4.0)
    return [(a, da), (bb, db)]


@register_vjp("ssd_scan")
def _ssd_scan_vjp(spec, g, b):
    st, dec = spec.ins
    ds, dd = b.emit("ssd_scan_grad",
                    (g[spec.outs[0]], b.res(st), b.res(dec)),
                    (b.shape(st), b.shape(dec)), op="scan", flops=4.0)
    return [(st, ds), (dec, dd)]


__all__ = ["OpSpec", "UnknownOpError", "differentiable_ops", "has_vjp",
           "materialize", "op_impl", "register_op", "register_vjp",
           "registered_ops", "vjp_rule"]
