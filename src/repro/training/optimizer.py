"""AdamW + LR schedule + global-norm clipping (no external deps).

Optimizer moments are fp32 regardless of param dtype (mixed-precision
posture: bf16 params, fp32 state, fp32 update math).  State pytrees mirror
the param tree so the sharding rules of distributed/sharding.py apply
leaf-for-leaf.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(step: jax.Array, oc: OptConfig) -> jax.Array:
    warm = oc.lr * (step + 1) / max(oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.lr * (oc.min_lr_frac + (1 - oc.min_lr_frac)
                   * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads: Any, state: dict, params: Any, oc: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads32, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    step = state["step"] + 1
    lr = lr_at(step, oc)
    b1c = 1.0 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - oc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = oc.b1 * m + (1 - oc.b1) * g
        v2 = oc.b2 * v + (1 - oc.b2) * g * g
        mh, vh = m2 / b1c, v2 / b2c
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads32, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
