"""Training launcher.

CPU-scale (smoke config, runnable here):
    PYTHONPATH=src python -m repro.training.cli --arch gemma-7b --smoke \\
        --steps 20 --batch 4 --seq 128

Production posture (full config; on a real v5e fleet):
    python -m repro.training.cli --arch qwen1.5-110b --steps 10000 \\
        --batch 256 --seq 4096 --ckpt /ckpts/qwen

The launcher wires: config → data pipeline (prefetching) → jitted train
step (remat, accumulation) → async checkpointer → heartbeat/straggler
monitors, and prints the off-chip transfer manifest (host code analogue).

``--compiled`` instead drives :func:`~repro.training.train_loop.
train_compiled` over a graph-level-autodiff
:class:`~repro.api.CompiledTrainStep` (GPT-2 block regression loss):
forward, backward and AdamW update each compiled through the full pass
pipeline — see docs/autodiff.md.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import SHAPES, get_config  # noqa: F401 — SHAPES re-export
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (SimulatedFailure, resume,
                                       resume_compiled, train,
                                       train_compiled)


def _main_compiled(args) -> int:
    """The ``--compiled`` path: graph-level autodiff end to end."""
    import numpy as np

    import repro.api as codo
    from repro.models.dataflow_models import gpt2_block_loss_fn

    d_model = args.d_model or 256
    oc = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                   total_steps=args.steps)
    step = codo.compile(gpt2_block_loss_fn, (args.seq, d_model),
                        (args.seq, d_model), grad=True,
                        name="gpt2_block_loss", opt=oc)
    rng = np.random.default_rng(args.seed)

    def batch_fn(i: int) -> tuple:
        x = rng.standard_normal((args.seq, d_model)).astype(np.float32)
        return x, 0.9 * x  # learnable regression target

    ckpt = Checkpointer(args.ckpt) if args.ckpt else None
    t0 = time.time()
    try:
        if args.resume and ckpt is not None and ckpt.steps():
            params, opt, report = resume_compiled(
                step, ckpt, steps=args.steps, batch_fn=batch_fn,
                checkpoint_every=args.ckpt_every)
        else:
            params, opt, report = train_compiled(
                step, steps=args.steps, batch_fn=batch_fn, checkpointer=ckpt,
                checkpoint_every=args.ckpt_every,
                fail_at=args.fail_at or None)
    except SimulatedFailure as e:
        print(f"!! {e} — restart with --resume to continue from the last "
              f"checkpoint")
        return 42
    finally:
        if ckpt is not None:
            ckpt.wait()
    dt = time.time() - t0
    print(f"compiled train step: steps={report.steps_done} "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"({dt:.1f}s, {report.straggler_flags} straggler flags, "
          f"checkpoints at {report.checkpoints})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="ArchConfig name (required unless --compiled)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--compiled", action="store_true",
                    help="drive a pipeline-compiled train step "
                         "(graph-level autodiff) instead of the jitted "
                         "transformer loop")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a failure after N steps (restart demo)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0, help="override d_model")
    ap.add_argument("--vocab", type=int, default=0, help="override vocab")
    args = ap.parse_args(argv)

    if args.compiled:
        return _main_compiled(args)
    if not args.arch:
        ap.error("--arch is required (or pass --compiled)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    import dataclasses
    over = {}
    if args.layers:
        over["n_layers"] = args.layers
    if args.d_model:
        over["d_model"] = args.d_model
        over["d_ff"] = 4 * args.d_model
        over["head_dim"] = 0
    if args.vocab:
        over["vocab"] = args.vocab
    if over:
        cfg = dataclasses.replace(cfg, **over)
    oc = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                   total_steps=args.steps)

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    source = SyntheticLM(cfg, dc)
    prefetch = Prefetcher(source)
    batches: dict[int, dict] = {}

    def batch_fn(step: int) -> dict:
        while step not in batches:
            s, b = prefetch.next()
            batches[s] = b
        return {k: jax.numpy.asarray(v) for k, v in batches.pop(step).items()}

    ckpt = Checkpointer(args.ckpt) if args.ckpt else None
    t0 = time.time()
    try:
        if args.resume and ckpt is not None and ckpt.steps():
            params, opt, report = resume(
                cfg, ckpt, steps=args.steps, batch_fn=batch_fn, oc=oc,
                seed=args.seed, checkpoint_every=args.ckpt_every)
        else:
            params, opt, report = train(
                cfg, steps=args.steps, batch_fn=batch_fn, checkpointer=ckpt,
                checkpoint_every=args.ckpt_every, oc=oc, seed=args.seed,
                fail_at=args.fail_at or None)
    except SimulatedFailure as e:
        print(f"!! {e} — restart with --resume to continue from the last "
              f"checkpoint")
        prefetch.close()
        return 42
    finally:
        if ckpt is not None:
            ckpt.wait()

    prefetch.close()
    dt = time.time() - t0
    print(f"arch={cfg.name} steps={report.steps_done} "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"({dt:.1f}s, {report.straggler_flags} straggler flags, "
          f"checkpoints at {report.checkpoints})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
