"""Training step construction + fault-tolerant training driver.

``build_train_step(cfg, mesh, ...)`` returns a jit-able function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` with

* per-group remat (activation checkpointing) inside the layer scan,
* optional gradient accumulation over microbatches (lax.scan — keeps the
  HLO one-microbatch-sized and lets XLA overlap the reduce-scatter of
  microbatch i with the backward of i+1),
* optional int8 gradient compression with error feedback (the
  distributed-optimization trick; see distributed/compression.py),
* parameter/optimizer-state donation.

``train(...)`` is the driver: data pipeline, async checkpointing,
heartbeat/straggler monitoring, simulated-failure injection for tests, and
elastic restart (restore into a smaller mesh) — DESIGN.md §5.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..distributed import compression
from ..distributed.sharding import as_shardings, batch_specs, param_specs
from ..models import transformer as tf
from .optimizer import OptConfig, adamw_init, adamw_update


def build_train_step(cfg: ArchConfig, oc: OptConfig | None = None, *,
                     accum: int = 1, remat: bool = True,
                     compress_grads: bool = False,
                     dp_axes: tuple[str, ...] = ()) -> Callable:
    """The function the dry-run lowers and the trainer executes."""
    oc = oc or OptConfig()

    def loss_of(params, batch):
        return tf.loss_fn(params, batch, cfg, remat=remat)

    def train_step(params, opt_state, batch):
        if accum > 1:
            def micro(g_acc, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return jax.tree.map(lambda a, b: a + b, g_acc, g), l

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if compress_grads and dp_axes:
            grads, opt_state = compression.compressed_allreduce(
                grads, opt_state, dp_axes)

        params, opt_state, metrics = adamw_update(grads, opt_state, params, oc)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def jit_train_step(cfg: ArchConfig, mesh, params_or_shapes, batch_like,
                   oc: OptConfig | None = None, *, accum: int = 1,
                   remat: bool = True, donate: bool = True):
    """jit with explicit in/out shardings (the dry-run entry point)."""
    pspecs = param_specs(params_or_shapes, mesh, cfg)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    bspecs = batch_specs(batch_like, mesh)
    step = build_train_step(cfg, oc, accum=accum, remat=remat)
    # NamedShardings, not bare specs: older jax.jit rejects PartitionSpec.
    pshard, oshard, bshard = (as_shardings(s, mesh)
                              for s in (pspecs, ospecs, bspecs))
    return jax.jit(
        step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1) if donate else (),
    )


# --------------------------------------------------------------------------
# Fault-tolerant driver
# --------------------------------------------------------------------------


@dataclass
class TrainReport:
    steps_done: int = 0
    restarts: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    straggler_flags: int = 0
    checkpoints: list = field(default_factory=list)


class StepTimeMonitor:
    """EWMA step-time tracker; flags stragglers (steps ≥ k× the mean)."""

    def __init__(self, k: float = 3.0, alpha: float = 0.2):
        self.k, self.alpha, self.mean = k, alpha, None
        self.flags = 0

    def observe(self, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        slow = dt > self.k * self.mean
        if slow:
            self.flags += 1
        self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
        return slow


class Heartbeat:
    """Deadline monitor: ``beat()`` every step; ``expired()`` signals a
    hang (on real fleets this triggers the coordinator's restart path)."""

    def __init__(self, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self.last = time.monotonic()

    def beat(self) -> None:
        self.last = time.monotonic()

    def expired(self) -> bool:
        return time.monotonic() - self.last > self.timeout_s


def train(cfg: ArchConfig, *, steps: int, batch_fn: Callable[[int], dict],
          checkpointer=None, checkpoint_every: int = 50,
          oc: OptConfig | None = None, seed: int = 0, mesh=None,
          fail_at: int | None = None, params=None, opt_state=None,
          start_step: int = 0, remat: bool = True) -> tuple[Any, Any, TrainReport]:
    """CPU-runnable training driver with checkpoint/restart semantics.

    ``fail_at`` injects a simulated failure (raises) after that step — the
    restart path (tests/examples) calls ``train`` again with the restored
    state, possibly on a different mesh (elastic restart).
    """
    oc = oc or OptConfig()
    report = TrainReport()
    if params is None:
        params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    if opt_state is None:
        opt_state = adamw_init(params)

    step_fn = jax.jit(build_train_step(cfg, oc, remat=remat),
                      donate_argnums=(0, 1))
    monitor, hb = StepTimeMonitor(), Heartbeat()

    for step in range(start_step, steps):
        t0 = time.perf_counter()
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        hb.beat()
        if monitor.observe(dt):
            report.straggler_flags += 1
        report.losses.append(loss)
        report.step_times.append(dt)
        report.steps_done = step + 1
        if checkpointer is not None and (step + 1) % checkpoint_every == 0:
            checkpointer.save(step + 1, {"params": params, "opt": opt_state})
            report.checkpoints.append(step + 1)
        if fail_at is not None and step + 1 >= fail_at:
            raise SimulatedFailure(f"injected failure at step {step + 1}")
    return params, opt_state, report


class SimulatedFailure(RuntimeError):
    pass


def resume(cfg: ArchConfig, checkpointer, *, steps: int, batch_fn,
           seed: int = 0, shardings=None, **kw):
    """Restore the latest checkpoint and continue (the restart path).
    Works onto a different mesh via ``shardings`` (elastic restart)."""
    like = {"params": tf.init_params(cfg, jax.random.PRNGKey(seed))}
    like["opt"] = adamw_init(like["params"])
    step, state = checkpointer.restore_latest(like, shardings)
    return train(cfg, steps=steps, batch_fn=batch_fn,
                 checkpointer=checkpointer, params=state["params"],
                 opt_state=state["opt"], start_step=step, seed=seed, **kw)


# --------------------------------------------------------------------------
# Compiled driver (graph-level autodiff)
# --------------------------------------------------------------------------


def train_compiled(step, *, steps: int, batch_fn: Callable[[int], tuple],
                   checkpointer=None, checkpoint_every: int = 50,
                   params=None, opt_state=None, start_step: int = 0,
                   fail_at: int | None = None, verify_every: int = 0,
                   jit: bool = True) -> tuple[Any, Any, TrainReport]:
    """The :func:`train` driver over a
    :class:`~repro.api.CompiledTrainStep` — forward, backward and AdamW
    update all run as pipeline-compiled dataflow graphs instead of one
    jitted ``value_and_grad``.

    Semantics match :func:`train`: same :class:`TrainReport`, the same
    straggler/heartbeat monitors, the same ``fail_at`` injection and
    checkpoint format (``{"params", "opt"}`` with ``optimizer``-layout
    opt state), so :func:`resume_compiled` restores checkpoints written
    by either driver.  ``batch_fn(step)`` returns the positional input
    arrays of the loss graph (e.g. ``(x, target)``).

    ``verify_every=N`` keeps the plain-jit path as a verification
    oracle: every N steps the compiled loss/gradients are re-checked
    against eager ``jax.grad`` of the source graph on that step's batch
    (raises on divergence beyond the documented fp band).
    """
    report = TrainReport()
    if params is None:
        params = step.init_params()
    if opt_state is None:
        opt_state = step.init_opt_state(params)
    monitor, hb = StepTimeMonitor(), Heartbeat()

    for i in range(start_step, steps):
        t0 = time.perf_counter()
        batch = batch_fn(i)
        if verify_every and i % verify_every == 0:
            step.verify(*batch, params=params)
        params, opt_state, metrics = step.step(params, opt_state, *batch,
                                               jit=jit)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        hb.beat()
        if monitor.observe(dt):
            report.straggler_flags += 1
        report.losses.append(loss)
        report.step_times.append(dt)
        report.steps_done = i + 1
        if checkpointer is not None and (i + 1) % checkpoint_every == 0:
            checkpointer.save(i + 1, {"params": params, "opt": opt_state})
            report.checkpoints.append(i + 1)
        if fail_at is not None and i + 1 >= fail_at:
            raise SimulatedFailure(f"injected failure at step {i + 1}")
    return params, opt_state, report


def resume_compiled(step, checkpointer, *, steps: int, batch_fn, **kw):
    """Restore the latest checkpoint and continue on the compiled step
    (the restart path of :func:`train_compiled`)."""
    like = {"params": step.init_params()}
    like["opt"] = step.init_opt_state(like["params"])
    at, state = checkpointer.restore_latest(like, None)
    return train_compiled(step, steps=steps, batch_fn=batch_fn,
                          checkpointer=checkpointer, params=state["params"],
                          opt_state=state["opt"], start_step=at, **kw)
