"""Architecture & shape configuration schema.

Every assigned architecture is a single :class:`ArchConfig` in its own
``configs/<id>.py``.  ``smoke()`` derives a reduced same-family config for
CPU tests; the full config is only ever lowered via ShapeDtypeStructs in
the dry-run.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned): every LM-family arch × these four cells.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor for dense (dropless-approx) dispatch
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    act: str = "silu"               # silu | gelu | geglu | relu
    glu: bool = True                # gated FFN (SwiGLU/GeGLU)
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    pos: str = "rope"               # rope | learned | none
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # attention pattern
    window: int = 0                 # 0 = full attention; >0 = sliding window
    subquadratic: bool = False      # True -> long_500k cell is runnable
    # mixture of experts
    moe: MoEConfig | None = None
    # state-space (mamba2)
    ssm: SSMConfig | None = None
    # hybrid block pattern, e.g. ("rglru","rglru","attn"); ("attn",) default
    block_pattern: tuple[str, ...] = ("attn",)
    local_window: int = 2048        # window for hybrid local-attn blocks
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500          # whisper audio frames (post conv-stub)
    # multimodal prefix (internvl)
    n_patches: int = 0              # vision patch tokens prepended (stub frontend)
    # provenance
    source: str = ""
    dtype: str = "bfloat16"

    # --- derived -----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab rounded up to a multiple of 256 so the
        vocab dim shards evenly over any mesh axis (standard practice;
        labels never index the pad region)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def attn_params(self) -> int:
        o = self.q_dim * self.d_model
        qkv = self.d_model * (self.q_dim + 2 * self.kv_dim)
        return qkv + o

    def ffn_params(self) -> int:
        mult = 3 if self.glu else 2
        per = mult * self.d_model * self.d_ff
        if self.moe:
            return per * self.moe.num_experts + self.d_model * self.moe.num_experts
        return per

    def layer_params(self) -> int:
        if self.family == "ssm" and self.ssm is not None:
            d_in = self.d_model * self.ssm.expand
            nheads = d_in // self.ssm.head_dim
            in_proj = self.d_model * (2 * d_in + 2 * self.ssm.d_state + nheads)
            out = d_in * self.d_model
            return in_proj + out + 2 * self.d_model
        n_attn = sum(1 for b in self.block_pattern if b == "attn")
        n_rec = len(self.block_pattern) - n_attn
        frac_attn = n_attn / len(self.block_pattern)
        attn = self.attn_params() * frac_attn
        rec = 0.0
        if n_rec:
            # rg-lru block: in/out proj + gates ~ 3*d*d_rnn with d_rnn ~ d
            rec = (1 - frac_attn) * 4 * self.d_model * self.d_model
        return int(attn + rec + self.ffn_params() + 2 * self.d_model)

    def param_count(self) -> int:
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        enc = self.n_enc_layers * (self.attn_params() + self.ffn_params())
        return emb + head + self.n_layers * self.layer_params() + enc + self.d_model

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        if not self.moe:
            return self.param_count()
        dense = self.param_count() - self.n_layers * self.ffn_params()
        per_expert = (3 if self.glu else 2) * self.d_model * self.d_ff
        active_ffn = self.n_layers * per_expert * self.moe.top_k
        return dense + active_ffn

    def runnable(self, shape: ShapeSpec) -> tuple[bool, str]:
        """Cell applicability (skips recorded in EXPERIMENTS.md)."""
        if shape.name == "long_500k" and not self.subquadratic:
            return False, ("full quadratic attention: 512k-token decode has no "
                           "sub-quadratic path on this arch (see DESIGN.md §4)")
        return True, ""

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, len(self.block_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=128,
            vocab=512,
            head_dim=16 if self.head_dim else 0,
            moe=MoEConfig(4, min(self.moe.top_k, 2)) if self.moe else None,
            ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=32,
                          conv_width=4) if self.ssm else None,
            n_enc_layers=2 if self.enc_dec else 0,
            enc_frames=16 if self.enc_dec else self.enc_frames,
            n_patches=8 if self.n_patches else 0,
            window=min(self.window, 64) if self.window else 0,
            local_window=64,
            dtype="float32",
        )
