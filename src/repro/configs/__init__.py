"""Config registry: ``get_config("<arch>")`` / ``--arch <id>``."""

from .base import SHAPES, ArchConfig, MoEConfig, ShapeSpec, SSMConfig
from .gemma_7b import ARCH as GEMMA_7B
from .gpt2_medium import ARCH as GPT2_MEDIUM
from .internvl2_1b import ARCH as INTERNVL2_1B
from .mamba2_780m import ARCH as MAMBA2_780M
from .mistral_large_123b import ARCH as MISTRAL_LARGE_123B
from .mixtral_8x22b import ARCH as MIXTRAL_8X22B
from .moonshot_v1_16b_a3b import ARCH as MOONSHOT_V1_16B_A3B
from .qwen1_5_110b import ARCH as QWEN1_5_110B
from .recurrentgemma_9b import ARCH as RECURRENTGEMMA_9B
from .starcoder2_15b import ARCH as STARCODER2_15B
from .whisper_large_v3 import ARCH as WHISPER_LARGE_V3

# The ten assigned architectures (the benchmark grid) + the paper's GPT-2.
ASSIGNED: dict[str, ArchConfig] = {
    a.name: a for a in [
        GEMMA_7B, QWEN1_5_110B, STARCODER2_15B, MISTRAL_LARGE_123B,
        WHISPER_LARGE_V3, RECURRENTGEMMA_9B, INTERNVL2_1B,
        MOONSHOT_V1_16B_A3B, MIXTRAL_8X22B, MAMBA2_780M,
    ]
}

CONFIGS: dict[str, ArchConfig] = dict(ASSIGNED)
CONFIGS[GPT2_MEDIUM.name] = GPT2_MEDIUM


def get_config(name: str) -> ArchConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[name]


def list_configs() -> list[str]:
    return sorted(CONFIGS)


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "ShapeSpec", "SHAPES",
           "ASSIGNED", "CONFIGS", "get_config", "list_configs"]
