"""internvl2-1b [vlm] — InternViT frontend STUB (precomputed patch
embeddings via input_specs()) + InternLM2-1B language backbone.
[arXiv:2404.16821; hf]"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    act="silu",
    glu=True,
    norm="rmsnorm",
    pos="rope",
    n_patches=256,                # vision prefix tokens (stub frontend)
    tie_embeddings=True,
    subquadratic=False,
    source="arXiv:2404.16821",
)
