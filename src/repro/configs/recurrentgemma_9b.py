"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern,
MQA (kv=1), window 2048.  [arXiv:2402.19427; unverified]"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    act="geglu",
    glu=True,
    norm="rmsnorm",
    pos="rope",
    block_pattern=("rglru", "rglru", "attn"),
    local_window=2048,
    tie_embeddings=True,
    subquadratic=True,            # constant RG-LRU state + windowed attn
    source="arXiv:2402.19427",
)
