"""whisper-large-v3 [audio] — encoder-decoder transformer backbone; the
conv/mel frontend is a STUB (input_specs() provides precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                  # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    act="gelu",
    glu=False,
    norm="layernorm",
    pos="learned",
    enc_dec=True,
    n_enc_layers=32,
    enc_frames=1500,
    tie_embeddings=True,
    subquadratic=False,
    source="arXiv:2212.04356",
)
