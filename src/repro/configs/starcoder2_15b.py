"""starcoder2-15b [dense] — GQA kv=4, RoPE, sliding-window 4096.
[arXiv:2402.19173; hf]"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    act="gelu",
    glu=False,                    # starcoder2 uses a plain (non-gated) MLP
    norm="layernorm",
    pos="rope",
    qkv_bias=True,
    window=4096,                  # sliding-window attention
    subquadratic=True,            # windowed KV -> long_500k decode runnable
    source="arXiv:2402.19173",
)
