"""gemma-7b [dense] — GeGLU, head_dim=256, MHA-as-GQA(kv=16).
[arXiv:2403.08295; hf]"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    head_dim=256,
    act="geglu",
    glu=True,
    norm="rmsnorm",
    pos="rope",
    tie_embeddings=True,          # gemma ties input/output embeddings
    subquadratic=False,
    source="arXiv:2403.08295",
)
