"""gpt2-medium — the paper's own LLM workload (§VIII-C, Table VI).
[hf:openai-community/gpt2-medium]"""

from .base import ArchConfig

ARCH = ArchConfig(
    name="gpt2-medium",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=50257,
    act="gelu",
    glu=False,
    norm="layernorm",
    pos="learned",
    tie_embeddings=True,
    subquadratic=False,
    source="hf:openai-community/gpt2-medium",
)
