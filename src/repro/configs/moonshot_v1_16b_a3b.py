"""moonshot-v1-16b-a3b [moe] — 64 experts top-6 (kimi/moonlight),
expert d_ff=1408.  [hf:moonshotai/Moonlight-16B-A3B; hf]"""

from .base import ArchConfig, MoEConfig

ARCH = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    act="silu",
    glu=True,
    norm="rmsnorm",
    pos="rope",
    moe=MoEConfig(num_experts=64, top_k=6),
    subquadratic=False,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
