"""mamba2-780m [ssm] — SSD (state-space duality), attention-free,
ssm_state=128.  [arXiv:2405.21060; unverified]"""

from .base import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    norm="rmsnorm",
    pos="none",
    block_pattern=("ssm",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256, conv_width=4),
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2405.21060",
)
