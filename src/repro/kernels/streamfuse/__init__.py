from .ops import pad_conv_relu, register
from .ref import pad_conv_relu_ref
from .streamfuse import fused_pad_conv_relu

__all__ = ["fused_pad_conv_relu", "pad_conv_relu", "pad_conv_relu_ref",
           "register"]
