from .chain import fused_matmul_chain, fused_softmax_matmul
from .ops import pad_conv_relu, register
from .ref import matmul_chain_ref, pad_conv_relu_ref, softmax_matmul_ref
from .streamfuse import fused_pad_conv_relu

__all__ = ["fused_matmul_chain", "fused_pad_conv_relu",
           "fused_softmax_matmul", "matmul_chain_ref", "pad_conv_relu",
           "pad_conv_relu_ref", "register", "softmax_matmul_ref"]
