"""Jit'd wrapper + CODO-lowering registration for the streamfuse kernel.

``register()`` hooks the kernel into the dataflow compiler's lowering: a
fusion group matching (pad, conv, ewise) — the motivating chain — executes
as this single streaming kernel instead of three XLA ops.
"""

from __future__ import annotations

from functools import partial

import jax

from .ref import pad_conv_relu_ref
from .streamfuse import fused_pad_conv_relu


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def pad_conv_relu(x, w, *, use_kernel: bool = True):
    if not use_kernel:
        return pad_conv_relu_ref(x, w)
    return fused_pad_conv_relu(x, w, interpret=not _on_tpu())


def register() -> None:
    """Register as the lowering for (pad, conv, ewise) fusion groups."""
    from ...core.lowering import register_group_kernel

    def factory(graph, group):
        pad_t = graph.task(group.tasks[0])
        conv_t = graph.task(group.tasks[1])
        relu_t = graph.task(group.tasks[2])
        x_buf = pad_t.reads[0].buffer
        w_buf = next(a.buffer for a in conv_t.reads
                     if graph.buffers[a.buffer].kind == "weight")
        out_buf = relu_t.writes[0].buffer

        def run(env):
            return {out_buf: pad_conv_relu(env[x_buf], env[w_buf])}

        return run

    register_group_kernel(("pad", "conv", "ewise"), factory)
