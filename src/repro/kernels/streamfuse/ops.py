"""Jit'd wrappers + CODO kernel-pattern registration for the streamfuse
fused kernels.

``register()`` hooks four :class:`~repro.core.routing.KernelPattern`\\ s
into the compiler's routing layer:

=======================  ===========================  =====================
pattern name             op pattern                   kernel
=======================  ===========================  =====================
``streamfuse.conv``      ``pad → conv → ewise``       ``fused_pad_conv_relu``
``streamfuse.mmchain``   ``matmul → *ewise → matmul`` ``fused_matmul_chain``
``streamfuse.softmaxmm`` ``softmax → matmul``         ``fused_softmax_matmul``
``streamfuse.mmgrad``    ``matmul → *ewise`` (grad)   ``fused_matmul_grad``
=======================  ===========================  =====================

Feasibility guards are pure graph analysis (spec kinds, strides, ranks,
dtypes) so the routing decision itself stays jax-free; backend selection
happens in the factories:

* on TPU the Pallas kernel runs compiled (declining chains whose resident
  weights would blow the VMEM budget);
* ``CODO_PALLAS_INTERPRET=1`` forces the Pallas kernel in interpret mode
  (how CI exercises the real kernel path on CPU);
* otherwise (CPU/GPU hosts) the kernel's fused jnp reference runs as one
  jit'd function — the same fusion decision, carried by XLA:CPU.
"""

from __future__ import annotations

import functools

import numpy as np

from ...core.ops import op_impl
from ...core.routing import (KernelPattern, pallas_interpret_forced,
                             register_kernel_pattern)
from .ref import (matmul_chain_ref, matmul_grad_ref, pad_conv_relu_ref,
                  softmax_matmul_ref)

# Elementwise spec kinds a kernel can replay on a VMEM block: exactly one
# operand (the chain value), attrs-only parameters.
EW_KINDS = frozenset({"relu", "gelu", "scale", "affine", "divc", "rdivc",
                      "identity"})

# Gradient-epilogue kinds (backward chains): chain value first operand,
# residual operands stream alongside it with the same row-blocking.
GRAD_EW_KINDS = frozenset({"relu_grad", "gelu_grad", "softmax_grad"})

# Resident-operand budget for compiled (TPU) kernels; interpret/reference
# modes are unconstrained.
VMEM_BUDGET_BYTES = 12 * 2 ** 20


def _mode() -> str:
    """'pallas' (compiled, TPU), 'interpret' (forced), or 'reference'."""
    if pallas_interpret_forced():
        return "interpret"
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _vmem_ok(*shapes) -> bool:
    return sum(int(np.prod(s)) for s in shapes) * 4 <= VMEM_BUDGET_BYTES


def _f32(graph, *bufs) -> bool:
    return all(np.dtype(graph.buffers[b].dtype) == np.float32 for b in bufs)


# --------------------------------------------------------------------------
# pad -> conv -> relu (the Fig. 2 motivating chain)
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _ref_conv_jit():
    import jax
    return jax.jit(pad_conv_relu_ref)


def pad_conv_relu(x, w, *, use_kernel: bool = True):
    """relu(conv2d(pad(x), w)), stride 1, SAME; backend-dispatched."""
    if not use_kernel:
        return _ref_conv_jit()(x, w)
    mode = _mode()
    if mode == "reference":
        return _ref_conv_jit()(x, w)
    from .streamfuse import fused_pad_conv_relu
    return fused_pad_conv_relu(x, w, interpret=(mode == "interpret"))


def _conv_feasible(graph, tasks) -> bool:
    pad_t, conv_t, relu_t = tasks
    if any(t.spec is None for t in tasks):
        return False
    if (pad_t.spec.kind, conv_t.spec.kind, relu_t.spec.kind) != (
            "pad2d", "conv2d", "relu"):
        return False
    if int(conv_t.spec.attrs.get("stride", 1)) != 1:
        return False
    if int(conv_t.spec.attrs.get("groups", 1)) != 1:
        return False
    if conv_t.spec.ins[0] != pad_t.spec.outs[0]:
        return False
    x_buf, w_buf = pad_t.spec.ins[0], conv_t.spec.ins[1]
    x_shape = graph.buffers[x_buf].shape
    w_shape = graph.buffers[w_buf].shape
    if len(x_shape) != 4 or len(w_shape) != 4:
        return False
    k = w_shape[-1]
    if w_shape[-2] != k or k % 2 != 1:
        return False
    if int(pad_t.spec.attrs.get("pad", -1)) != k // 2:     # SAME only
        return False
    return _f32(graph, x_buf, w_buf, relu_t.spec.outs[0])


def _conv_factory(graph, group, tasks, tile=None):
    import jax

    pad_t, conv_t, relu_t = tasks
    x_buf, w_buf = pad_t.spec.ins[0], conv_t.spec.ins[1]
    out_buf = relu_t.spec.outs[0]

    mode = _mode()                 # resolved once; the lowering memo key
    if mode == "reference":        # covers the switches that change it
        fn = _ref_conv_jit()
    else:
        from .streamfuse import fused_pad_conv_relu
        fn = jax.jit(functools.partial(fused_pad_conv_relu,
                                       interpret=(mode == "interpret")))

    def run(env):
        return {out_buf: fn(env[x_buf], env[w_buf])}

    return run


# --------------------------------------------------------------------------
# matmul -> *ewise -> matmul
# --------------------------------------------------------------------------


def _mm_chain_feasible(graph, tasks) -> bool:
    first, last = tasks[0], tasks[-1]
    if any(t.spec is None for t in tasks):
        return False
    if first.spec.kind != "matmul" or last.spec.kind != "matmul":
        return False
    prev_out = first.spec.outs[0]
    for t in tasks[1:-1]:
        if t.spec.kind not in EW_KINDS or t.spec.ins != (prev_out,):
            return False
        prev_out = t.spec.outs[0]
    if last.spec.ins[0] != prev_out:    # chain value must stream in as LHS
        return False
    bufs = (*first.spec.ins, last.spec.ins[1], last.spec.outs[0])
    if any(len(graph.buffers[b].shape) != 2 for b in bufs[:3]):
        return False
    return _f32(graph, *bufs)


def _ew_applier(ew_tasks):
    impls = [(op_impl(t.spec.kind), t.spec) for t in ew_tasks]

    def ew(h):
        for impl, spec in impls:
            h = impl(spec, {spec.ins[0]: h})[spec.outs[0]]
        return h

    return ew


def _mm_chain_tiles(graph, tasks):
    """Autotune candidates: row-block sizes for the streamed activation
    (``None`` = the kernel's 128 default).  Reference mode has no blocking
    to sweep — routed-vs-generic is the only question there."""
    if _mode() == "reference":
        return [None]
    m = graph.buffers[tasks[0].spec.ins[0]].shape[0]
    return [None] + [{"block_m": b} for b in (64, 256)
                     if b <= max(m, 64)]


def _mm_chain_factory(graph, group, tasks, tile=None):
    import jax
    from .chain import fused_matmul_chain

    first, last = tasks[0], tasks[-1]
    a_buf, w1_buf = first.spec.ins
    w2_buf = last.spec.ins[1]
    out_buf = last.spec.outs[0]
    ew = _ew_applier(tasks[1:-1])

    mode = _mode()
    if mode == "pallas" and not _vmem_ok(graph.buffers[w1_buf].shape,
                                         graph.buffers[w2_buf].shape):
        return None                     # resident weights exceed VMEM
    if mode == "reference":
        fn = jax.jit(lambda a, w1, w2: matmul_chain_ref(a, w1, w2, ew))
    else:
        block_m = int((tile or {}).get("block_m", 128))
        fn = jax.jit(functools.partial(fused_matmul_chain, ew=ew,
                                       block_m=block_m,
                                       interpret=(mode == "interpret")))

    def run(env):
        return {out_buf: fn(env[a_buf], env[w1_buf], env[w2_buf])}

    return run


# --------------------------------------------------------------------------
# matmul -> *ewise gradient epilogue (backward-pass chains)
# --------------------------------------------------------------------------


def _mm_grad_feasible(graph, tasks) -> bool:
    """Backward chains only: a cotangent matmul whose elementwise tail
    contains at least one gradient kind (so forward ``matmul → ewise``
    prefixes are never claimed and the longer ``mmchain`` match still
    supersedes this one over shared tasks)."""
    mm, tail = tasks[0], tasks[1:]
    if any(t.spec is None for t in tasks) or not tail:
        return False
    if mm.spec.kind != "matmul" or len(mm.spec.ins) != 2:
        return False
    a_buf, w_buf = mm.spec.ins
    mn = graph.buffers[mm.spec.outs[0]].shape
    shapes = (graph.buffers[a_buf].shape, graph.buffers[w_buf].shape, mn)
    if any(len(s) != 2 for s in shapes):
        return False
    prev, has_grad = mm.spec.outs[0], False
    for t in tail:
        kind = t.spec.kind
        if kind in GRAD_EW_KINDS:
            has_grad = True
        elif kind not in EW_KINDS:
            return False
        if not t.spec.ins or t.spec.ins[0] != prev:
            return False
        for b in t.spec.ins[1:]:        # residual operands ride the stream
            if graph.buffers[b].shape != mn or not _f32(graph, b):
                return False
        if kind == "softmax_grad" and int(
                t.spec.attrs.get("axis", -1)) not in (-1, 1):
            return False                # row blocks span full rows only
        prev = t.spec.outs[0]
    if not has_grad:
        return False
    return _f32(graph, a_buf, w_buf, tail[-1].spec.outs[0])


def _grad_ew_applier(tail_tasks):
    """Replay the gradient epilogue's registered impls on a VMEM block.
    Returns ``(ew, extra_bufs)``: ``ew(h, *extras)`` threads the chain
    value through each stage's first operand with the residual operands
    bound positionally from ``extra_bufs`` order."""
    impls = [(op_impl(t.spec.kind), t.spec) for t in tail_tasks]
    extra_bufs = [b for t in tail_tasks for b in t.spec.ins[1:]]

    def ew(h, *extras):
        env = dict(zip(extra_bufs, extras))
        for impl, spec in impls:
            env[spec.ins[0]] = h
            h = impl(spec, env)[spec.outs[0]]
        return h

    return ew, extra_bufs


def _mm_grad_tiles(graph, tasks):
    if _mode() == "reference":
        return [None]
    m = graph.buffers[tasks[0].spec.ins[0]].shape[0]
    return [None] + [{"block_m": b} for b in (64, 256)
                     if b <= max(m, 64)]


def _mm_grad_factory(graph, group, tasks, tile=None):
    import jax
    from .chain import fused_matmul_grad

    mm, tail = tasks[0], tasks[1:]
    a_buf, w_buf = mm.spec.ins
    out_buf = tail[-1].spec.outs[0]
    ew, extra_bufs = _grad_ew_applier(tail)

    mode = _mode()
    if mode == "pallas" and not _vmem_ok(graph.buffers[w_buf].shape):
        return None                     # resident operand exceeds VMEM
    if mode == "reference":
        fn = jax.jit(lambda a, w, *ex: matmul_grad_ref(a, w, ex, ew))
    else:
        block_m = int((tile or {}).get("block_m", 128))
        fn = jax.jit(functools.partial(fused_matmul_grad, ew=ew,
                                       block_m=block_m,
                                       interpret=(mode == "interpret")))

    def run(env):
        return {out_buf: fn(env[a_buf], env[w_buf],
                            *(env[b] for b in extra_bufs))}

    return run


# --------------------------------------------------------------------------
# softmax -> matmul (attention tail)
# --------------------------------------------------------------------------


def _softmax_mm_feasible(graph, tasks) -> bool:
    sm, mm = tasks
    if sm.spec is None or mm.spec is None:
        return False
    if sm.spec.kind != "softmax" or mm.spec.kind != "matmul":
        return False
    s_shape = graph.buffers[sm.spec.ins[0]].shape
    if len(s_shape) != 2 or int(sm.spec.attrs.get("axis", -1)) not in (
            -1, len(s_shape) - 1):
        return False
    if mm.spec.ins[0] != sm.spec.outs[0]:   # probabilities stream in as LHS
        return False
    v_buf = mm.spec.ins[1]
    if len(graph.buffers[v_buf].shape) != 2:
        return False
    return _f32(graph, sm.spec.ins[0], v_buf, mm.spec.outs[0])


def _softmax_mm_tiles(graph, tasks):
    """Autotune candidates: (row, contraction) block pairs for the online
    softmax·V stream (``None`` = the kernel's 128/128 default)."""
    if _mode() == "reference":
        return [None]
    s, k = graph.buffers[tasks[0].spec.ins[0]].shape
    out = [None]
    for bm, bk in ((64, 128), (128, 256)):
        if bm <= max(s, 64) and bk <= max(k, 128):
            out.append({"block_m": bm, "block_k": bk})
    return out


def _softmax_mm_factory(graph, group, tasks, tile=None):
    import jax
    from .chain import fused_softmax_matmul

    sm, mm = tasks
    s_buf, v_buf, out_buf = sm.spec.ins[0], mm.spec.ins[1], mm.spec.outs[0]

    mode = _mode()
    if mode == "pallas" and not _vmem_ok(graph.buffers[v_buf].shape):
        return None
    if mode == "reference":
        fn = jax.jit(softmax_matmul_ref)
    else:
        tile = tile or {}
        fn = jax.jit(functools.partial(
            fused_softmax_matmul,
            block_m=int(tile.get("block_m", 128)),
            block_k=int(tile.get("block_k", 128)),
            interpret=(mode == "interpret")))

    def run(env):
        return {out_buf: fn(env[s_buf], env[v_buf])}

    return run


# --------------------------------------------------------------------------
# Registration
# --------------------------------------------------------------------------

_REGISTERED = False


def register() -> None:
    """Register the streamfuse kernel patterns with the routing layer
    (idempotent — re-imports and repeated ``register_all()`` calls do not
    churn the registry epoch)."""
    global _REGISTERED
    if _REGISTERED:
        return
    _REGISTERED = True
    register_kernel_pattern(KernelPattern(
        name="streamfuse.conv", pattern=("pad", "conv", "ewise"),
        factory=_conv_factory, feasible=_conv_feasible,
        description="fused pad->conv3x3->relu streaming kernel (Fig. 2)"))
    register_kernel_pattern(KernelPattern(
        name="streamfuse.mmchain", pattern=("matmul", "*ewise", "matmul"),
        factory=_mm_chain_factory, feasible=_mm_chain_feasible,
        tiles=_mm_chain_tiles,
        description="ew(a@w1)@w2 with the activation row-block in VMEM"))
    register_kernel_pattern(KernelPattern(
        name="streamfuse.softmaxmm", pattern=("softmax", "matmul"),
        factory=_softmax_mm_factory, feasible=_softmax_mm_feasible,
        tiles=_softmax_mm_tiles,
        description="online-softmax(s)@v streaming attention tail"))
    register_kernel_pattern(KernelPattern(
        name="streamfuse.mmgrad", pattern=("matmul", "*ewise"),
        factory=_mm_grad_factory, feasible=_mm_grad_feasible,
        tiles=_mm_grad_tiles,
        description="cotangent matmul with fused gradient epilogue "
                    "(backward chains)"))
