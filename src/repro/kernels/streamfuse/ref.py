"""Pure-jnp oracles for the streamfuse fused kernels (pad+conv+relu,
matmul chains, softmax·matmul tails)."""

import jax
import jax.numpy as jnp


def pad_conv_relu_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    k = w.shape[-1]
    p = k // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (p, p), (p, p)))
    y = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32), (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return jnp.maximum(y, 0).astype(x.dtype)


def matmul_chain_ref(a: jax.Array, w1: jax.Array, w2: jax.Array,
                     ew=()) -> jax.Array:
    """``ew(a @ w1) @ w2`` — ``ew`` a callable or sequence applied in order."""
    h = a @ w1
    for f in ([ew] if callable(ew) else list(ew)):
        h = f(h)
    return h @ w2


def matmul_grad_ref(a: jax.Array, w: jax.Array, extras=(),
                    ew=None) -> jax.Array:
    """``ew(a @ w, *extras)`` — the backward matmul + gradient-epilogue
    chain; ``ew`` takes the product block plus the residual operands."""
    h = a.astype(jnp.float32) @ w.astype(jnp.float32)
    if ew is not None:
        h = ew(h, *extras)
    return h.astype(a.dtype)


def softmax_matmul_ref(s: jax.Array, v: jax.Array) -> jax.Array:
    return jax.nn.softmax(s, axis=-1) @ v
