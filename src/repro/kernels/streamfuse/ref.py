"""Pure-jnp oracle for the fused pad+conv+relu streaming kernel."""

import jax
import jax.numpy as jnp


def pad_conv_relu_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    k = w.shape[-1]
    p = k // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (p, p), (p, p)))
    y = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32), (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return jnp.maximum(y, 0).astype(x.dtype)
