"""Streaming Pallas kernels for the dense fusion-group chains the router
maps (matmul→\\*ewise→matmul chains and softmax·matmul attention tails).

Both kernels follow the same CODO playbook as the conv chain in
``streamfuse.py``: the FIFO between the fused tasks is a VMEM value that
never round-trips through HBM, and reductions are rewritten to emit each
output element exactly once (Fig. 5).

``fused_matmul_chain`` — ``ew(a @ w1) @ w2`` with the intermediate
activation row-block resident in VMEM.  Grid: ``(M/bm,)`` — one
activation row-block per step; both weight operands stay VMEM-resident,
so the kernel targets block/projection-sized chains (the factory declines
shapes whose weights exceed the VMEM budget on real TPUs; interpret mode
has no such limit).

``fused_softmax_matmul`` — ``softmax(s, -1) @ v`` via the online-softmax
recurrence: the KV axis streams block by block through the sequential
last grid axis while the ``(m, l, acc)`` triple lives in VMEM scratch —
flash-attention's tail without the q·kᵀ head, exactly the shape of the
attention fusion groups ``gpt2_block`` produces after the softmax's
producer is a separate group task.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _block(dim: int, pref: int = 128) -> int:
    """Largest clean block: the MXU-aligned preferred size when it tiles
    ``dim`` exactly, otherwise the whole dim (single block)."""
    return pref if dim % pref == 0 else dim


# --------------------------------------------------------------------------
# matmul -> *ewise -> matmul
# --------------------------------------------------------------------------


def _chain_kernel(a_ref, w1_ref, w2_ref, o_ref, *, ew: Callable):
    h = jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), w1_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h = ew(h)                                    # fused elementwise tail(s)
    o_ref[...] = jax.lax.dot_general(
        h, w2_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def fused_matmul_chain(a: jax.Array, w1: jax.Array, w2: jax.Array, *,
                       ew: Callable | Sequence[Callable] = (),
                       block_m: int = 128,
                       interpret: bool = True) -> jax.Array:
    """``ew(a @ w1) @ w2`` as one Pallas kernel; ``ew`` is a single
    f32-block→f32-block callable or a sequence applied in order (empty =
    bare matmul chain)."""
    M, K = a.shape
    K2, N1 = w1.shape
    N12, N2 = w2.shape
    assert K == K2 and N1 == N12, (a.shape, w1.shape, w2.shape)
    fns = [ew] if callable(ew) else list(ew)

    def apply_ew(h):
        for f in fns:
            h = f(h)
        return h

    bm = min(_block(M, block_m), M)
    kernel = functools.partial(_chain_kernel, ew=apply_ew)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N1), lambda i: (0, 0)),
            pl.BlockSpec((N1, N2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N2), a.dtype),
        interpret=interpret,
    )(a, w1, w2)


# --------------------------------------------------------------------------
# matmul -> *ewise gradient epilogue (backward-pass chains)
# --------------------------------------------------------------------------


def _grad_chain_kernel(*refs, ew: Callable):
    a_ref, w_ref, o_ref = refs[0], refs[1], refs[-1]
    extras = [r[...].astype(jnp.float32) for r in refs[2:-1]]
    h = jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] = ew(h, *extras).astype(o_ref.dtype)


def fused_matmul_grad(a: jax.Array, w: jax.Array, *extras: jax.Array,
                      ew: Callable, block_m: int = 128,
                      interpret: bool = True) -> jax.Array:
    """``ew(a @ w, *extras)`` as one Pallas kernel — the backward-pass
    sibling of :func:`fused_matmul_chain`: a cotangent matmul whose
    gradient epilogue (``relu_grad``/``gelu_grad``/``softmax_grad`` plus
    plain elementwise) fuses onto the VMEM row-block instead of
    round-tripping through HBM.  ``extras`` are the epilogue's residual
    operands, each ``(M, N)`` and streamed with the same row-blocking as
    the output; ``softmax_grad``'s row reduction is exact because blocks
    span full rows."""
    M, K = a.shape
    K2, N = w.shape
    assert K == K2, (a.shape, w.shape)
    assert all(e.shape == (M, N) for e in extras), (
        [e.shape for e in extras], (M, N))
    bm = min(_block(M, block_m), M)
    kernel = functools.partial(_grad_chain_kernel, ew=ew)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
        ] + [pl.BlockSpec((bm, N), lambda i: (i, 0)) for _ in extras],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )(a, w, *extras)


# --------------------------------------------------------------------------
# softmax -> matmul (online-softmax streaming tail)
# --------------------------------------------------------------------------


def _softmax_mm_kernel(s_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                       nk: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = s_ref[...].astype(jnp.float32)           # (bm, bk)
    v = v_ref[...].astype(jnp.float32)           # (bk, N)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                      ).astype(o_ref.dtype)


def fused_softmax_matmul(s: jax.Array, v: jax.Array, *,
                         block_m: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    """``softmax(s, axis=-1) @ v`` as one streaming Pallas kernel.
    ``s``: (M, K); ``v``: (K, N).  The K axis iterates on the sequential
    last grid dimension, so the softmax normalizer is the online
    recurrence and the probability matrix never materializes."""
    M, K = s.shape
    K2, N = v.shape
    assert K == K2, (s.shape, v.shape)
    bm = min(_block(M, block_m), M)
    bk = min(_block(K, block_k), K)
    grid = (M // bm, K // bk)
    kernel = functools.partial(_softmax_mm_kernel, nk=grid[1])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, N), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), s.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((bm, N), jnp.float32),
        ],
        interpret=interpret,
    )(s, v)
