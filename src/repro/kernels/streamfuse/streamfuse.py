"""Fused pad→conv3×3→relu streaming kernel — the paper's motivating
example (Fig. 2) as one Pallas kernel.

The three tasks communicate through VMEM instead of HBM: the "FIFO"
between Padding and Conv2D is a set of K row-shifted BlockSpec views of
the padded input — each grid step streams rows [h, h+K) into VMEM, which
is exactly the (K-1)-row **line buffer** of Fig. 7 realized by the grid
pipeline (block dim 1 on the row axis makes the block index an element
index, so consecutive steps re-fetch K-1 rows the pipeline already holds).
The Conv→ReLU FIFO is a register value; the kh·kw·ci **window buffer** is
the VMEM working set of the dot below.

Grid: (N, H) — one output row per step; weights stay VMEM-resident.  The
grid pipeline double-buffers the next row while the MXU works on the
current one: Fig. 1's ping-pong and FIFO in one mechanism.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(*refs, W: int, K: int, C: int, CO: int):
    x_rows = refs[:K]           # K refs, each (1, C, 1, Wp)
    w_ref = refs[K]
    o_ref = refs[K + 1]
    x = jnp.concatenate([r[0, :, 0:1, :] for r in x_rows], axis=1)
    x = x.astype(jnp.float32)                        # (C, K, Wp)
    w = w_ref[...].astype(jnp.float32)               # (CO, C, K, K)
    # window buffer: K shifted column views -> (C, K, K, W)
    win = jnp.stack([x[:, :, kw:kw + W] for kw in range(K)], axis=2)
    acc = jax.lax.dot_general(
        w.reshape(CO, C * K * K), win.reshape(C * K * K, W),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = jnp.maximum(acc, 0.0).astype(o_ref.dtype)  # fused ReLU


def fused_pad_conv_relu(x: jax.Array, w: jax.Array, *,
                        interpret: bool = True) -> jax.Array:
    """x: (N, C, H, W); w: (CO, C, K, K), stride 1, SAME padding.
    Returns relu(conv2d(pad(x), w)): (N, CO, H, W)."""
    N, C, H, W = x.shape
    CO, C2, K, K2 = w.shape
    assert C == C2 and K == K2 and K % 2 == 1
    p = K // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (p, p), (p, p)))
    Wp = W + 2 * p

    def row_spec(dk: int) -> pl.BlockSpec:
        return pl.BlockSpec((1, C, 1, Wp),
                            lambda n, h, _dk=dk: (n, 0, h + _dk, 0))

    kernel = functools.partial(_fused_kernel, W=W, K=K, C=C, CO=CO)
    return pl.pallas_call(
        kernel,
        grid=(N, H),
        in_specs=[row_spec(dk) for dk in range(K)] + [
            pl.BlockSpec((CO, C, K, K), lambda n, h: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, CO, 1, W), lambda n, h: (n, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((N, CO, H, W), x.dtype),
        interpret=interpret,
    )(*([xp] * K), w)
