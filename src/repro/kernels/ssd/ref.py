"""Pure-jnp oracle for the SSD chunk-state scan kernel."""

import jax
import jax.numpy as jnp


def ssd_chunk_scan_ref(states: jax.Array, decay: jax.Array) -> jax.Array:
    """states: (nc, BH, P, N); decay: (nc, BH, 1, 1) -> carried-in states."""

    def step(h, inp):
        st, dec = inp
        return h * dec.astype(jnp.float32) + st.astype(jnp.float32), h

    h0 = jnp.zeros(states.shape[1:], jnp.float32)
    _, prevs = jax.lax.scan(step, h0, (states, decay))
    return prevs.astype(states.dtype)
