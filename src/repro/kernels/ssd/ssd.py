"""Mamba-2 SSD chunk-state Pallas kernel.

The inter-chunk recurrence h_{c+1} = decay_c · h_c + state_c over per-chunk
states (B, H, P, N) — the sequential backbone of the SSD algorithm — is
another pure linear stream: FIFO-native.  The intra-chunk dense blocks are
MXU matmuls best left to XLA; this kernel owns the sequential part that
XLA would otherwise express as a scan with HBM round-trips per step.

Per grid step c the kernel consumes (state_c, decay_c), updates the VMEM-
resident running state, and emits the *carried-in* state h_c (what the
intra-chunk off-diagonal term consumes) — emitted exactly once, before the
update, i.e. as early as possible (Fig. 5 discipline).

Grid: (n_chunks,); state shaped (B·H, P, N) for (sublane, lane) tiling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(states_ref, decay_ref, prev_ref, h_scr):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    # emit the carried-in state for this chunk (used by the off-diagonal
    # output term), then fold in this chunk's contribution.
    prev_ref[0] = h_scr[...].astype(prev_ref.dtype)
    dec = decay_ref[0].astype(jnp.float32)         # (BH, 1, 1) broadcastable
    st = states_ref[0].astype(jnp.float32)         # (BH, P, N)
    h_scr[...] = h_scr[...] * dec + st


def ssd_chunk_scan(states: jax.Array, decay: jax.Array, *,
                   interpret: bool = True) -> jax.Array:
    """states: (nc, BH, P, N); decay: (nc, BH, 1, 1).
    Returns h_prev: (nc, BH, P, N) — the state carried *into* each chunk."""
    nc, BH, P, N = states.shape
    return pl.pallas_call(
        _ssd_kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, BH, P, N), lambda c: (c, 0, 0, 0)),
            pl.BlockSpec((1, BH, 1, 1), lambda c: (c, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BH, P, N), lambda c: (c, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, BH, P, N), states.dtype),
        scratch_shapes=[pltpu.VMEM((BH, P, N), jnp.float32)],
        interpret=interpret,
    )(states, decay)
