from .ops import ssd_states
from .patterns import register
from .ref import ssd_chunk_scan_ref
from .ssd import ssd_chunk_scan

__all__ = ["register", "ssd_chunk_scan", "ssd_chunk_scan_ref", "ssd_states"]
