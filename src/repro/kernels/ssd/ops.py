"""Jit'd public wrapper for the SSD chunk-state kernel."""

from __future__ import annotations

from functools import partial

import jax

from .ref import ssd_chunk_scan_ref
from .ssd import ssd_chunk_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def ssd_states(states, decay, *, use_kernel: bool = True):
    if not use_kernel:
        return ssd_chunk_scan_ref(states, decay)
    return ssd_chunk_scan(states, decay, interpret=not _on_tpu())
