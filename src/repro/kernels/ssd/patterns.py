"""CODO kernel-pattern registration: the SSD inter-chunk state scan.

``ssd.scan`` claims the single ``scan`` task a traced
``F.ssd_scan(states, decay)`` emits (carried-in chunk states over
``(nc, BH, P, N)`` end-states and ``(nc, BH, 1, 1)`` decays) and
replaces its sequential generic lowering with the chunk-scan Pallas
kernel — a one-task chain, hence ``allow_single=True``.
"""

from __future__ import annotations

import functools

from ...core.routing import KernelPattern, register_kernel_pattern
from ..common import all_f32, kernel_mode, vmem_ok


def _feasible(graph, tasks) -> bool:
    (t,) = tasks
    if t.spec is None or t.spec.kind != "ssd_scan":
        return False
    st_buf, dec_buf = t.spec.ins
    out_buf = t.spec.outs[0]
    st_shape = graph.buffers[st_buf].shape
    dec_shape = graph.buffers[dec_buf].shape
    if len(st_shape) != 4 or len(dec_shape) != 4:
        return False
    if dec_shape[:2] != st_shape[:2] or dec_shape[2:] != (1, 1):
        return False
    return all_f32(graph, st_buf, dec_buf, out_buf)


def factory(graph, group, tasks, tile=None):
    import jax

    (t,) = tasks
    st_buf, dec_buf = t.spec.ins
    out_buf = t.spec.outs[0]

    mode = kernel_mode()
    if mode == "pallas" and not vmem_ok(graph.buffers[st_buf].shape):
        return None

    if mode == "reference":
        from .ref import ssd_chunk_scan_ref
        fn = jax.jit(ssd_chunk_scan_ref)
    else:
        from .ssd import ssd_chunk_scan
        fn = jax.jit(functools.partial(ssd_chunk_scan,
                                       interpret=(mode == "interpret")))

    def run(env):
        return {out_buf: fn(env[st_buf], env[dec_buf])}

    return run


_REGISTERED = False


def register() -> None:
    """Register the ssd kernel pattern (idempotent)."""
    global _REGISTERED
    if _REGISTERED:
        return
    _REGISTERED = True
    register_kernel_pattern(KernelPattern(
        name="ssd.scan", pattern=("scan",),
        factory=factory, feasible=_feasible,
        allow_single=True,
        description="Mamba-2 SSD inter-chunk state scan "
                    "(replaces the sequential generic scan)"))
