"""Jit'd public wrapper for the blocked matmul kernel."""

from __future__ import annotations

from functools import partial

import jax

from .matmul import matmul
from .ref import matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "use_kernel"))
def mm(a, b, *, block_m: int = 128, block_n: int = 128, block_k: int = 128,
       use_kernel: bool = True):
    if not use_kernel:
        return matmul_ref(a, b)
    return matmul(a, b, block_m=block_m, block_n=block_n, block_k=block_k,
                  interpret=not _on_tpu())
