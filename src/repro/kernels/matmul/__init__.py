from .matmul import matmul
from .ops import mm
from .ref import matmul_ref

__all__ = ["matmul", "matmul_ref", "mm"]
