"""Blocked matmul Pallas kernel — the paper's reduction rewriting (Fig. 5)
in its purest form.

The naive loop writes ``out[m,n]`` once per k iteration (the access-count
mismatch of §IV-B).  The rewritten kernel accumulates the (bm, bn) tile in
a VMEM f32 scratch across the sequential k grid axis and emits it exactly
once when the last k block retires — early, just-in-time, FIFO-clean.

Grid (M/bm, N/bn, K/bk); blocks MXU-aligned (multiples of 128 on the
lane dims; bm on the sublane dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = True) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape)
    grid = (M // bm, N // bn, K // bk)
    kernel = functools.partial(_mm_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
