"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)
