"""CODO kernel-pattern registration: the full attention chain.

``flashattn.mha`` claims ``matmul -> *ewise -> softmax -> matmul`` — the
whole ``softmax(c * q @ kᵀ) @ v`` chain a traced attention block emits
(2-D single-head or 3-D heads-folded-batched operands).  It anchors at
the *first* attention matmul, which precedes the softmax in topo order,
so a feasible match supersedes the narrower ``streamfuse.softmaxmm``
tail: the online-softmax stream starts at the score matmul and the S×S
score matrix never materializes in HBM.

The chain carries no mask, so the kernel runs with ``causal=False,
window=0``; interior ``scale`` tasks fold into the kernel's internal
1/√hd by pre-scaling q with ``c·√hd``.
"""

from __future__ import annotations

import functools
import math

from ...core.routing import KernelPattern, register_kernel_pattern
from ..common import all_f32, kernel_mode, pow2_block, vmem_ok


def _chain_parts(tasks):
    """(mm1, interior ewise list, softmax, mm2) or None if kinds drift."""
    if any(t.spec is None for t in tasks) or len(tasks) < 3:
        return None
    mm1, sm, mm2 = tasks[0], tasks[-2], tasks[-1]
    ews = tasks[1:-2]
    if (mm1.spec.kind, sm.spec.kind, mm2.spec.kind) != (
            "matmul", "softmax", "matmul"):
        return None
    return mm1, ews, sm, mm2


def _feasible(graph, tasks) -> bool:
    parts = _chain_parts(tasks)
    if parts is None:
        return False
    mm1, ews, sm, mm2 = parts
    # Interiors must be pure rescales of the chain value (the 1/√hd).
    prev = mm1.spec.outs[0]
    for t in ews:
        if t.spec.kind != "scale" or t.spec.ins != (prev,):
            return False
        prev = t.spec.outs[0]
    if sm.spec.ins[0] != prev or mm2.spec.ins[0] != sm.spec.outs[0]:
        return False
    q_buf, kt_buf = mm1.spec.ins
    v_buf, out_buf = mm2.spec.ins[1], mm2.spec.outs[0]
    q_shape = graph.buffers[q_buf].shape
    kt_shape = graph.buffers[kt_buf].shape
    v_shape = graph.buffers[v_buf].shape
    if len(q_shape) not in (2, 3) or len(kt_shape) != len(q_shape) \
            or len(v_shape) != len(q_shape):
        return False
    hd = q_shape[-1]
    if kt_shape[-2] != hd or v_shape[-1] != hd:       # kt is (.., hd, Sk)
        return False
    if v_shape[-2] != kt_shape[-1]:                   # Sk agreement
        return False
    if len(q_shape) == 3 and not (q_shape[0] == kt_shape[0] == v_shape[0]):
        return False
    axis = int(sm.spec.attrs.get("axis", -1))
    if axis not in (-1, len(q_shape) - 1):
        return False
    return all_f32(graph, q_buf, kt_buf, v_buf, out_buf)


def _scale_of(ews) -> float:
    c = 1.0
    for t in ews:
        c *= float(t.spec.attrs.get("s", 1.0))
    return c


def tiles(graph, tasks):
    """(block_q, block_k) candidates; ``None`` = divisor-derived default."""
    if kernel_mode() == "reference":
        return [None]
    q_shape = graph.buffers[tasks[0].spec.ins[0]].shape
    sk = graph.buffers[tasks[0].spec.ins[1]].shape[-1]
    sq = q_shape[-2]
    out = [None]
    for bq, bk in ((64, 64), (128, 128)):
        if sq % bq == 0 and sk % bk == 0:
            out.append({"block_q": bq, "block_k": bk})
    return out


def factory(graph, group, tasks, tile=None):
    import jax
    import jax.numpy as jnp

    parts = _chain_parts(tasks)
    mm1, ews, sm, mm2 = parts
    q_buf, kt_buf = mm1.spec.ins
    v_buf, out_buf = mm2.spec.ins[1], mm2.spec.outs[0]
    q_shape = graph.buffers[q_buf].shape
    sq, hd = q_shape[-2], q_shape[-1]
    sk = graph.buffers[kt_buf].shape[-1]
    c = _scale_of(ews)

    mode = kernel_mode()
    if mode == "pallas" and not vmem_ok(graph.buffers[kt_buf].shape,
                                        graph.buffers[v_buf].shape):
        return None                     # resident K/V exceed VMEM

    if mode == "reference":
        # Exactly the chain's computation, fused under one jit.
        def mha_ref(q, kt, v, _c=c):
            p = jax.nn.softmax(_c * jnp.matmul(q, kt), axis=-1)
            return jnp.matmul(p, v)
        fn = jax.jit(mha_ref)
    else:
        from .flashattn import flash_attention
        tile = tile or {}
        bq = int(tile.get("block_q", pow2_block(sq)))
        bk = int(tile.get("block_k", pow2_block(sk)))
        kernel = functools.partial(flash_attention, causal=False, window=0,
                                   block_q=bq, block_k=bk,
                                   interpret=(mode == "interpret"))
        # The kernel divides scores by √hd internally; fold the chain's
        # scale c in by pre-scaling q with c·√hd.
        pre = c * math.sqrt(hd)

        def mha_kernel(q, kt, v, _pre=pre, _kernel=kernel):
            batched = q.ndim == 3
            if not batched:
                q, kt, v = q[None], kt[None], v[None]
            qq = (q * _pre)[:, None]                      # (BH, 1, Sq, hd)
            kk = jnp.swapaxes(kt, -1, -2)[:, None]        # (BH, 1, Sk, hd)
            vv = v[:, None]
            o = _kernel(qq, kk, vv)[:, 0]
            return o if batched else o[0]
        fn = jax.jit(mha_kernel)

    def run(env):
        return {out_buf: fn(env[q_buf], env[kt_buf], env[v_buf])}

    return run


_REGISTERED = False


def register() -> None:
    """Register the flashattn kernel pattern (idempotent)."""
    global _REGISTERED
    if _REGISTERED:
        return
    _REGISTERED = True
    register_kernel_pattern(KernelPattern(
        name="flashattn.mha",
        pattern=("matmul", "*ewise", "softmax", "matmul"),
        factory=factory, feasible=_feasible, tiles=tiles,
        description="full softmax(c·q@kᵀ)@v chain via online-softmax "
                    "streaming (supersedes the softmaxmm tail)"))
