from .flashattn import flash_attention
from .ops import flash_attn
from .patterns import register
from .ref import attention_ref

__all__ = ["attention_ref", "flash_attention", "flash_attn", "register"]
