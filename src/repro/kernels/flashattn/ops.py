"""Jit'd public wrapper for the flash-attention kernel.

On TPU the Pallas kernel runs compiled; everywhere else it runs in
interpret mode (the kernel body executes in Python on CPU) so the same
code path is validated by the test sweep.
"""

from __future__ import annotations

from functools import partial

import jax

from .flashattn import flash_attention
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "use_kernel"))
def flash_attn(q, k, v, *, causal: bool = True, window: int = 0,
               block_q: int = 128, block_k: int = 128,
               use_kernel: bool = True):
    """Dispatch: Pallas kernel (compiled on TPU / interpreted elsewhere)."""
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=not _on_tpu())
