"""Streaming flash-attention Pallas kernel (TPU target).

CODO tie-in: the online-softmax recurrence is the paper's
reduction-operation rewriting (Fig. 5) on the softmax×V chain — KV is the
reduction dimension, the (m, l, acc) triple lives in VMEM scratch (the
"temporary array"), and the output tile is emitted exactly once, as early
as possible (when the last KV block for this Q tile retires).  The KV
stream through VMEM is the FIFO; the Pallas grid pipeline's double
buffering of the next KV block is the ping-pong stage of Fig. 1 — both
patterns in one kernel.

Grid: (B·Hq, Sq/bq, Sk/bk) — the last axis iterates sequentially on TPU,
so scratch persists across KV blocks.  GQA is expressed in the k/v
index_map (q-head b maps to kv-head b // group).  Causal + sliding-window
masks are built from block coordinates.

MXU alignment: bq/bk multiples of 128 (lane), hd is the contraction dim.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Sk, hd).  Returns (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)

    qf = q.reshape(B * Hq, Sq, hd)
    kf = k.reshape(B * Hkv, Sk, hd)
    vf = v.reshape(B * Hkv, Sk, hd)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki, _G=G: (b // _G, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki, _G=G: (b // _G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, hd)
