"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each subpackage ships the kernel (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jit'd ``ops`` wrapper, and a ``ref`` pure-jnp oracle the tests
sweep against in interpret mode:

* ``flashattn``  — streaming online-softmax attention (GQA/causal/SWA)
* ``matmul``     — blocked matmul with k-accumulation (Fig. 5 rewriting)
* ``streamfuse`` — fused pad→conv→relu (the Fig. 2 motivating chain)
* ``rglru``      — RG-LRU linear recurrence (FIFO-native stream)
* ``ssd``        — Mamba-2 SSD inter-chunk state scan
"""

from . import flashattn, matmul, rglru, ssd, streamfuse


def register_all() -> None:
    """Hook hand-written kernels into the CODO lowering registry.

    Order matters only for patterns sharing an anchor op: streamfuse
    first (the PR-6 families), then the attention/recurrence families
    (ROADMAP item 4).  ``flashattn.mha`` anchors at the score *matmul*,
    which precedes the softmax in topo order, so it claims the full
    chain before ``streamfuse.softmaxmm`` can anchor at the tail."""
    streamfuse.register()
    flashattn.register()
    rglru.register()
    ssd.register()
