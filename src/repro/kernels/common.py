"""Shared backend-dispatch helpers for kernel-pattern registration.

Every kernel family resolves the same three questions when its routing
factory builds an executable step — which backend mode to run in, whether
the resident operands fit VMEM, and whether the chain is all-f32.  The
streamfuse answers (repro/kernels/streamfuse/ops.py) are the reference
semantics; this module is their shared home so the flashattn/rglru/ssd
pattern modules don't each re-derive them.

Modes:

* ``"pallas"``    — compiled Pallas kernel (TPU hosts);
* ``"interpret"`` — the Pallas kernel body in interpret mode, forced by
  ``CODO_PALLAS_INTERPRET=1`` (how CI exercises the true kernel path on
  CPU runners);
* ``"reference"`` — the kernel's fused jnp reference under one jit
  (CPU/GPU hosts): the same fusion decision, carried by XLA.
"""

from __future__ import annotations

import numpy as np

from ..core.routing import pallas_interpret_forced

# Resident-operand budget for compiled (TPU) kernels; interpret/reference
# modes are unconstrained.
VMEM_BUDGET_BYTES = 12 * 2 ** 20


def kernel_mode() -> str:
    """'pallas' (compiled, TPU), 'interpret' (forced), or 'reference'."""
    if pallas_interpret_forced():
        return "interpret"
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def vmem_ok(*shapes) -> bool:
    return sum(int(np.prod(s)) for s in shapes) * 4 <= VMEM_BUDGET_BYTES


def all_f32(graph, *bufs) -> bool:
    return all(np.dtype(graph.buffers[b].dtype) == np.float32 for b in bufs)


def pow2_block(n: int, cap: int = 128) -> int:
    """Largest power-of-two divisor of ``n``, capped at ``cap`` — the
    block size the Pallas kernels' divisibility asserts always accept."""
    b = 1
    while b * 2 <= cap and n % (b * 2) == 0:
        b *= 2
    return b
