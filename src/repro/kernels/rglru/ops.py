"""Jit'd public wrapper for the RG-LRU recurrence kernel."""

from __future__ import annotations

from functools import partial

import jax

from .ref import rglru_ref
from .rglru import rglru_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def rglru(a, b, *, chunk: int = 128, use_kernel: bool = True):
    if not use_kernel:
        return rglru_ref(a, b)
    return rglru_scan(a, b, chunk=chunk, interpret=not _on_tpu())
