"""CODO kernel-pattern registration: the RG-LRU linear recurrence.

``rglru.scan`` claims the single ``scan`` task a traced
``F.rglru_scan(a, b)`` emits (``h_t = a_t·h_{t-1} + b_t`` over axis 1 of
``(B, S, D)`` operands) and replaces its sequential generic lowering
with the chunked-scan Pallas kernel — a one-task chain, hence
``allow_single=True``.
"""

from __future__ import annotations

import functools

from ...core.routing import KernelPattern, register_kernel_pattern
from ..common import all_f32, kernel_mode, pow2_block, vmem_ok


def _feasible(graph, tasks) -> bool:
    (t,) = tasks
    if t.spec is None or t.spec.kind != "rglru_scan":
        return False
    a_buf, b_buf = t.spec.ins
    out_buf = t.spec.outs[0]
    a_shape = graph.buffers[a_buf].shape
    if len(a_shape) != 3 or graph.buffers[b_buf].shape != a_shape:
        return False
    return all_f32(graph, a_buf, b_buf, out_buf)


def tiles(graph, tasks):
    """Chunk-length candidates; ``None`` = divisor-derived default."""
    if kernel_mode() == "reference":
        return [None]
    s = graph.buffers[tasks[0].spec.ins[0]].shape[1]
    return [None] + [{"chunk": ch} for ch in (64, 128)
                     if ch < s and s % ch == 0]


def factory(graph, group, tasks, tile=None):
    import jax

    (t,) = tasks
    a_buf, b_buf = t.spec.ins
    out_buf = t.spec.outs[0]
    s = graph.buffers[a_buf].shape[1]

    mode = kernel_mode()
    if mode == "pallas" and not vmem_ok(graph.buffers[a_buf].shape,
                                        graph.buffers[b_buf].shape):
        return None

    if mode == "reference":
        from .ref import rglru_ref
        fn = jax.jit(rglru_ref)
    else:
        from .rglru import rglru_scan
        chunk = int((tile or {}).get("chunk", pow2_block(s)))
        fn = jax.jit(functools.partial(rglru_scan, chunk=chunk,
                                       interpret=(mode == "interpret")))

    def run(env):
        return {out_buf: fn(env[a_buf], env[b_buf])}

    return run


_REGISTERED = False


def register() -> None:
    """Register the rglru kernel pattern (idempotent)."""
    global _REGISTERED
    if _REGISTERED:
        return
    _REGISTERED = True
    register_kernel_pattern(KernelPattern(
        name="rglru.scan", pattern=("scan",),
        factory=factory, feasible=_feasible, tiles=tiles,
        allow_single=True,
        description="chunked RG-LRU linear recurrence h=a·h+b "
                    "(replaces the sequential generic scan)"))
