from .ops import rglru
from .ref import rglru_ref
from .rglru import rglru_scan

__all__ = ["rglru", "rglru_ref", "rglru_scan"]
