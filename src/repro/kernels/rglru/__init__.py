from .ops import rglru
from .patterns import register
from .ref import rglru_ref
from .rglru import rglru_scan

__all__ = ["register", "rglru", "rglru_ref", "rglru_scan"]
