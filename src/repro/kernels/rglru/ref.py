"""Pure-jnp oracle for the RG-LRU recurrence kernel."""

import jax
import jax.numpy as jnp


def rglru_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (af, bf), axis=1)
    return h.astype(a.dtype)
