"""RG-LRU linear-recurrence Pallas kernel.

The recurrence h_t = a_t ⊙ h_{t-1} + b_t is FIFO-native (DESIGN.md §4):
each (a_t, b_t) element is consumed once, in order, and each h_t emitted
once.  The kernel streams time-chunks through VMEM; the carried state
h (B, D) lives in VMEM scratch and persists across the sequential grid —
the paper's temporary accumulator at sequence scale.

Within a chunk the scan runs as a fori_loop over time with the channel
dim vectorized on the VPU (on TPU: (8, 128)-tiled (B, D) updates).

Grid: (S / chunk,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk: int):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        a_t = a_ref[:, t, :].astype(jnp.float32)
        b_t = b_ref[:, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        o_ref[:, t, :] = h.astype(o_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def rglru_scan(a: jax.Array, b: jax.Array, *, chunk: int = 128,
               interpret: bool = True) -> jax.Array:
    """a, b: (B, S, D) -> h: (B, S, D) with h_t = a_t·h_{t-1} + b_t."""
    B, S, D = a.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(S // chunk,),
        in_specs=[
            pl.BlockSpec((B, chunk, D), lambda ci: (0, ci, 0)),
            pl.BlockSpec((B, chunk, D), lambda ci: (0, ci, 0)),
        ],
        out_specs=pl.BlockSpec((B, chunk, D), lambda ci: (0, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((B, D), jnp.float32)],
        interpret=interpret,
    )(a, b)
