"""The CODO serving runtime: bounded queue, dynamic batching, worker pool,
zero-downtime artifact hot-swap.

``launch/serve.py`` ran one design on one input; this module is the
millions-of-users story (ROADMAP item 2).  A :class:`ServingRuntime` owns

* a **bounded request queue** (``CODO_SERVE_MAX_QUEUE``; overflow raises
  :class:`QueueFullError` — backpressure, never unbounded memory),
* a **dynamic batcher**: requests for the same model arriving within a
  configurable window (``CODO_SERVE_BATCH_WINDOW_MS``) coalesce into ONE
  execution of a leading-batch-dim graph built by
  :func:`repro.core.frontend.batch_graph` and compiled through the shared
  content-addressed :class:`~repro.core.cache.CompileCache` — so N
  same-signature requests cost one compile (then pure cache hits) and one
  device dispatch.  Workloads whose graphs cannot batch (see
  :func:`~repro.core.frontend.batch_blockers`) fall back to per-request
  execution, correct first.
* an optional **process worker pool** (``CODO_SERVE_WORKERS``, spawn
  start method — serving workers execute jax, so fork is not safe here
  the way it is for the compile-only pool in ``core/compiler.py``).
  Workers share the disk compile cache and the ``TuningDB`` sidecar via
  environment passed at spawn; a crashed worker breaks the pool, the
  runtime **respawns** it and retries the affected requests (bounded by
  ``max_retries``, then a clean :class:`ServeError` on the future).
* **hot-swap**: :meth:`ServingRuntime.swap` loads a new artifact via
  ``codo.load``, warms it, then atomically flips the serving handle —
  requests already dispatched drain on the old design; queued and new
  requests resolve the new one.  Zero requests are lost.

Everything is event-based (``threading.Condition``); nothing in here or
in its tests synchronizes by sleeping.

.. code-block:: python

    rt = ServingRuntime(ServeConfig(batch_window_ms=5, max_batch=8))
    rt.add_model("m", "artifacts/model.json")     # codo.load + warm
    futs = [rt.submit("m", x=arr) for arr in batch]
    outs = [f.result(timeout=30) for f in futs]
    rt.swap("m", "artifacts/model_v2.json")       # zero-downtime
    rt.close()
"""

from __future__ import annotations

import os
import threading
import time
from collections import deque
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass, field
from typing import Any

import numpy as np

__all__ = ["QueueFullError", "ServeConfig", "ServeError", "ServeFuture",
           "ServeStats", "ServingRuntime"]


class ServeError(RuntimeError):
    """A request failed permanently (execution error, or a worker crashed
    more than ``max_retries`` times)."""


class QueueFullError(ServeError):
    """The bounded request queue is at ``max_queue`` — backpressure: the
    caller should retry later or shed load."""


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, "") or default)
    except ValueError:
        return default


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, "") or default)
    except ValueError:
        return default


@dataclass(frozen=True)
class ServeConfig:
    """Runtime knobs.  :meth:`from_env` reads the documented
    ``CODO_SERVE_*`` environment variables (README "Environment knobs")."""
    batch_window_ms: float = 2.0    # how long the head request waits
    max_batch: int = 8              # dispatch early at this group size
    max_queue: int = 256            # bounded queue -> QueueFullError
    workers: int = 0                # 0 = execute in-process
    max_retries: int = 2            # worker-crash retries per request

    @classmethod
    def from_env(cls, **overrides) -> "ServeConfig":
        base = dict(
            batch_window_ms=_env_float("CODO_SERVE_BATCH_WINDOW_MS", 2.0),
            max_queue=_env_int("CODO_SERVE_MAX_QUEUE", 256),
            workers=_env_int("CODO_SERVE_WORKERS", 0),
        )
        base.update(overrides)
        return cls(**base)


class ServeFuture:
    """Completion handle for one submitted request (event-based — no
    polling, no sleeps).  ``result`` re-raises the request's failure."""

    def __init__(self, rid: int):
        self.rid = rid
        self._event = threading.Event()
        self._value: Any = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        return self._event.wait(timeout)

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done "
                               f"after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def _set_result(self, value) -> None:
        self._value = value
        self._event.set()

    def _set_error(self, err: BaseException) -> None:
        self._error = err
        self._event.set()


@dataclass
class ServeStats:
    """Counters a load test (or the bench) reads after the fact.  Compile
    accounting lives on the runtime's ``cache.stats`` — a batched window
    is exactly one cache miss, then hits."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    batches: int = 0                # dispatch groups executed
    batched_requests: int = 0       # requests served through batch_graph
    fallback_requests: int = 0      # per-request executions
    retries: int = 0                # requeues after a worker crash
    respawns: int = 0               # worker-pool rebuilds
    swaps: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


@dataclass
class _Request:
    rid: int
    model: str
    env: dict
    future: ServeFuture
    retries: int = 0
    arrived: float = field(default_factory=time.monotonic)


class _ModelHandle:
    """One served design generation: the program, its coalescing
    signature, and the memoized per-batch-size batched programs."""

    def __init__(self, name: str, program, path: str | None,
                 generation: int):
        from repro.core.frontend import batch_blockers
        self.name = name
        self.program = program
        self.path = path                    # set when loadable by workers
        self.generation = generation
        self.signature = program.graph.structural_hash()
        self.blockers = batch_blockers(program.source)
        self.batched: dict[int, Any] = {}   # batch size -> CompiledProgram
        self.lock = threading.Lock()        # guards `batched`

    def warm(self) -> None:
        """Lower + execute once on deterministic inputs so the first real
        request never pays trace/compile latency (hot-swap warms the new
        design *before* the flip)."""
        from repro.models.dataflow_models import random_inputs
        env = self.program.make_env(**random_inputs(self.program.graph))
        self.program.lower(jit=True)(env)


class ServingRuntime:
    """See the module docstring.  Thread-safe; one dispatcher thread owns
    batching, execution runs inline (``workers=0``) or on the process
    pool."""

    def __init__(self, config: ServeConfig | None = None, *, cache=None):
        from repro.core.compiler import default_cache
        self.config = config or ServeConfig.from_env()
        self.cache = cache if cache is not None else default_cache()
        self.stats = ServeStats()
        self._models: dict[str, _ModelHandle] = {}
        self._generation = 0
        self._queue: deque[_Request] = deque()
        self._rid = 0
        self._inflight = 0
        self._paused = False
        self._stop = False
        self._cond = threading.Condition()
        self._pool: ProcessPoolExecutor | None = None
        self._pool_lock = threading.Lock()
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="codo-serve-dispatch",
            daemon=True)
        self._dispatcher.start()

    # ---- model registry --------------------------------------------------
    def add_model(self, name: str, source, *, warm: bool = True
                  ) -> _ModelHandle:
        """Register a model under ``name``: an artifact path (``codo.load``
        — required for process workers, which re-load it themselves), a
        parsed artifact dict, or a ready ``CompiledProgram``."""
        handle = self._make_handle(name, source, warm=warm)
        with self._cond:
            self._models[name] = handle
        return handle

    def swap(self, name: str, source, *, warm: bool = True) -> _ModelHandle:
        """Zero-downtime hot-swap: build and warm the replacement fully,
        then atomically flip the handle.  Requests already dispatched (or
        taken by a worker) finish on the old design; everything after the
        flip — including requests still queued — resolves the new one.
        Nothing is dropped."""
        if name not in self._models:
            raise KeyError(f"no model {name!r} to swap "
                           f"(serving: {sorted(self._models)})")
        handle = self._make_handle(name, source, warm=warm)
        with self._cond:
            self._models[name] = handle
            self.stats.swaps += 1
        return handle

    def _make_handle(self, name: str, source, *, warm: bool) -> _ModelHandle:
        from repro import api as codo
        path: str | None = None
        if isinstance(source, (str, os.PathLike)):
            path = os.fspath(source)
            program = codo.load(path)
        elif isinstance(source, dict):
            program = codo.load(source)
        else:
            program = source        # a ready CompiledProgram
        with self._cond:
            self._generation += 1
            gen = self._generation
        handle = _ModelHandle(name, program, path, gen)
        if warm and self.config.workers == 0:
            handle.warm()
        return handle

    # ---- request path ----------------------------------------------------
    def submit(self, model: str, **arrays) -> ServeFuture:
        """Enqueue one request (named input arrays, the ``CompiledProgram``
        keyword convention).  Returns immediately with a
        :class:`ServeFuture`; raises :class:`QueueFullError` at
        ``max_queue`` and ``KeyError`` for an unregistered model."""
        with self._cond:
            if self._stop:
                raise ServeError("runtime is closed")
            if model not in self._models:
                raise KeyError(f"no model {model!r} "
                               f"(serving: {sorted(self._models)})")
            if len(self._queue) >= self.config.max_queue:
                raise QueueFullError(
                    f"request queue is full ({self.config.max_queue}); "
                    "retry later (CODO_SERVE_MAX_QUEUE raises the bound)")
            self._rid += 1
            fut = ServeFuture(self._rid)
            self._queue.append(_Request(self._rid, model, dict(arrays), fut))
            self.stats.submitted += 1
            self._cond.notify_all()
        return fut

    # ---- test/ops hooks (event-based; tests never sleep) -----------------
    def pause(self) -> None:
        """Stop dispatching (requests keep queueing — the deterministic
        way to fill one batching window, or to drive the queue to
        ``max_queue`` in a backpressure test)."""
        with self._cond:
            self._paused = True
            self._cond.notify_all()

    def resume(self) -> None:
        with self._cond:
            self._paused = False
            self._cond.notify_all()

    def flush(self, timeout: float | None = None) -> bool:
        """Block until the queue is empty and nothing is in flight."""
        with self._cond:
            return self._cond.wait_for(
                lambda: not self._queue and self._inflight == 0, timeout)

    def close(self, timeout: float | None = 30.0) -> None:
        """Drain, stop the dispatcher, shut the pool down."""
        self.flush(timeout)
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        self._dispatcher.join(timeout)
        with self._pool_lock:
            if self._pool is not None:
                self._pool.shutdown(wait=False, cancel_futures=True)
                self._pool = None

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- dispatcher ------------------------------------------------------
    def _dispatch_loop(self) -> None:
        cfg = self.config
        window = cfg.batch_window_ms / 1e3
        while True:
            with self._cond:
                self._cond.wait_for(
                    lambda: self._stop
                    or (self._queue and not self._paused))
                if self._stop:
                    return
                head = self._queue[0]
                deadline = head.arrived + window

                def group_size() -> int:
                    return sum(1 for r in self._queue
                               if r.model == head.model)

                # Hold the window open for the head's group: dispatch as
                # soon as it reaches max_batch, or when the window ends.
                while not self._stop and not self._paused:
                    if group_size() >= cfg.max_batch:
                        break
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cond.wait(left)
                if self._stop:
                    return
                if self._paused:
                    continue
                batch: list[_Request] = []
                rest: deque[_Request] = deque()
                for r in self._queue:
                    if r.model == head.model and len(batch) < cfg.max_batch:
                        batch.append(r)
                    else:
                        rest.append(r)
                self._queue = rest
                handle = self._models.get(head.model)
                self._inflight += len(batch)
                self._cond.notify_all()
            if handle is None:      # model removed while queued
                self._finish(batch, error=ServeError(
                    f"model {head.model!r} is no longer served"))
                continue
            if self.config.workers > 0 and handle.path is not None:
                self._dispatch_pool(handle, batch)
            else:
                self._execute_inline(handle, batch)

    def _finish(self, batch: list[_Request], *, results=None,
                error: BaseException | None = None) -> None:
        with self._cond:
            self._inflight -= len(batch)
            self.stats.batches += 1
            if error is None:
                self.stats.completed += len(batch)
            else:
                self.stats.failed += len(batch)
            self._cond.notify_all()
        for i, r in enumerate(batch):
            if error is None:
                r.future._set_result(results[i])
            else:
                r.future._set_error(error)

    def _requeue(self, batch: list[_Request], err: BaseException) -> None:
        """After a worker crash: bounded retries, then a clean error."""
        retry, dead = [], []
        for r in batch:
            r.retries += 1
            (retry if r.retries <= self.config.max_retries else dead).append(r)
        with self._cond:
            self.stats.retries += len(retry)
            self._inflight -= len(batch)
            self.stats.failed += len(dead)
            for r in retry:
                self._queue.appendleft(r)
            self._cond.notify_all()
        for r in dead:
            r.future._set_error(ServeError(
                f"request {r.rid} failed after {r.retries} worker "
                f"crashes ({type(err).__name__}: {err})"))

    # ---- in-process execution -------------------------------------------
    def _execute_inline(self, handle: _ModelHandle,
                        batch: list[_Request]) -> None:
        try:
            results = _run_batch(handle, batch, self.cache, self.stats,
                                 self._cond)
        except Exception as e:          # noqa: BLE001 — becomes the response
            self._finish(batch, error=ServeError(
                f"execution failed for {handle.name!r}: "
                f"{type(e).__name__}: {e}"))
            return
        self._finish(batch, results=results)

    # ---- process-pool execution -----------------------------------------
    def _ensure_pool(self) -> ProcessPoolExecutor:
        with self._pool_lock:
            if self._pool is None:
                import multiprocessing as mp
                env = {
                    "CODO_CACHE_DIR": getattr(self.cache, "disk_dir", "")
                    and str(self.cache.disk_dir),
                    "CODO_TUNING_DB": os.environ.get("CODO_TUNING_DB", ""),
                    "CODO_SERVE_FAULT":
                        os.environ.get("CODO_SERVE_FAULT", ""),
                }
                # spawn, never fork: serving workers execute jax.
                self._pool = ProcessPoolExecutor(
                    max_workers=self.config.workers,
                    mp_context=mp.get_context("spawn"),
                    initializer=_serve_worker_init, initargs=(env,))
            return self._pool

    def _break_pool(self) -> None:
        with self._pool_lock:
            if self._pool is not None:
                self._pool.shutdown(wait=False, cancel_futures=True)
                self._pool = None
        with self._cond:
            self.stats.respawns += 1

    def _dispatch_pool(self, handle: _ModelHandle,
                       batch: list[_Request]) -> None:
        try:
            fut = self._ensure_pool().submit(
                _serve_worker_run, handle.path, handle.generation,
                [r.env for r in batch], not handle.blockers)
        except BrokenProcessPool as e:
            self._break_pool()
            self._requeue(batch, e)
            return
        fut.add_done_callback(
            lambda f, b=batch, h=handle: self._pool_done(h, b, f))

    def _pool_done(self, handle: _ModelHandle, batch: list[_Request],
                   fut) -> None:
        try:
            results, batched = fut.result()
        except BrokenProcessPool as e:
            self._break_pool()
            self._requeue(batch, e)
            return
        except Exception as e:          # noqa: BLE001 — becomes the response
            self._finish(batch, error=ServeError(
                f"worker execution failed for {handle.name!r}: "
                f"{type(e).__name__}: {e}"))
            return
        with self._cond:
            if batched:
                self.stats.batched_requests += len(batch)
            else:
                self.stats.fallback_requests += len(batch)
        self._finish(batch, results=results)


# --------------------------------------------------------------------------
# Batched execution core — shared by the in-process path and the workers.
# --------------------------------------------------------------------------


def _batched_program(handle: _ModelHandle, size: int, cache):
    """The leading-batch-dim program for ``size`` requests, compiled
    through the shared cache (one miss per (design, size) — every later
    window is a pure cache hit) and memoized on the handle."""
    from repro import api as codo
    from repro.core.frontend import batch_graph
    with handle.lock:
        prog = handle.batched.get(size)
        if prog is None:
            bg = batch_graph(handle.program.source, size)
            prog = codo.compile(bg, options=handle.program.compiled.options,
                                cache=cache)
            weights = {b.name for b in bg.weights()}
            bound = {k: v for k, v in handle.program._bindings.items()
                     if k in weights}
            if bound:
                prog.bind(**bound)
            handle.batched[size] = prog
    return prog


def _run_batch(handle: _ModelHandle, batch: list[_Request], cache,
               stats: ServeStats | None = None,
               cond: threading.Condition | None = None) -> list:
    """Execute one dispatch group: coalesced through ``batch_graph`` when
    the design allows it and every request binds exactly the inputs,
    otherwise per-request.  Returns one ``{output: np.ndarray}`` dict per
    request, identical either way (the bit-identity tests pin this)."""
    program = handle.program
    inputs = list(program.input_names)
    coalesce = (len(batch) > 1 and not handle.blockers
                and all(set(r.env) == set(inputs) for r in batch))
    if coalesce:
        bp = _batched_program(handle, len(batch), cache)
        stacked = {n: np.stack([np.asarray(r.env[n]) for r in batch])
                   for n in inputs}
        env = bp.make_env(**stacked)
        out = bp.lower(jit=True)(env)
        results = [
            {n: np.asarray(out[n])[i] for n in program.output_names}
            for i in range(len(batch))]
    else:
        results = []
        low = program.lower(jit=True)
        for r in batch:
            out = low(program.make_env(**r.env))
            results.append({n: np.asarray(out[n])
                            for n in program.output_names})
    if stats is not None:
        with cond:
            if coalesce:
                stats.batched_requests += len(batch)
            else:
                stats.fallback_requests += len(batch)
    return results


# --------------------------------------------------------------------------
# Worker-process side (module-level: must pickle by reference under spawn).
# --------------------------------------------------------------------------

_WORKER_PROGRAMS: dict = {}


def _serve_worker_init(env: dict) -> None:
    """Runs once in each spawned worker: point this process at the shared
    disk compile cache and tuning-DB sidecar before any codo import binds
    its defaults."""
    for k, v in env.items():
        if v:
            os.environ[k] = v
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _maybe_fault() -> None:
    """Test-only crash injection (``CODO_SERVE_FAULT``): ``crash`` dies on
    every request; ``crash_once:<marker>`` dies only while the marker file
    exists and consumes it first, so exactly one crash happens no matter
    how many workers race."""
    fault = os.environ.get("CODO_SERVE_FAULT", "")
    if fault == "crash":
        os._exit(1)
    if fault.startswith("crash_once:"):
        marker = fault.split(":", 1)[1]
        try:
            os.unlink(marker)
        except FileNotFoundError:
            return
        os._exit(1)


def _serve_worker_run(path: str, generation: int, envs: list[dict],
                      batch_ok: bool):
    """One dispatch group inside a worker.  The artifact is loaded (and
    its batched variants compiled) at most once per (path, generation) per
    worker; the compiles go through the shared disk cache, so sibling
    workers hit what the first one stored."""
    _maybe_fault()
    key = (path, generation)
    handle = _WORKER_PROGRAMS.get(key)
    if handle is None:
        from repro import api as codo
        from repro.kernels import register_all
        register_all()
        handle = _ModelHandle("worker", codo.load(path), path, generation)
        _WORKER_PROGRAMS[key] = handle
    from repro.core.compiler import default_cache
    batch = [_Request(i, "worker", env, ServeFuture(i))
             for i, env in enumerate(envs)]
    if not batch_ok:
        handle.blockers = handle.blockers or ["disabled"]
    results = _run_batch(handle, batch, default_cache())
    coalesced = len(envs) > 1 and batch_ok and not handle.blockers
    return results, coalesced
