"""repro.serving — the production serving stack.

* :mod:`repro.serving.runtime` — :class:`ServingRuntime`: bounded queue,
  dynamic batching, process worker pool, zero-downtime artifact hot-swap
  (``docs/serving.md``).
* :mod:`repro.serving.cli` — the ``python -m repro.serving.cli`` launcher
  (``repro.launch.serve`` is its deprecated alias).
* :mod:`repro.serving.generator` — slot-based LM token generation
  (``repro.serving.serve`` is its deprecated alias).

Only the runtime names are imported eagerly; the generator pulls in the
transformer stack, so import it explicitly.
"""

from .runtime import (QueueFullError, ServeConfig, ServeError, ServeFuture,
                      ServeStats, ServingRuntime)

__all__ = ["QueueFullError", "ServeConfig", "ServeError", "ServeFuture",
           "ServeStats", "ServingRuntime"]
