"""Deprecated alias — the generation driver lives in
:mod:`repro.serving.generator` (the ``serve`` name now belongs to the
serving *runtime* stack: ``repro.serving.runtime`` + ``repro.serving.cli``).

This shim warns once on import and re-exports the public names so old
imports keep working; new code should import ``repro.serving.generator``.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.serving.serve is deprecated: import repro.serving.generator "
    "(generation driver) or repro.serving.runtime (serving runtime) instead",
    DeprecationWarning, stacklevel=2)

from .generator import (Generator, Request, build_prefill_step,  # noqa: E402
                        build_serve_step, jit_prefill_step, jit_serve_step)

__all__ = ["Generator", "Request", "build_prefill_step", "build_serve_step",
           "jit_prefill_step", "jit_serve_step"]
