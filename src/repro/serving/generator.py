"""Serving: jitted decode/prefill steps + a batched generation driver.

``build_serve_step`` is what the decode-shape dry-run cells lower: one new
token for every sequence in the batch against a KV cache / recurrent state
of the cell's stated length, cache donated (in-place ring-buffer update).

``Generator`` is the runnable driver (examples/serve_lm.py): greedy or
top-k sampling, slot-based continuous batching (finished sequences are
replaced by queued requests without re-compiling).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..distributed.sharding import (as_shardings, batch_specs, cache_specs,
                                    param_specs)
from ..models import transformer as tf


def build_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, tokens, cache):
        logits, cache = tf.decode_step(params, tokens, cache, cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache
    return serve_step


def jit_serve_step(cfg: ArchConfig, mesh, params_or_shapes, cache_like):
    pspecs = param_specs(params_or_shapes, mesh, cfg)
    cspecs = cache_specs(cache_like, mesh, cfg)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # NamedShardings, not bare specs: older jax.jit rejects PartitionSpec.
    pshard, tshard, cshard = (
        as_shardings(s, mesh)
        for s in (pspecs, jax.sharding.PartitionSpec(dp), cspecs))
    return jax.jit(
        build_serve_step(cfg),
        in_shardings=(pshard, tshard, cshard),
        out_shardings=(tshard, None, cshard),
        donate_argnums=(2,),
    )


def build_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        return tf.prefill(params, batch, cfg)
    return prefill_step


def jit_prefill_step(cfg: ArchConfig, mesh, params_or_shapes, batch_like):
    pspecs = param_specs(params_or_shapes, mesh, cfg)
    bspecs = batch_specs(batch_like, mesh)
    return jax.jit(build_prefill_step(cfg),
                   in_shardings=(as_shardings(pspecs, mesh),
                                 as_shardings(bspecs, mesh)),
                   out_shardings=None)


# --------------------------------------------------------------------------
# Generation driver
# --------------------------------------------------------------------------


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


class Generator:
    """Slot-based batched decoding with greedy sampling."""

    def __init__(self, cfg: ArchConfig, params, batch: int, cache_len: int):
        self.cfg, self.params = cfg, params
        self.batch, self.cache_len = batch, cache_len
        self.cache = tf.init_cache(cfg, batch, cache_len)
        self.step_fn = jax.jit(build_serve_step(cfg), donate_argnums=(2,))
        self.slots: list[Request | None] = [None] * batch
        self.queue: list[Request] = []
        self.tokens = np.zeros((batch,), np.int32)
        self.steps = 0
        self.tokens_out = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # feed the prompt one token at a time (prefill-by-decode —
                # fine at example scale; production prefill uses prefill())
                self.tokens[i] = req.prompt[0]
                req._cursor = 1  # type: ignore[attr-defined]

    def step(self) -> None:
        self._fill_slots()
        tok = jnp.asarray(self.tokens)
        nxt, _logits, self.cache = self.step_fn(self.params, tok, self.cache)
        nxt = np.asarray(nxt)
        self.steps += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = getattr(req, "_cursor", len(req.prompt))
            if cur < len(req.prompt):
                self.tokens[i] = req.prompt[cur]
                req._cursor = cur + 1  # type: ignore[attr-defined]
            else:
                req.out.append(int(nxt[i]))
                self.tokens[i] = int(nxt[i])
                self.tokens_out += 1
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.slots[i] = None

    def run(self, max_steps: int = 256) -> list[Request]:
        finished: list[Request] = []
        pending = list(self.queue)
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
            for r in pending:
                if r.done and r not in finished:
                    finished.append(r)
        return finished
