"""The serving CLI: batched LM generation, or a request loop against a
compiled-design artifact — now routed through the
:class:`~repro.serving.runtime.ServingRuntime` (dynamic batching, worker
pool, hot-swap; see ``docs/serving.md``).

CPU-scale LM demo:
    PYTHONPATH=src python -m repro.serving.cli --arch gpt2-medium --smoke \\
        --requests 6 --batch 4 --max-new 8

Artifact serving — no recompile, no model code: ``codo.load`` a versioned
JSON artifact (docs/artifact_format.md) into a ``CompiledProgram`` and run
a request loop against the jitted design.  By default each request gets
random inputs; production-style serving feeds real tensors from an npz
archive (one array per input buffer, validated against the artifact's
buffer table):

    PYTHONPATH=src python -m repro.core.compiler --configs gpt2-medium \\
        --opts opt5 --export artifacts/
    PYTHONPATH=src python -m repro.serving.cli \\
        --artifact artifacts/gpt2-medium-opt5.json --requests 8 \\
        --inputs batch.npz

``python -m repro.launch.serve`` remains as a deprecated alias.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np


class InputError(ValueError):
    """An --inputs npz archive does not match the artifact's buffers."""


def load_input_env(path: str, graph) -> dict:
    """Load real input tensors for ``graph`` from an ``.npz`` archive.

    Every ``input`` buffer must be present with the exact declared shape;
    dtypes are normalized *before* validation: arrays are cast to the
    buffer dtype (an information-losing cast — e.g. float64 data under
    disabled x64, or int labels into a float buffer — is allowed,
    mirroring jnp's weak-dtype behavior), and a non-numeric array that
    cannot cast is an :class:`InputError`, never a raw traceback.  Weight
    buffers may optionally be supplied too; unknown array names are an
    error, so a typo'd key cannot silently fall back to random data.
    Every failure mode — unreadable archive, pickled object arrays, 0-d
    scalars, shape or name mismatches — reports as :class:`InputError`
    (CLI exit code 2).
    """
    try:
        with np.load(path) as npz:
            arrays = {k: npz[k] for k in npz.files}
    except InputError:
        raise
    except Exception as e:      # OSError, BadZipFile, pickle-disabled, ...
        raise InputError(f"{path}: not a readable npz archive "
                         f"({type(e).__name__}: {e})") from e
    bindable = {b.name: b for b in graph.buffers.values()
                if b.kind in ("input", "weight")}
    unknown = sorted(set(arrays) - set(bindable))
    if unknown:
        raise InputError(f"{path}: unknown array names {unknown}; "
                         f"bindable buffers: {sorted(bindable)}")
    missing = sorted(b.name for b in graph.inputs() if b.name not in arrays)
    if missing:
        raise InputError(f"{path}: missing input arrays {missing} "
                         f"(inputs: {sorted(b.name for b in graph.inputs())})")
    env = {}
    for name, arr in arrays.items():
        buf = bindable[name]
        # Normalize the dtype first: validation below then reasons about
        # clean, buffer-typed arrays only.
        try:
            arr = np.asarray(arr).astype(np.dtype(buf.dtype), copy=False)
        except (TypeError, ValueError) as e:
            raise InputError(
                f"{path}: array {name!r} (dtype {np.asarray(arr).dtype}) "
                f"does not cast to buffer dtype "
                f"{np.dtype(buf.dtype).name}: {e}") from e
        if arr.ndim == 0 and tuple(buf.shape):
            raise InputError(
                f"{path}: array {name!r} is 0-d (a Python scalar saved "
                f"with np.savez?); buffer {name!r} expects shape "
                f"{tuple(buf.shape)}")
        if tuple(arr.shape) != tuple(buf.shape):
            raise InputError(f"{path}: array {name!r} has shape "
                             f"{tuple(arr.shape)}, buffer expects "
                             f"{tuple(buf.shape)}")
        env[name] = arr
    return env


def serve_artifact(args) -> int:
    """Serve straight from an imported artifact: the design the compiler
    exported is the unit of deployment — this launcher never sees the
    model-building code that produced it.  Requests flow through the
    :class:`ServingRuntime`: same-model requests inside one batching
    window coalesce into a leading-batch-dim execution."""
    from repro.core.artifact import artifact_summary
    from repro.kernels import register_all
    from repro.models.dataflow_models import random_inputs

    from .runtime import ServeConfig, ServingRuntime

    register_all()     # fused-group kinds resolve against this process
    print(artifact_summary(args.artifact))
    cfg = ServeConfig.from_env(workers=args.workers,
                               batch_window_ms=args.batch_window_ms,
                               max_batch=max(1, args.max_batch))
    with ServingRuntime(cfg) as rt:
        handle = rt.add_model("artifact", args.artifact)
        program = handle.program
        if cfg.workers == 0:
            print(program.lower(jit=True).summary())

        if args.inputs:
            env = load_input_env(args.inputs, program.graph)
            try:
                program.make_env(**env)     # validate before serving
            except (KeyError, TypeError, ValueError) as e:
                # Anything load_input_env's checks missed still reports as
                # the documented InputError (exit 2), never a traceback.
                raise InputError(f"{args.inputs}: {e}") from e
            envs = [env] * args.requests
            print(f"serving real inputs from {args.inputs} "
                  f"({sorted(env)})")
        else:
            # Inputs only: the weights are the model's (bound from the
            # v1.3 payload, or the deterministic initializer) — and
            # identical-keyed requests coalesce into batched dispatches.
            envs = [{n: random_inputs(program.graph, seed=args.seed + i)[n]
                     for n in program.input_names}
                    for i in range(args.requests)]

        t0 = time.time()
        futs = [rt.submit("artifact", **env) for env in envs]
        outs = [f.result(timeout=600) for f in futs]
        dt = time.time() - t0
        s = rt.stats
        print(f"{args.requests} requests in {dt * 1e3:.1f} ms "
              f"({args.requests / max(dt, 1e-9):.1f} req/s); "
              f"{s.batches} dispatches, {s.batched_requests} batched / "
              f"{s.fallback_requests} per-request; "
              f"outputs {sorted(program.output_names)}")
        assert len(outs) == args.requests
    return 0


def serve_lm(args) -> int:
    from repro.configs import get_config
    from repro.models import transformer as tf

    import jax

    from .generator import Generator, Request

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    gen = Generator(cfg, params, batch=args.batch, cache_len=args.cache_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        gen.submit(Request(rid, prompt=list(
            rng.integers(1, cfg.vocab, size=args.prompt_len)),
            max_new=args.max_new))

    t0 = time.time()
    finished = gen.run(max_steps=args.cache_len - 1)
    dt = time.time() - t0
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    print(f"{len(finished)}/{args.requests} finished; {gen.steps} decode "
          f"steps, {gen.tokens_out} tokens, "
          f"{gen.tokens_out / max(dt, 1e-9):.1f} tok/s (CPU smoke)")
    return 0


def main(argv=None) -> int:
    from .runtime import ServeConfig
    env = ServeConfig.from_env()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="",
                    help="LM architecture to serve (token generation)")
    ap.add_argument("--artifact", default="",
                    help="serve a compiled-design JSON artifact instead "
                         "(see docs/artifact_format.md)")
    ap.add_argument("--inputs", default="",
                    help="with --artifact: npz archive of real input "
                         "tensors (one array per input buffer; shapes/"
                         "dtypes validated) instead of random data")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=env.workers,
                    help="serving worker processes (0 = in-process; "
                         "default CODO_SERVE_WORKERS)")
    ap.add_argument("--batch-window-ms", type=float,
                    default=env.batch_window_ms,
                    help="dynamic-batching window "
                         "(default CODO_SERVE_BATCH_WINDOW_MS)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="dispatch a window early at this group size")
    args = ap.parse_args(argv)

    if bool(args.arch) == bool(args.artifact):
        ap.error("exactly one of --arch or --artifact is required")
    if args.inputs and not args.artifact:
        ap.error("--inputs only applies to --artifact serving")
    if args.artifact and args.requests < 1:
        ap.error("--requests must be >= 1 when serving an artifact")
    try:
        return serve_artifact(args) if args.artifact else serve_lm(args)
    except InputError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
