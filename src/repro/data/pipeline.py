"""Deterministic sharded synthetic data pipeline with background prefetch.

Production posture without external deps:

* **Determinism & elasticity** — batch(step) is a pure function of
  (seed, step, global layout), so restarts and re-sharded restarts replay
  the exact token stream: host h of H regenerates its slice from the
  global index space regardless of H (elastic re-mesh safe).
* **Prefetch** — a daemon thread keeps a bounded queue of ready batches
  (double buffering the host→device copy against the step).
* **Packing** — documents of geometric length are packed into fixed
  (batch, seq) windows with -100-masked boundaries, which exercises the
  loss mask path the way a real LM mixture would.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np

from ..configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    mask_boundaries: bool = True
    num_hosts: int = 1
    host_index: int = 0


class SyntheticLM:
    """Deterministic synthetic token stream."""

    def __init__(self, cfg: ArchConfig, dc: DataConfig):
        assert dc.global_batch % dc.num_hosts == 0
        self.cfg, self.dc = cfg, dc
        self.local_batch = dc.global_batch // dc.num_hosts

    def _row(self, step: int, global_row: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.dc.seed * 1_000_003 + step) * 65_537 + global_row)
        s = self.dc.seq_len
        toks = rng.integers(1, self.cfg.vocab, size=s + 1, dtype=np.int64)
        if self.dc.mask_boundaries:
            # pack geometric-length documents; boundary target is masked
            pos = 0
            while pos < s:
                ln = int(rng.geometric(1.0 / self.dc.mean_doc_len))
                pos += max(ln, 1)
                if pos <= s:
                    toks[pos - 1] = 0  # EOD
        return toks

    def batch(self, step: int) -> dict:
        rows = [self._row(step, self.dc.host_index * self.local_batch + r)
                for r in range(self.local_batch)]
        arr = np.stack(rows)
        tokens = arr[:, :-1].astype(np.int32)
        labels = arr[:, 1:].astype(np.int32)
        if self.dc.mask_boundaries:
            labels = np.where(tokens == 0, -100, labels)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.n_patches:
            rng = np.random.default_rng(self.dc.seed * 7 + step)
            out["tokens"] = out["tokens"][:, :self.dc.seq_len - self.cfg.n_patches]
            out["labels"] = out["labels"][:, :self.dc.seq_len - self.cfg.n_patches]
            out["patch_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.n_patches, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        if self.cfg.enc_dec:
            rng = np.random.default_rng(self.dc.seed * 13 + step)
            out["frames"] = rng.standard_normal(
                (self.local_batch, self.cfg.enc_frames, self.cfg.d_model)
            ).astype(np.float32) * 0.02
        return out


class Prefetcher:
    """Bounded background prefetch over any ``batch(step)`` source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self.q.get()

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
