"""Sharding rules: parameter/batch/cache PartitionSpecs per architecture.

Strategy (DESIGN.md §5):

* **Parameters** — 2D-sharded: the tensor-parallel dim (heads / d_ff /
  experts / vocab) over ``model``, the other large dim over ``data``
  (FSDP/ZeRO-3 posture: all-gathered at use, grads reduce-scattered by
  GSPMD).  Replicated over ``pod`` (pure DP across pods → hierarchical
  all-reduce on the slow axis).
* **Optimizer state** — same specs as its parameter.
* **Batch** — global batch over ("pod","data"); sequence unsharded.
* **KV cache / SSM state** — batch over data axes, heads/channels over
  ``model``.

Rules are path-pattern based over the param pytree so every architecture
family (dense / MoE / SSM / hybrid / enc-dec) is covered by one table.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


def use_mesh(mesh: Mesh):
    """Version-compat context manager activating ``mesh`` as the ambient
    mesh.

    Newer JAX exposes ``jax.sharding.use_mesh`` (context manager) or
    ``jax.set_mesh``; older releases (<= 0.4.x) only have the ``Mesh``
    object's own context manager.  Callers write ``with use_mesh(m): ...``
    and get whichever the installed JAX supports.
    """
    um = getattr(jax.sharding, "use_mesh", None)
    if um is not None:
        return um(mesh)
    sm = getattr(jax, "set_mesh", None)
    if sm is not None:
        try:
            prev = jax.sharding.get_abstract_mesh()
        except Exception:
            prev = None
        ctx = sm(mesh)
        if hasattr(ctx, "__enter__"):
            return ctx

        # set_mesh mutated global state: restore the previous mesh on exit
        # so the with-block doesn't leak its mesh into later code.
        @contextlib.contextmanager
        def _restoring():
            try:
                yield mesh
            finally:
                try:
                    sm(prev)
                except Exception:
                    pass
        return _restoring()
    return mesh  # jax <= 0.4.x: Mesh is itself a context manager


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, **kwargs):
    """Version-compat ``jax.shard_map``.

    Newer JAX promotes shard_map to the top level with a ``check_vma``
    flag; older releases ship it as ``jax.experimental.shard_map`` with the
    flag spelled ``check_rep``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)

# stacked containers whose leaves carry a leading layer/group dim
_STACKED = ("groups", "encoder", "decoder")


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def _param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
                cfg: ArchConfig) -> P:
    axes = set(mesh.axis_names)
    dp = "data" if "data" in axes else None
    tp = "model" if "model" in axes else None
    nd = len(shape)
    stacked = any(path.startswith(s + "/") or f"/{s}/" in path for s in _STACKED)
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape
    bn = len(body)

    def spec(*xs):
        return P(*(lead + tuple(xs)))

    # ---- embeddings ------------------------------------------------------
    if path.endswith("embed/tok"):
        return spec(tp, dp)
    if path.endswith("embed/pos"):
        return spec(None, tp)
    if path.endswith("lm_head/w"):
        return spec(dp, tp)

    # ---- norms / scalars ---------------------------------------------------
    if bn <= 1:
        return spec(*([None] * bn))

    # ---- MoE expert banks (leading E dim) ----------------------------------
    if "/mlp/" in path and bn == 3:
        E = body[0]
        tp_sz = mesh.shape.get("model", 1)
        if E >= tp_sz:
            # expert parallelism over `model`
            if path.endswith("w_out"):
                return spec(tp, None, dp)
            return spec(tp, dp, None)
        # few experts: shard the ffn dim instead
        if path.endswith("w_out"):
            return spec(None, tp, dp)
        return spec(None, dp, tp)
    if path.endswith("mlp/router/w"):
        return spec(dp, None)

    # ---- attention ---------------------------------------------------------
    if any(path.endswith(s) for s in ("wq/w", "wk/w", "wv/w")):
        return spec(dp, tp)
    if path.endswith("wo/w"):
        return spec(tp, dp)
    if any(path.endswith(s) for s in ("wq/b", "wk/b", "wv/b")):
        return spec(tp)

    # ---- dense FFN ---------------------------------------------------------
    if any(path.endswith(s) for s in ("w_in/w", "w_gate/w")):
        return spec(dp, tp)
    if path.endswith("w_out/w"):
        return spec(tp, dp)
    if any(path.endswith(s) for s in ("w_in/b", "w_gate/b")):
        return spec(tp)

    # ---- SSM ----------------------------------------------------------------
    if path.endswith("in_proj/w"):
        return spec(dp, tp)
    if path.endswith("out_proj/w"):
        return spec(tp, dp)
    if path.endswith("ssm/conv"):
        return spec(None, tp)

    # ---- RG-LRU -------------------------------------------------------------
    if any(path.endswith(s) for s in ("in_x/w", "in_y/w", "w_a/w", "w_i/w")):
        return spec(dp, tp)
    if path.endswith("rec/out/w"):
        return spec(tp, dp)
    if path.endswith("rec/conv"):
        return spec(None, tp)

    # default: shard the biggest dim over model when divisible, else replicate
    body_specs: list[Any] = [None] * bn
    big = int(np.argmax(body))
    if tp and body[big] % mesh.shape["model"] == 0:
        body_specs[big] = tp
    return spec(*body_specs)


class ShardingSpecError(ValueError):
    """A PartitionSpec names a mesh axis that does not exist or does not
    divide the dim it shards (raised by :func:`sanitize_spec` in strict
    mode instead of silently truncating the spec)."""


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh: Mesh, *,
                  strict: bool = True, path: str = "") -> P:
    """Validate ``spec`` against ``shape`` on ``mesh``.

    Strict (the default): raise :class:`ShardingSpecError` when a named
    axis is missing from the mesh or does not divide its dim evenly (pjit
    input shardings require equal shards) — a spec that silently degrades
    to replicated is a perf cliff, not a preference.

    ``strict=False`` restores the historical best-effort behavior — drop
    the offending axes and keep the rest — which is what the heuristic
    rule tables here want (e.g. long_500k's global_batch=1 legitimately
    turns its batch sharding off).  ``path`` labels errors with the pytree
    location.
    """
    if len(spec) > len(shape):
        raise ShardingSpecError(
            f"{path or 'spec'}: PartitionSpec{tuple(spec)} has "
            f"{len(spec)} entries for shape {tuple(shape)} of rank "
            f"{len(shape)}")
    out = []
    where = f" at {path!r}" if path else ""
    for d, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        size = shape[d]
        for a in axes:
            n = mesh.shape.get(a)
            if n is None:
                if strict:
                    raise ShardingSpecError(
                        f"spec{where} names mesh axis {a!r} on dim {d}, "
                        f"but the mesh only has axes "
                        f"{tuple(mesh.axis_names)}")
                continue
            if size % n == 0:
                keep.append(a)
                size //= n
            elif strict:
                raise ShardingSpecError(
                    f"spec{where} shards dim {d} (size {shape[d]}) over "
                    f"mesh axis {a!r} (size {n}), which does not divide "
                    f"it evenly; pass strict=False to drop the axis "
                    f"instead")
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def param_specs(params_or_shapes: Any, mesh: Mesh, cfg: ArchConfig, *,
                strict: bool = False) -> Any:
    """PartitionSpec pytree mirroring the param pytree.

    The rule table is a placement *preference*, so by default dims the
    mesh cannot divide fall back to replicated.  ``strict=True`` turns
    every such fallback into a :class:`ShardingSpecError` naming the
    parameter — use it in tests/CI to prove a config shards cleanly on a
    given mesh.
    """

    def rule(path, leaf):
        p = _path_str(path)
        spec = _param_spec(p, tuple(leaf.shape), mesh, cfg)
        return sanitize_spec(spec, tuple(leaf.shape), mesh,
                             strict=strict, path=p)

    return jax.tree_util.tree_map_with_path(rule, params_or_shapes)


def param_shardings(params_or_shapes: Any, mesh: Mesh, cfg: ArchConfig) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_or_shapes, mesh, cfg))


def as_shardings(specs: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree.

    Version compat for ``jax.jit`` in/out_shardings: newer JAX resolves bare
    PartitionSpecs against the ambient mesh, older releases require
    ``Sharding`` objects.  ``None`` leaves (infer/replicate) pass through.
    """
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Batch dim over (pod, data); everything else replicated."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def rule(leaf):
        nd = len(leaf.shape)
        if not nd:
            return P()
        return sanitize_spec(P(dp, *([None] * (nd - 1))), tuple(leaf.shape),
                             mesh, strict=False)

    return jax.tree.map(rule, batch)


def cache_specs(cache: Any, mesh: Mesh, cfg: ArchConfig) -> Any:
    """Decode-cache specs: batch over data axes, head/channel dim over model.

    Cache leaf layouts (leading group dim G when stacked):
      kv        (G, B, C, Hkv, hd)
      rglru h   (G, B, D)          rglru conv (G, B, 3, D)
      ssm state (G, B, H, P, N)    ssm conv   (G, B, cw-1, ch)
      enc_out   (B, F, D)
      pos       ()
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def rule(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        stacked = p.startswith(("groups", "layers")) or "/groups/" in p
        lead = (None,) if stacked else ()
        body = leaf.shape[1:] if stacked else leaf.shape
        bn = len(body)
        if p.endswith("enc_out"):
            spec = P(dp, None, None)
        elif bn == 4 and p.split("/")[-1] in ("k", "v"):
            # (B, C, Hkv, hd): shard heads over model when divisible,
            # otherwise shard the cache sequence dim (partial-softmax
            # reductions over C are GSPMD-expressible)
            tp_sz = mesh.shape.get("model", 1)
            if body[2] % tp_sz == 0:
                spec = P(*(lead + (dp, None, "model", None)))
            else:
                spec = P(*(lead + (dp, "model", None, None)))
        elif bn == 4:                                        # ssm state (B,H,P,N)
            spec = P(*(lead + (dp, "model", None, None)))
        elif bn == 3:                                        # conv buffers
            spec = P(*(lead + (dp, None, "model")))
        elif bn == 2:                                        # rglru h (B,D)
            spec = P(*(lead + (dp, "model")))
        else:
            spec = P(*(lead + (dp,) + (None,) * (bn - 1)))
        return sanitize_spec(spec, tuple(leaf.shape), mesh,
                             strict=False, path=p)

    return jax.tree_util.tree_map_with_path(rule, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# In-model sharding hints
# --------------------------------------------------------------------------

BATCH = ("pod", "data")  # logical batch axes


def shard_hint(x, *axes):
    """``with_sharding_constraint`` that adapts to whatever mesh is current.

    ``axes`` entries are mesh-axis names, tuples of names, or None; names
    missing from the current mesh and dims the axes don't divide are
    dropped.  Outside any mesh this is the identity, so model code can
    sprinkle hints without caring about the execution context.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or not mesh.axis_names:
        return x
    names = set(mesh.axis_names)
    spec = []
    for d, a in enumerate(axes):
        cand = a if isinstance(a, tuple) else ((a,) if a else ())
        keep, size = [], x.shape[d]
        for nm in cand:
            if nm in names and size % mesh.shape[nm] == 0:
                keep.append(nm)
                size //= mesh.shape[nm]
        spec.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return jax.lax.with_sharding_constraint(x, P(*spec))
