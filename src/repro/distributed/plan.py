"""Pure-data sharding plan: mesh shape + per-buffer placements + the
collective schedule that stitches the shards back together.

Everything here is importable without jax (mirroring ``core/artifact.py``):
the plan is what enters the lowering memo key and the v1.4 artifact
``sharding`` section, so it must be plain hashable data that round-trips
through JSON byte-for-byte.  Building a plan from a graph lives in
:mod:`repro.distributed.partition`; turning one into ``jax.lax``
collectives lives in :mod:`repro.distributed.collectives`.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

__all__ = [
    "MeshSpec",
    "ShardSpec",
    "CollectiveStep",
    "ShardingPlan",
    "COLLECTIVE_KINDS",
]

# Typed collective vocabulary.  ``via`` on a step records how the plan
# decided to realize it (bandwidth-optimal decompositions are a *plan*
# decision, not an execution-time one, so artifacts replay identically):
#   all_gather      via "direct" (jax.lax.all_gather) or "ring" (ppermute)
#   psum            via "direct" (jax.lax.psum) or "rs_ag"
#                   (reduce_scatter + all_gather, 2(n-1)/n bytes per link)
#   reduce_scatter  emitted only as a component of an "rs_ag" psum today
#   ppermute        the ring building block; emitted via all_gather "ring"
COLLECTIVE_KINDS = ("all_gather", "reduce_scatter", "psum", "ppermute")


@dataclass(frozen=True)
class MeshSpec:
    """Device-mesh shape as pure data: ``(("data", 4), ("model", 2))``.

    The jax ``Mesh`` (which pins actual devices) is only reconstructed at
    execution time — see ``launch.mesh.mesh_from_spec`` — so a plan made
    on an 8-device CI host round-trips through an artifact and reloads on
    any machine with enough devices.
    """

    axes: tuple[tuple[str, int], ...]

    def __post_init__(self):
        axes = tuple((str(n), int(s)) for n, s in self.axes)
        object.__setattr__(self, "axes", axes)
        names = [n for n, _ in axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh axis names: {names}")
        for n, s in axes:
            if s < 1:
                raise ValueError(f"mesh axis {n!r} has size {s}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.axes)

    @property
    def size(self) -> int:
        total = 1
        for _, s in self.axes:
            total *= s
        return total

    def axis_size(self, name: str) -> int:
        for n, s in self.axes:
            if n == name:
                return s
        raise KeyError(f"no mesh axis {name!r} in {self.names}")

    @classmethod
    def of(cls, mesh) -> "MeshSpec":
        """Coerce a jax ``Mesh`` (duck-typed: ``.shape`` mapping) or an
        existing ``MeshSpec``."""
        if isinstance(mesh, cls):
            return mesh
        shape = getattr(mesh, "shape", None)
        if hasattr(shape, "items"):
            return cls(tuple((str(k), int(v)) for k, v in shape.items()))
        raise TypeError(f"cannot build MeshSpec from {type(mesh).__name__}")

    def to_dict(self) -> dict:
        return {"axes": [[n, s] for n, s in self.axes]}

    @classmethod
    def from_dict(cls, d: dict) -> "MeshSpec":
        return cls(tuple((n, s) for n, s in d["axes"]))


@dataclass(frozen=True)
class ShardSpec:
    """Per-buffer placement: one mesh-axis name (or None) per buffer dim.

    The *local* array on each device is the global shape with every
    sharded dim divided by its axis size; a spec of all-None means the
    buffer is fully replicated.
    """

    dims: tuple[str | None, ...]

    def __post_init__(self):
        object.__setattr__(
            self, "dims",
            tuple(None if d is None else str(d) for d in self.dims))
        named = [d for d in self.dims if d is not None]
        if len(set(named)) != len(named):
            raise ValueError(f"mesh axis used on two dims: {self.dims}")

    @property
    def is_replicated(self) -> bool:
        return all(d is None for d in self.dims)

    def shard_factor(self, mesh: MeshSpec) -> int:
        f = 1
        for d in self.dims:
            if d is not None:
                f *= mesh.axis_size(d)
        return f

    def local_shape(self, shape: tuple[int, ...], mesh: MeshSpec) -> tuple:
        out = []
        for size, d in zip(shape, self.dims):
            out.append(size if d is None else size // mesh.axis_size(d))
        return tuple(out)

    @classmethod
    def replicated(cls, ndim: int) -> "ShardSpec":
        return cls((None,) * ndim)

    def to_dict(self) -> dict:
        return {"dims": list(self.dims)}

    @classmethod
    def from_dict(cls, d: dict) -> "ShardSpec":
        return cls(tuple(d["dims"]))


@dataclass(frozen=True)
class CollectiveStep:
    """One inter-device edge of the TransferPlan, lowered to a typed
    collective.  ``where``/``task`` anchor it in the schedule: gathers run
    *before* the first consumer that needs the full buffer, reductions
    run *after* the producer that left partial sums.

    Buffer sizing reuses the FIFO-depth machinery: ``depth`` slots of
    ``chunk_bytes`` each (a ring step holds one in-flight chunk per slot,
    exactly like a FIFO holds ``fifo_depth`` tiles), and ``channel`` is
    the HBM channel the off-chip pass assigned to the staged buffer.
    """

    kind: str                 # one of COLLECTIVE_KINDS
    buffer: str               # env/scope key the step rewrites
    axis: str                 # mesh axis reduced/gathered over
    task: str                 # schedule anchor (task name)
    where: str = "after"      # "before" (pre-consumer) | "after" (post-producer)
    dim: int = 0              # buffer dim gathered/scattered (AG/RS)
    bytes: int = 0            # per-device payload
    chunk_bytes: int = 0      # one ring/scatter chunk
    depth: int = 1            # FIFO-depth slots backing the transfer
    channel: int = -1         # HBM channel from the TransferPlan (-1: none)
    via: str = "direct"       # "direct" | "ring" | "rs_ag"

    def __post_init__(self):
        if self.kind not in COLLECTIVE_KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r}")
        if self.where not in ("before", "after"):
            raise ValueError(f"bad collective anchor {self.where!r}")

    def to_dict(self) -> dict:
        return {
            "kind": self.kind, "buffer": self.buffer, "axis": self.axis,
            "task": self.task, "where": self.where, "dim": self.dim,
            "bytes": self.bytes, "chunk_bytes": self.chunk_bytes,
            "depth": self.depth, "channel": self.channel, "via": self.via,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CollectiveStep":
        return cls(**{k: d[k] for k in (
            "kind", "buffer", "axis", "task", "where", "dim", "bytes",
            "chunk_bytes", "depth", "channel", "via")})


@dataclass(frozen=True)
class ShardingPlan:
    """The complete multi-device story for one compiled design."""

    mesh: MeshSpec
    strategy: str                                  # replicate|dp|tp|dp_tp
    specs: dict[str, ShardSpec] = field(default_factory=dict)
    steps: tuple[CollectiveStep, ...] = ()
    estimated_cycles: float = 0.0                  # per-device, collectives in

    def spec_of(self, buffer: str, ndim: int) -> ShardSpec:
        return self.specs.get(buffer, ShardSpec.replicated(ndim))

    @property
    def collective_bytes(self) -> int:
        return sum(s.bytes for s in self.steps)

    def digest(self) -> str:
        """Stable content digest — enters the lowering memo key (the same
        role ``RoutingCostParams.digest`` plays for routing state)."""
        canon = (
            self.mesh.axes, self.strategy,
            tuple(sorted((k, v.dims) for k, v in self.specs.items())),
            tuple((s.kind, s.buffer, s.axis, s.task, s.where, s.dim,
                   s.via) for s in self.steps),
        )
        return hashlib.sha256(repr(canon).encode()).hexdigest()[:16]

    def summary(self) -> str:
        sharded = sum(1 for s in self.specs.values() if not s.is_replicated)
        kinds = {}
        for s in self.steps:
            kinds[s.kind] = kinds.get(s.kind, 0) + 1
        ks = ",".join(f"{k}x{v}" for k, v in sorted(kinds.items())) or "none"
        return (f"sharding[{self.strategy}] mesh="
                + "x".join(f"{n}:{s}" for n, s in self.mesh.axes)
                + f" {sharded}/{len(self.specs)} buffers sharded"
                + f" collectives={ks} ({self.collective_bytes} B)")

    def to_dict(self) -> dict:
        return {
            "mesh": self.mesh.to_dict(),
            "strategy": self.strategy,
            "specs": {k: v.to_dict() for k, v in sorted(self.specs.items())},
            "steps": [s.to_dict() for s in self.steps],
            "estimated_cycles": self.estimated_cycles,
            "digest": self.digest(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ShardingPlan":
        plan = cls(
            mesh=MeshSpec.from_dict(d["mesh"]),
            strategy=d["strategy"],
            specs={k: ShardSpec.from_dict(v)
                   for k, v in d.get("specs", {}).items()},
            steps=tuple(CollectiveStep.from_dict(s)
                        for s in d.get("steps", [])),
            estimated_cycles=float(d.get("estimated_cycles", 0.0)),
        )
        want = d.get("digest")
        if want and want != plan.digest():
            raise ValueError(
                f"sharding plan digest mismatch: {want} != {plan.digest()}")
        return plan
