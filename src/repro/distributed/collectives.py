"""Lower inter-device edges to typed collectives and execute them.

Two halves, split on the jax boundary:

* **Plan construction** (jax-free): :func:`build_steps` turns the raw
  events the partitioner emitted into sized
  :class:`~repro.distributed.plan.CollectiveStep` records.  Buffer sizing
  reuses the single-device FIFO machinery — a collective stages ``depth``
  slots (the buffer's FIFO depth) of one ``chunk_bytes`` chunk each, and
  inherits the HBM channel the off-chip pass balanced the buffer onto.
  Decomposition choices are made *here*, from byte counts, so an exported
  plan replays identically:

  - a psum at or above ``CODO_COLLECTIVE_RSAG_BYTES`` becomes
    reduce_scatter + all_gather (``via="rs_ag"``, the bandwidth-optimal
    2(n-1)/n bytes-per-link form) when the leading dim splits evenly;
  - an all_gather at or above ``CODO_COLLECTIVE_RING_BYTES`` becomes a
    ppermute ring (``via="ring"``): n-1 neighbor hops of one chunk each
    instead of one n·chunk broadcast.

* **Execution** (imports jax lazily): :func:`make_collective` compiles a
  step into a ``jax.lax`` closure applied inside ``shard_map``, and
  :func:`attach` anchors the closures before/after their tasks.
"""

from __future__ import annotations

import os
from collections import defaultdict

from repro.distributed.plan import CollectiveStep, MeshSpec, ShardingPlan

__all__ = ["build_steps", "make_collective", "attach",
            "env_partition_specs"]

_MIB = 1 << 20


def _threshold(env: str, default: int) -> int:
    try:
        return int(os.environ.get(env, default))
    except ValueError:
        return default


def _depth(graph, name: str, buffer_plan) -> int:
    if buffer_plan is not None:
        d = getattr(buffer_plan, "fifo_depth", {}).get(name)
        if d:
            return int(d)
    from repro.core import buffers as _b
    return int(_b._fifo_depth(graph, graph.buffers[name]))


def build_steps(graph, mesh: MeshSpec, events, *, buffer_plan=None,
                transfer_plan=None) -> tuple[CollectiveStep, ...]:
    """Size and type the raw partitioner events into the plan schedule."""
    ring_at = _threshold("CODO_COLLECTIVE_RING_BYTES", _MIB)
    rsag_at = _threshold("CODO_COLLECTIVE_RSAG_BYTES", _MIB)
    channels = getattr(transfer_plan, "channel_of", None) or {}
    steps = []
    for ev in events:
        buf = graph.buffers[ev["buffer"]]
        n = mesh.axis_size(ev["axis"])
        if ev["kind"] == "all_gather":
            # each device contributes its local shard once per link
            payload = buf.nbytes // n
            chunk = payload
            via = "ring" if (payload * (n - 1) >= ring_at and n > 1) \
                else "direct"
        elif ev["kind"] == "psum":
            payload = buf.nbytes
            chunk = buf.nbytes // n
            via = "rs_ag" if (payload >= rsag_at and n > 1
                              and buf.shape and buf.shape[0] % n == 0) \
                else "direct"
        else:  # pragma: no cover - partitioner only emits the two above
            payload = buf.nbytes
            chunk = buf.nbytes // max(n, 1)
            via = "direct"
        steps.append(CollectiveStep(
            kind=ev["kind"], buffer=ev["buffer"], axis=ev["axis"],
            task=ev["task"], where=ev["where"], dim=int(ev.get("dim", 0)),
            bytes=int(payload), chunk_bytes=int(chunk),
            depth=_depth(graph, ev["buffer"], buffer_plan),
            channel=int(channels.get(ev["buffer"], -1)), via=via))
    return tuple(steps)


# --------------------------------------------------------------------------
# execution (lazy jax)
# --------------------------------------------------------------------------


def _ring_all_gather(x, axis_name: str, dim: int, n: int):
    """All-gather as n-1 ppermute neighbor hops.

    After hop j, the local slot holds the shard of device ``(i - j) mod
    n``; stacking the slots and reindexing by ``(i - arange(n)) mod n``
    restores device order before the concat, so the result is
    bit-identical to ``jax.lax.all_gather(..., tiled=True)``.
    """
    import jax
    import jax.numpy as jnp
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)
    stacked = jnp.take(jnp.stack(chunks), (idx - jnp.arange(n)) % n, axis=0)
    stacked = jnp.moveaxis(stacked, 0, dim)
    shape = x.shape[:dim] + (n * x.shape[dim],) + x.shape[dim + 1:]
    return stacked.reshape(shape)


def make_collective(step: CollectiveStep, mesh: MeshSpec):
    """Compile one plan step into a ``jax.lax`` closure (local -> local)."""
    import jax
    n = mesh.axis_size(step.axis)
    axis = step.axis
    if step.kind == "psum":
        if step.via == "rs_ag" and n > 1:
            def rs_ag(x):
                x = jax.lax.psum_scatter(x, axis, scatter_dimension=0,
                                         tiled=True)
                return jax.lax.all_gather(x, axis, axis=0, tiled=True)
            return rs_ag
        return lambda x: jax.lax.psum(x, axis)
    if step.kind == "all_gather":
        dim = step.dim
        if step.via == "ring" and n > 1:
            return lambda x: _ring_all_gather(x, axis, dim, n)
        return lambda x: jax.lax.all_gather(x, axis, axis=dim, tiled=True)
    raise ValueError(f"cannot execute collective kind {step.kind!r}")


def attach(steps):
    """Index plan steps by schedule anchor: (before[task], after[task])."""
    before: dict[str, list] = defaultdict(list)
    after: dict[str, list] = defaultdict(list)
    for s in steps:
        (before if s.where == "before" else after)[s.task].append(s)
    return before, after


def env_partition_specs(graph, plan: ShardingPlan):
    """jax ``PartitionSpec`` dicts for the env pytree: (inputs+weights,
    outputs) — what ``shard_map`` needs as in_specs/out_specs."""
    from jax.sharding import PartitionSpec as P

    def spec(buf):
        return P(*plan.spec_of(buf.name, len(buf.shape)).dims)

    ins = {b.name: spec(b) for b in graph.inputs() + graph.weights()}
    outs = {b.name: spec(b) for b in graph.outputs()}
    return ins, outs
