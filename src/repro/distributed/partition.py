"""Mesh partitioning pass: assign every buffer a placement and derive the
collective events that keep the sharded execution numerically identical
to the single-device lowering.

The pass runs *after* ``codo_opt`` (the single-device pipeline output is
mesh-agnostic, so the compile cache stays shared across meshes) and works
by forward propagation over the task toposort — the same order the
lowered program executes in, which is what lets a "gather before task T"
event rewrite the live value exactly once:

* **data parallel** seeds every graph input with its leading dim sharded
  over the ``data`` axis and lets specs flow through elementwise ops.
* **tensor parallel** decides weight placement lazily at each matmul:
  an unsharded activation gets a column-sharded weight (output sharded
  over ``model``), a ``model``-sharded activation gets a row-sharded
  weight — whose contraction leaves *partial sums*, resolved by a psum
  emitted right after the producing task (the Megatron pairing falls out
  of propagation instead of being pattern-matched).
* every op the rules don't understand conservatively gathers its sharded
  operands first, which is always correct — just not free.  The cost
  model (:func:`repro.core.costmodel.estimate_sharding`) prices those
  gathers against the per-shard compute win, and ``strategy="auto"``
  picks the cheapest feasible candidate.

Everything here is jax-free: the output is a pure-data
:class:`~repro.distributed.plan.ShardingPlan`.
"""

from __future__ import annotations

from typing import Iterable

from repro.core.costmodel import HwParams, V5E, estimate_sharding
from repro.distributed.plan import MeshSpec, ShardSpec, ShardingPlan
from repro.distributed import collectives as _coll

__all__ = ["PartitionError", "partition", "propagate", "STRATEGIES"]

STRATEGIES = ("replicate", "dp", "tp", "dp_tp", "auto")

_EWISE_UNARY = {"relu", "gelu", "scale", "affine", "divc", "rdivc",
                "identity"}
_EWISE_BINARY = {"add", "vadd", "mul", "div"}


class PartitionError(ValueError):
    """Unknown strategy, missing mesh axis, or an unshardable graph."""


def _dp_axis(mesh: MeshSpec) -> str:
    return "data" if "data" in mesh.names else mesh.names[0]


def _tp_axis(mesh: MeshSpec) -> str | None:
    if "model" in mesh.names:
        return "model"
    rest = [n for n in mesh.names if n != _dp_axis(mesh)]
    return rest[0] if rest else None


class _Prop:
    """Mutable propagation state: per-buffer dim assignments + events."""

    def __init__(self, graph, mesh: MeshSpec):
        self.graph = graph
        self.mesh = mesh
        self.dims: dict[str, list] = {}      # buffer -> [axis|None]*ndim
        self.events: list[dict] = []         # raw collective events

    def spec(self, name: str) -> list:
        if name not in self.dims:
            self.dims[name] = [None] * len(self.graph.buffers[name].shape)
        return self.dims[name]

    def divides(self, name: str, d: int, axis: str) -> bool:
        size = self.graph.buffers[name].shape[d]
        n = self.mesh.axis_size(axis)
        return n > 0 and size % n == 0 and size // n >= 1

    def gather(self, name: str, task: str, dims: Iterable[int] | None = None):
        """Replicate ``name`` (fully, or along ``dims``) before ``task``."""
        spec = self.spec(name)
        targets = range(len(spec)) if dims is None else dims
        for d in targets:
            if spec[d] is not None:
                self.events.append({"kind": "all_gather", "buffer": name,
                                    "axis": spec[d], "task": task,
                                    "where": "before", "dim": d})
                spec[d] = None

    def psum(self, name: str, axis: str, task: str):
        self.events.append({"kind": "psum", "buffer": name, "axis": axis,
                            "task": task, "where": "after", "dim": 0})


def _visit_matmul(st: _Prop, task, tp_axis: str | None):
    a, b = task.spec.ins
    out = task.spec.outs[0]
    sa = st.spec(a)

    # Lazy tensor-parallel weight placement (2-D weights only).
    bbuf = st.graph.buffers[b]
    if (tp_axis is not None and bbuf.kind == "weight"
            and b not in st.dims and len(bbuf.shape) == 2):
        sb = st.spec(b)
        if sa[-1] == tp_axis and st.divides(b, 0, tp_axis):
            sb[0] = tp_axis                        # row-parallel
        elif sa[-1] is None and st.divides(b, 1, tp_axis):
            sb[1] = tp_axis                        # column-parallel
    sb = st.spec(b)

    # Batched matmul: leading batch dims must agree shard-for-shard.
    nbatch = len(sa) - 2
    for d in range(nbatch):
        if sa[d] != sb[d]:
            st.gather(a, task.name, [d])
            st.gather(b, task.name, [d])

    # Contraction dims: both sharded the same way -> partial sums (psum
    # after the task); any mismatch -> gather the offending operand.
    ca, cb = sa[-1], sb[-2]
    partial = None
    if ca is not None and ca == cb:
        partial = ca
    else:
        if ca is not None:
            st.gather(a, task.name, [len(sa) - 1])
        if cb is not None:
            st.gather(b, task.name, [len(sb) - 2])

    # Output dims: a's rows, b's cols.  The same mesh axis cannot shard
    # two output dims — gather b's column sharding on conflict.
    om, on = sa[-2], sb[-1]
    if om is not None and om == on:
        st.gather(b, task.name, [len(sb) - 1])
        on = None
    batch = [sa[d] for d in range(nbatch)]
    for d, ax in enumerate(batch):
        if ax is not None and ax in (om, on):
            st.gather(a, task.name, [d])
            st.gather(b, task.name, [d])
            batch[d] = None
    st.dims[out] = batch + [om, on]
    if partial is not None:
        st.psum(out, partial, task.name)


def _visit(st: _Prop, task, tp_axis: str | None):
    spec = task.spec
    kind = spec.kind
    if kind == "matmul":
        _visit_matmul(st, task, tp_axis)
    elif kind in _EWISE_UNARY:
        st.dims[spec.outs[0]] = list(st.spec(spec.ins[0]))
    elif kind == "dup":
        # reuse-pass fanout: every copy inherits the source placement
        for o in spec.outs:
            st.dims[o] = list(st.spec(spec.ins[0]))
    elif kind in _EWISE_BINARY:
        a, b = spec.ins[0], spec.ins[1]
        sa, sb = st.spec(a), st.spec(b)
        ashape = st.graph.buffers[a].shape
        bshape = st.graph.buffers[b].shape
        if tuple(ashape) != tuple(bshape) or len(sa) != len(sb):
            st.gather(a, task.name)
            st.gather(b, task.name)
        else:
            for d in range(len(sa)):
                if sa[d] != sb[d]:
                    st.gather(a, task.name, [d])
                    st.gather(b, task.name, [d])
        st.dims[spec.outs[0]] = list(st.spec(a))
    elif kind == "transpose":
        x = spec.ins[0]
        sx = st.spec(x)
        perm = spec.attrs.get("perm")
        perm = tuple(int(p) for p in perm) if perm is not None \
            else tuple(reversed(range(len(sx))))
        st.dims[spec.outs[0]] = [sx[p] for p in perm]
    elif kind == "softmax":
        x = spec.ins[0]
        sx = st.spec(x)
        axis = int(spec.attrs.get("axis", -1)) % len(sx)
        if sx[axis] is not None:
            st.gather(x, task.name, [axis])
        st.dims[spec.outs[0]] = list(st.spec(x))
    elif kind in ("zeros", "const", "fill_interior"):
        for o in spec.outs:
            st.dims[o] = [None] * len(st.graph.buffers[o].shape)
    else:
        # Conservative fallback (conv2d, pool, reshape, concat, split,
        # slice, mean, mv, scans, ...): gather every sharded operand and
        # compute replicated.  Correct for any op; the cost model decides
        # whether the strategy is still worth it.
        for i in spec.ins:
            st.gather(i, task.name)
        for o in spec.outs:
            st.dims[o] = [None] * len(st.graph.buffers[o].shape)


def propagate(graph, mesh: MeshSpec, strategy: str):
    """Run the placement rules; return (specs, raw collective events)."""
    st = _Prop(graph, mesh)
    dp = strategy in ("dp", "dp_tp")
    tp_axis = _tp_axis(mesh) if strategy in ("tp", "dp_tp") else None
    if strategy in ("tp", "dp_tp") and tp_axis is None:
        raise PartitionError(
            f"strategy {strategy!r} needs a tensor axis; mesh has only "
            f"{mesh.names}")
    if dp:
        ax = _dp_axis(mesh)
        for buf in graph.inputs():
            if len(buf.shape) >= 1 and st.divides(buf.name, 0, ax):
                st.spec(buf.name)[0] = ax
    for task in graph.toposort():
        if task.spec is None:
            raise PartitionError(f"task {task.name} has no op spec")
        _visit(st, task, tp_axis)
    specs = {name: ShardSpec(tuple(st.spec(name)))
             for name in graph.buffers}
    return specs, st.events


def _candidates(mesh: MeshSpec) -> list[str]:
    cands = ["replicate", "dp"]
    if _tp_axis(mesh) is not None:
        cands += ["tp", "dp_tp"]
    return cands


def partition(compiled, mesh, strategy: str = "auto",
              hw: HwParams = V5E) -> ShardingPlan:
    """Partition a compiled design (or bare graph) across ``mesh``.

    ``compiled`` is a ``CompiledDataflow`` (its buffer/transfer plans size
    the collective buffers) or a ``DataflowGraph``.  ``mesh`` is a jax
    ``Mesh`` or a :class:`MeshSpec`.  ``strategy="auto"`` prices every
    feasible candidate with :func:`estimate_sharding` and keeps the
    cheapest; the explicit names force one.
    """
    spec = MeshSpec.of(mesh)
    graph = getattr(compiled, "graph", compiled)
    buffer_plan = getattr(compiled, "buffer_plan", None)
    transfer_plan = getattr(compiled, "transfer_plan", None)
    if strategy not in STRATEGIES:
        raise PartitionError(
            f"unknown strategy {strategy!r}; pick one of {STRATEGIES}")

    def build(name: str) -> ShardingPlan:
        specs, events = propagate(graph, spec, name)
        steps = _coll.build_steps(graph, spec, events,
                                  buffer_plan=buffer_plan,
                                  transfer_plan=transfer_plan)
        plan = ShardingPlan(mesh=spec, strategy=name, specs=specs,
                            steps=steps)
        est = estimate_sharding(graph, plan, hw)
        return ShardingPlan(mesh=spec, strategy=name, specs=specs,
                            steps=steps, estimated_cycles=est.total_cycles)

    if strategy != "auto":
        return build(strategy)
    plans = [build(name) for name in _candidates(spec)]
    return min(plans, key=lambda p: p.estimated_cycles)
