"""Int8 gradient compression with error feedback (distributed-optimization
trick, DESIGN.md §5).

Per-tensor symmetric quantization: q = round(g / s · 127), s = max|g|.
The quantization residual is carried in the optimizer state ("ef" buffers)
and added back before the next quantization — the standard error-feedback
correction that keeps compressed SGD/Adam convergent.

The all-reduce itself runs on int32-accumulated int8 payloads (4× [bf16] /
2× [f32→int8+scale] wire reduction).  Inside pjit the psum is expressed
with ``jax.lax.psum`` when running under shard_map; under plain pjit the
quantize/dequantize pair still shrinks any GSPMD-inserted all-reduce to
the int8 payload.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, ef: Any | None = None):
    """Quantize every leaf (+error feedback).  Returns (q_tree, scale_tree,
    new_ef_tree)."""
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)
    qs = jax.tree.map(quantize, corrected,
                      is_leaf=lambda x: isinstance(x, jax.Array))
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda c, qq, ss: c - dequantize(qq, ss),
                          corrected, q, s)
    return q, s, new_ef


def compressed_allreduce(grads: Any, opt_state: dict,
                         axes: tuple[str, ...]) -> tuple[Any, dict]:
    """shard_map-visible compressed gradient all-reduce with error feedback
    kept in ``opt_state['ef']``.

    All ranks quantize against a *shared* scale (pmax of local abs-max):
    the int32-accumulated payload then dequantizes exactly as
    scale · Σ q_r.  Wire cost: 1 byte/grad + one scalar pmax per tensor,
    vs 2-4 bytes/grad uncompressed.
    """
    ef = opt_state.get("ef")
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, ef)

    def leaf_reduce(c):
        amax = jax.lax.pmax(jnp.max(jnp.abs(c)), axes)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axes)
        return summed.astype(jnp.float32) * scale, c - q.astype(jnp.float32) * scale

    pairs = jax.tree.map(leaf_reduce, corrected)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = dict(opt_state)
    new_state["ef"] = new_ef
    return out, new_state


def wire_bytes(tree: Any, compressed: bool) -> int:
    leaves = jax.tree.leaves(tree)
    if compressed:
        return sum(x.size * 1 + 4 for x in leaves)
    return sum(x.size * x.dtype.itemsize for x in leaves)
