"""repro.api — the ``codo`` frontend: one callable from function to design.

This is the primary public API of the reproduction (``import codo`` works
too, via the ``src/codo.py`` alias):

.. code-block:: python

    import codo

    def model(x):
        h = codo.F.fc(x, 512, relu=True)
        return codo.F.fc(h, 512) + x

    program = codo.compile(model, (64, 512))   # trace -> codo_opt
    y = program(x_array)                       # lower + execute
    program.export("design.json")              # portable artifact
    program.diagnostics.table()                # per-pass timings
    program.cost.total_cycles                  # modeled latency

``compile`` traces the function (:mod:`repro.core.frontend`), runs the
six-pass ``codo_opt`` pipeline, and wraps the result in a
:class:`CompiledProgram`.  Traced graphs are structurally identical to
hand-built ones, so they share the same content-addressed compile cache —
compiling a function whose graph was already compiled (by anyone, through
any road) is a cache hit.

Calling convention: positional arrays bind to the traced function's
parameters in order; keyword arrays override any buffer (inputs *or*
weights) by name.  Weight buffers created inside ops default to the same
deterministic shape-keyed initializer eager mode uses
(:func:`repro.core.frontend.weight_init`), so ``codo.compile(fn)(x)``
equals ``fn(x)`` exactly; bind real parameters with
:meth:`CompiledProgram.bind`.

The low-level road — build a :class:`~repro.core.graph.DataflowGraph` by
hand (``GB``) and call :func:`~repro.core.compiler.codo_opt` — remains
fully supported; ``compile`` accepts a ready graph too.

Smoke CLI (used by the CI compile-smoke job)::

    PYTHONPATH=src python -m repro.api gemm --cache-dir .codo_cache --run
"""

from __future__ import annotations

import argparse
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import frontend
from repro.core.compiler import (CodoOptions, CompiledDataflow, _UNSET,
                                 codo_opt)
from repro.core.graph import DataflowGraph

# Re-exports: `codo.trace`, `codo.buffer`, `codo.ShapedBuffer`, and the op
# namespace as `codo.F` (also importable as `from repro.core import
# frontend as F`).
F = frontend
ShapedBuffer = frontend.ShapedBuffer
buffer = frontend.buffer
trace = frontend.trace
TraceError = frontend.TraceError


class CompiledProgram:
    """A compiled dataflow design with a function calling convention.

    Wraps the :class:`~repro.core.compiler.CompiledDataflow` the pipeline
    produced plus the trace's io contract (which argument is which input
    buffer, which buffer comes back).  Lowering to an executable jax
    program happens lazily on first call and is memoized by the lowering
    cache, keyed on the design's structural hash.
    """

    def __init__(self, source: DataflowGraph, compiled: CompiledDataflow,
                 input_names: Sequence[str], output_names: Sequence[str]):
        self.source = source                  # pre-pass graph (the oracle)
        self.compiled = compiled
        self.input_names = list(input_names)
        self.output_names = list(output_names)
        self.origin: str | None = None        # trace provenance (v1.5)
        self._bindings: dict[str, Any] = {}
        self._lowered = None
        self._lowered_key = None
        self._sharding = None
        self._provenance: dict | None = None

    # ---- introspection ---------------------------------------------------
    @property
    def graph(self) -> DataflowGraph:
        """The optimized (post-pass) graph."""
        return self.compiled.graph

    @property
    def diagnostics(self):
        """Per-pass :class:`~repro.core.passes.CompileDiagnostics`."""
        return self.compiled.diagnostics

    @property
    def cost(self):
        """Modeled :class:`~repro.core.costmodel.GraphCost` of the design."""
        return self.compiled.final

    @property
    def speedup(self) -> float:
        return self.compiled.speedup

    @property
    def fifo_fraction(self) -> float:
        return self.compiled.fifo_fraction

    @property
    def compile_seconds(self) -> float:
        return self.compiled.compile_seconds

    @property
    def schedule_report(self):
        return self.compiled.schedule_report

    @property
    def cache_hit(self) -> bool:
        return self.compiled.cache_hit

    def report(self) -> str:
        return self.compiled.report()

    def __repr__(self) -> str:
        ins = ", ".join(self.input_names)
        outs = ", ".join(self.output_names)
        return (f"CompiledProgram({self.graph.name}: ({ins}) -> ({outs}), "
                f"speedup {self.speedup:.1f}x, "
                f"{'cache hit' if self.cache_hit else 'compiled'})")

    # ---- parameters ------------------------------------------------------
    def bind(self, **arrays) -> "CompiledProgram":
        """Attach concrete values for weight (or input) buffers by name.
        Unbound weights fall back to the deterministic shape-keyed
        initializer shared with eager mode."""
        for name, value in arrays.items():
            buf = self.graph.buffers.get(name)
            if buf is None or buf.kind not in ("weight", "input"):
                known = sorted(b.name for b in self.graph.buffers.values()
                               if b.kind in ("weight", "input"))
                raise KeyError(f"no bindable buffer {name!r}; "
                               f"inputs/weights: {known}")
            self._check(buf, value)
            self._bindings[name] = value
        return self

    @staticmethod
    def _check(buf, value) -> None:
        shape = tuple(getattr(value, "shape", ()))
        if shape != tuple(buf.shape):
            raise ValueError(f"buffer {buf.name!r} expects shape "
                             f"{tuple(buf.shape)}, got {shape}")

    # ---- sharding --------------------------------------------------------
    @property
    def sharding(self):
        """The :class:`~repro.distributed.plan.ShardingPlan`, or None for
        a single-device program."""
        return self._sharding

    def shard(self, mesh, strategy: str = "auto") -> "CompiledProgram":
        """Partition this design across ``mesh`` (a jax ``Mesh`` or a
        pure-data :class:`~repro.distributed.plan.MeshSpec`).  The plan
        enters the lowering memo key, travels in the v1.4 artifact, and
        subsequent calls execute via ``shard_map`` with the plan's
        collective schedule.  ``shard(None)`` reverts to single-device."""
        if mesh is None:
            self._sharding = None
        else:
            from repro.distributed.partition import partition
            self._sharding = partition(self.compiled, mesh, strategy)
        self._lowered = None
        return self

    # ---- execution -------------------------------------------------------
    def lower(self, jit: bool = True):
        """The lowered executable program (memoized per jit flag and
        sharding-plan digest)."""
        plan = self._sharding
        key = (bool(jit), plan.digest() if plan is not None else "")
        if self._lowered is None or self._lowered_key != key:
            from repro.core.lowering import lower  # lazy: jax
            self._lowered = lower(self.compiled, jit=jit, sharding=plan)
            self._lowered_key = key
        return self._lowered

    def make_env(self, *arrays, **named) -> dict[str, Any]:
        """The full execution environment for one call: positional arrays
        mapped onto the traced inputs, keyword overrides, bound weights,
        and shape-keyed defaults for the rest."""
        if len(arrays) > len(self.input_names):
            raise TypeError(f"{self.graph.name} takes {len(self.input_names)} "
                            f"positional inputs {self.input_names}, "
                            f"got {len(arrays)}")
        env = dict(self._bindings)
        for name, value in zip(self.input_names, arrays):
            self._check(self.graph.buffers[name], value)
            env[name] = value
        for name, value in named.items():
            buf = self.graph.buffers.get(name)
            if buf is None or buf.kind not in ("input", "weight"):
                known = sorted(b.name for b in self.graph.buffers.values()
                               if b.kind in ("input", "weight"))
                raise KeyError(f"no bindable buffer {name!r} (intermediates "
                               f"are produced by the design and cannot be "
                               f"overridden); inputs/weights: {known}")
            self._check(buf, value)
            env[name] = value
        missing = [n for n in self.input_names if n not in env]
        if missing:
            raise TypeError(f"missing inputs {missing} "
                            f"(signature: {self.input_names})")
        for b in self.graph.weights():
            if b.name not in env:
                env[b.name] = frontend.weight_init(b.shape, b.dtype)
        return env

    def __call__(self, *arrays, jit: bool = True, **named):
        """Run the compiled design.  Returns one array per traced output
        (a bare array for single-output programs, a tuple otherwise)."""
        out = self.lower(jit=jit)(self.make_env(*arrays, **named))
        vals = tuple(out[n] for n in self.output_names)
        return vals[0] if len(vals) == 1 else vals

    def verify(self, *arrays, rtol: float | None = None,
               atol: float | None = None, **named):
        """Check the lowered design against the un-optimized oracle (the
        source graph executed task by task) on these inputs.  A sharded
        program is verified through its multi-device lowering; the default
        tolerance widens to the documented fp-reassociation band (psum
        tree-reduces device partials, and local-shape matmuls may contract
        in a different order) — see ``lowering.verify_sharding``."""
        sharded = self._sharding is not None
        rtol = (1e-4 if sharded else 1e-5) if rtol is None else rtol
        atol = (5e-5 if sharded else 1e-5) if atol is None else atol
        env = self.make_env(*arrays, **named)
        got = self.lower(jit=False)(env)
        want = self.source.execute(env)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]), rtol=rtol, atol=atol,
                err_msg=f"output {k} diverged after lowering")

    # ---- autotuning ------------------------------------------------------
    def autotune(self, *, repeats: int = 5, warmup: int = 2, seed: int = 0,
                 save_path: str | None = None) -> list:
        """Measure routed-vs-generic for every pattern-matched chain of
        this design (sweeping each kernel's tile candidates) and persist
        the winners in the process tuning database — subsequent
        :meth:`lower`/calls route on measurement instead of prediction
        (the tuning-DB digest is in the lowering memo key, so the switch
        is automatic).  Returns the new
        :class:`~repro.core.tuning.TuningRecord`\\ s."""
        from repro.core.tuning import autotune_compiled  # lazy: jax
        records = autotune_compiled(self.compiled, repeats=repeats,
                                    warmup=warmup, seed=seed,
                                    save_path=save_path)
        self._lowered = None            # re-route against the measurements
        return records

    # ---- artifacts -------------------------------------------------------
    def export(self, path: str | None = None, *,
               weights: "bool | dict | None" = None, sidecar: bool = False):
        """Write (or return) the versioned JSON artifact of this design
        (docs/artifact_format.md).  Tuning-database entries matching the
        design's chains travel in the v1.2 ``tuning`` section.

        ``weights=True`` embeds every weight buffer's concrete array —
        bound values first, the deterministic initializer for the rest —
        so the artifact is a *self-contained served model* (v1.3
        ``weights`` section; ``codo.load`` binds them back, no
        ``weight_init`` needed at the serving end).  Pass a dict to ship
        specific arrays, and ``sidecar=True`` to write them to
        ``<path>.weights.npz`` instead of base64-in-JSON.

        A sharded program additionally writes its :class:`ShardingPlan`
        into the v1.4 ``sharding`` section, so ``codo.load`` reproduces
        the multi-device program on any host with enough devices.

        v1.5 artifacts also carry a ``provenance`` section — the
        *pre-pass* source graph's structural hash plus the trace origin —
        so ``artifact diff`` can tell "same source, different pipeline"
        from "different source"."""
        from repro.core.artifact import export_artifact  # lazy
        if weights is True:
            weights = {b.name: (self._bindings.get(b.name)
                                if b.name in self._bindings
                                else frontend.weight_init(b.shape, b.dtype))
                       for b in self.graph.weights()}
        return export_artifact(self.compiled, path, weights=weights,
                               weights_sidecar=sidecar,
                               sharding=self._sharding,
                               provenance=self.provenance())

    def provenance(self) -> dict:
        """The v1.5 ``provenance`` section: pre-pass source structural hash
        plus trace origin.  Loaded programs return the section stored in
        their artifact (the post-pass graph is not the source)."""
        if self._provenance is not None:
            return dict(self._provenance)
        return {"source_structural_hash": self.source.structural_hash(),
                "origin": self.origin or f"graph:{self.source.name}"}

    # ---- autodiff --------------------------------------------------------
    def value_and_grad(self, *, opt=None, wrt=None) -> "CompiledTrainStep":
        """Differentiate this program's source graph and compile the
        forward/backward/update triple through the same pass pipeline —
        the method form of ``codo.compile(fn, ..., grad=True)``."""
        step = _compile_train_step(self.source, options=self.compiled.options,
                                   opt=opt, wrt=wrt, origin=self.origin)
        return step


def _io_from_graph(graph: DataflowGraph) -> tuple[list[str], list[str]]:
    return ([b.name for b in graph.inputs()],
            [b.name for b in graph.outputs()])


class CompiledTrainStep:
    """A training step compiled end-to-end through the pass pipeline.

    Three linked :class:`CompiledProgram`\\ s — ``forward`` (loss +
    residuals), ``backward`` (cotangent walk, built by
    :mod:`repro.core.autodiff`), ``update`` (AdamW + global-norm clip +
    warmup-cosine schedule as registry ops) — each individually traced
    through fusion, fine-violation elimination, cost-gated kernel routing
    and the compile cache.  The backward graph's matmul→gradient-epilogue
    chains route to the ``streamfuse.mmgrad`` kernel when the cost gate
    approves (or under ``CODO_FORCE_PALLAS=1``).

    Numerical contract (the documented fp band, see docs/autodiff.md):
    against eager ``jax.grad`` + ``training.optimizer.adamw_update`` the
    compiled loss is bit-exact, gradients match within
    ``rtol=2e-3, atol=1e-4`` (fp32 reassociation across contractions),
    and the update math *given identical gradients* is bit-tight
    (observed ≤3e-8).
    """

    def __init__(self, source: DataflowGraph, graphs,
                 forward: CompiledProgram, backward: CompiledProgram,
                 update: CompiledProgram):
        self.source = source            # pre-pass loss graph (the oracle)
        self.graphs = graphs            # core.autodiff.TrainGraphs
        self.forward = forward
        self.backward = backward
        self.update = update
        self.input_names = [b.name for b in source.inputs()]
        self.param_names = list(graphs.params)
        self.origin: str | None = None
        self._provenance: dict | None = None
        self._initial_params: dict | None = None   # artifact-carried weights

    # ---- state -----------------------------------------------------------
    def init_params(self) -> dict:
        """Deterministic initial parameters (shape-keyed ``weight_init``),
        or the weight payload carried by the artifact this step was loaded
        from."""
        if self._initial_params is not None:
            return dict(self._initial_params)
        return {b.name: frontend.weight_init(b.shape, b.dtype)
                for b in self.source.weights()
                if b.name in set(self.param_names)}

    def init_opt_state(self, params: dict | None = None) -> dict:
        """Fresh AdamW state in ``training.optimizer`` checkpoint format:
        ``{"m": {...}, "v": {...}, "step": int32 scalar}``."""
        params = params if params is not None else self.init_params()
        return {"m": {w: np.zeros(np.shape(params[w]), np.float32)
                      for w in self.param_names},
                "v": {w: np.zeros(np.shape(params[w]), np.float32)
                      for w in self.param_names},
                "step": np.zeros((), np.int32)}

    # ---- execution -------------------------------------------------------
    def value_and_grad(self, *arrays, params: dict | None = None,
                       jit: bool = True, **named):
        """Run the compiled forward + backward graphs; returns
        ``(loss, grads)`` with ``grads`` keyed by parameter name."""
        g = self.graphs
        params = dict(params) if params is not None else self.init_params()
        fenv = self.forward.make_env(*arrays, **params, **named)
        fouts = self.forward.lower(jit=jit)(fenv)
        benv = {g.seeds[g.loss]: np.ones((1, 1), np.float32)}
        for r in g.residuals:
            benv[r] = fouts[r] if r in fouts else fenv[r]
        bouts = self.backward.lower(jit=jit)(benv)
        grads = {w: bouts[g.grads[w]] for w in self.param_names}
        return fouts[g.loss], grads

    def step(self, params: dict, opt_state: dict, *arrays,
             jit: bool = True, **named):
        """One full training step: forward, backward, AdamW update.
        Returns ``(new_params, new_opt_state, metrics)`` where metrics
        carries scalar ``loss``, ``grad_norm`` and ``lr``."""
        loss, grads = self.value_and_grad(*arrays, params=params, jit=jit,
                                          **named)
        uenv = {"step": np.asarray(opt_state["step"],
                                   np.float32).reshape(1, 1)}
        for w in self.param_names:
            uenv[w] = params[w]
            uenv[f"grad_{w}"] = grads[w]
            uenv[f"m_{w}"] = opt_state["m"][w]
            uenv[f"v_{w}"] = opt_state["v"][w]
        uouts = self.update.lower(jit=jit)(uenv)
        new_params = {w: uouts[f"new_{w}"] for w in self.param_names}
        new_state = {"m": {w: uouts[f"new_m_{w}"] for w in self.param_names},
                     "v": {w: uouts[f"new_v_{w}"] for w in self.param_names},
                     "step": np.asarray(uouts["new_step"],
                                        np.float32).reshape(()).astype(np.int32)}
        metrics = {"loss": np.asarray(loss).reshape(()),
                   "grad_norm": np.asarray(uouts["grad_norm"]).reshape(()),
                   "lr": np.asarray(uouts["lr"]).reshape(())}
        return new_params, new_state, metrics

    def verify(self, *arrays, params: dict | None = None,
               rtol: float = 2e-3, atol: float = 1e-4, **named):
        """Check compiled loss + gradients against eager ``jax.grad`` of
        the source graph on these inputs, within the documented fp band."""
        import jax  # lazy
        g = self.graphs
        params = dict(params) if params is not None else self.init_params()
        loss, grads = self.value_and_grad(*arrays, params=params, **named)
        base = dict(zip(self.input_names, arrays))
        base.update(named)

        def loss_fn(ps):
            return self.source.execute({**base, **ps})[g.loss].reshape(())

        ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params)
        np.testing.assert_allclose(
            np.asarray(loss).reshape(()), np.asarray(ref_loss),
            rtol=rtol, atol=atol, err_msg="loss diverged from eager jax.grad")
        for w in self.param_names:
            np.testing.assert_allclose(
                np.asarray(grads[w]), np.asarray(ref_grads[w]),
                rtol=rtol, atol=atol,
                err_msg=f"grad {w} diverged from eager jax.grad")

    # ---- tooling ---------------------------------------------------------
    def autotune(self, **kw) -> list:
        """Autotune all three phases' routed chains (see
        :meth:`CompiledProgram.autotune`)."""
        records = []
        for p in (self.forward, self.backward, self.update):
            records += p.autotune(**kw)
        return records

    def provenance(self) -> dict:
        if self._provenance is not None:
            return dict(self._provenance)
        return {"source_structural_hash": self.source.structural_hash(),
                "origin": self.origin or f"graph:{self.source.name}"}

    def export(self, path: str | None = None, *,
               weights: "bool | dict | None" = None):
        """Write (or return) the v1.5 *train-step* artifact: one JSON doc
        with ``kind: "train_step"``, a full per-phase artifact under
        ``phases.{forward,backward,update}``, and the linking ``train``
        section (loss/seed/residual/grad names + optimizer attrs) so
        ``codo.load`` reconstructs the executable step in a fresh
        interpreter.  ``weights=True`` embeds the parameters in the
        forward phase (v1.3 semantics)."""
        from repro.core.artifact import export_train_step_artifact  # lazy
        g = self.graphs
        if weights is True:
            weights = self.init_params()
        train = {"loss": g.loss, "seeds": dict(g.seeds),
                 "residuals": list(g.residuals), "grads": dict(g.grads),
                 "params": list(g.params), "opt": dict(g.opt)}
        return export_train_step_artifact(
            {"forward": self.forward.compiled,
             "backward": self.backward.compiled,
             "update": self.update.compiled},
            train, path, weights=weights, provenance=self.provenance())

    def report(self) -> str:
        lines = [f"train step {self.source.name}: "
                 f"{len(self.param_names)} params, "
                 f"{len(self.graphs.residuals)} residuals"]
        for tag, p in (("forward", self.forward), ("backward", self.backward),
                       ("update", self.update)):
            lines.append(f"-- {tag} " + "-" * max(1, 60 - len(tag)))
            lines.append(p.report())
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"<CompiledTrainStep {self.source.name} "
                f"params={len(self.param_names)} "
                f"fwd={len(self.forward.compiled.graph.tasks)}t "
                f"bwd={len(self.backward.compiled.graph.tasks)}t "
                f"upd={len(self.update.compiled.graph.tasks)}t>")


def _compile_train_step(source: DataflowGraph, *, options=None, cache=_UNSET,
                        opt=None, wrt=None, origin: str | None = None,
                        **codo_kwargs) -> CompiledTrainStep:
    from repro.core.autodiff import build_train_graphs  # lazy: jax via ops
    graphs = build_train_graphs(source, oc=opt, wrt=wrt)
    progs = []
    for phase in (graphs.forward, graphs.backward, graphs.update):
        compiled = codo_opt(phase, options, cache=cache, **codo_kwargs)
        progs.append(CompiledProgram(phase, compiled, *_io_from_graph(phase)))
    step = CompiledTrainStep(source, graphs, *progs)
    step.origin = origin or f"graph:{source.name}"
    return step


def compile(fn: Callable | DataflowGraph, *specs,  # noqa: A001 — the API name
            options: CodoOptions | None = None, name: str | None = None,
            cache=_UNSET, autotune: bool = False, mesh=None,
            sharding_strategy: str = "auto", grad: bool = False,
            opt=None, wrt=None,
            **codo_kwargs) -> CompiledProgram:
    """Trace ``fn`` over ``specs`` (shape tuples / :func:`buffer` protos)
    and compile it through the ``codo_opt`` pipeline.

    ``fn`` may also be a ready :class:`DataflowGraph` (then ``specs`` must
    be empty) — the escape hatch for hand-built graphs.  ``options``
    defaults to the full opt5 pipeline; ``cache=None`` disables
    memoization for this call.  ``autotune=True`` additionally measures
    routed-vs-generic for every pattern-matched chain right after the
    compile (see :meth:`CompiledProgram.autotune`) so the program routes
    on measurement instead of the cost model's prediction.  Extra keyword
    arguments are forwarded to :func:`~repro.core.compiler.codo_opt`.

    ``mesh`` (a jax ``Mesh`` or a
    :class:`~repro.distributed.plan.MeshSpec`) makes the result a
    *multi-device* program: the partitioner runs after the single-device
    pipeline (so the compile cache stays shared across meshes) and
    ``sharding_strategy`` picks the placement — ``"auto"`` prices every
    feasible candidate, or force one of ``replicate``/``dp``/``tp``/
    ``dp_tp``.  See docs/sharding.md.

    ``grad=True`` differentiates the (scalar-loss) graph instead: the
    reverse toposort walk in :mod:`repro.core.autodiff` emits the
    backward as a second dataflow graph, an AdamW update graph rides
    along (``opt`` — an ``OptConfig``, a dict of its fields, or ``None``
    for defaults; ``wrt`` restricts the parameter set), and all three
    compile through this same pipeline.  Returns a
    :class:`CompiledTrainStep`.  See docs/autodiff.md.
    """
    if isinstance(fn, DataflowGraph):
        if specs:
            raise TraceError("compile(graph) takes no input specs — the "
                             "graph already declares its buffers")
        source, ins, outs = fn, *_io_from_graph(fn)
        if name is not None and name != source.name:
            raise TraceError(f"compile(graph, name={name!r}) cannot rename "
                             f"graph {source.name!r}")
        origin = f"graph:{source.name}"
    else:
        source, ins, outs = frontend.trace_io(fn, *specs, name=name)
        origin = (f"traced:{getattr(fn, '__module__', '?')}."
                  f"{getattr(fn, '__qualname__', source.name)}")
    if grad:
        if mesh is not None:
            raise TraceError("grad=True does not compose with mesh= yet — "
                             "shard the phases individually")
        step = _compile_train_step(source, options=options, cache=cache,
                                   opt=opt, wrt=wrt, origin=origin,
                                   **codo_kwargs)
        if autotune:
            step.autotune()
        return step
    if opt is not None or wrt is not None:
        raise TraceError("opt=/wrt= only apply with grad=True")
    compiled = codo_opt(source, options, cache=cache, **codo_kwargs)
    program = CompiledProgram(source, compiled, ins, outs)
    program.origin = origin
    if mesh is not None:
        program.shard(mesh, sharding_strategy)
    if autotune:
        program.autotune()
    return program


def load(path) -> CompiledProgram:
    """Reconstruct a :class:`CompiledProgram` from an exported artifact
    (path or parsed document) — no recompile, any process; op kinds
    resolve against this process's registry.  Bound-weight payloads (v1.3)
    are hash-verified and re-bound, so a weight-carrying artifact executes
    without ever reaching the shape-keyed initializer.

    A v1.5 *train-step* artifact (``kind: "train_step"``) reconstructs a
    :class:`CompiledTrainStep` instead — all three phase graphs plus the
    linking ``train`` section."""
    from repro.core.artifact import (TRAIN_STEP_KIND, artifact_weights,
                                     import_artifact, load_artifact)  # lazy
    doc = load_artifact(path)
    if doc.get("kind") == TRAIN_STEP_KIND:
        return _load_train_step(doc)
    path = doc
    compiled = import_artifact(path)
    # The artifact carries the optimized graph only; it is its own oracle.
    ins, outs = _io_from_graph(compiled.graph)
    program = CompiledProgram(compiled.graph, compiled, ins, outs)
    # Keep the stored provenance (the post-pass graph's hash is NOT the
    # pre-pass source hash) so re-exports round-trip the v1.5 section.
    program._provenance = doc.get("provenance")
    plan = getattr(compiled, "sharding_plan", None)
    if plan is not None:
        # v1.4 sharding section: restore the multi-device program as-is
        # (the jax Mesh is only rebuilt from the plan's MeshSpec at
        # execution time, so loading needs no devices).
        program._sharding = plan
    bound = artifact_weights(path)
    if bound:
        program.bind(**bound)
    return program


def _load_train_step(doc: dict) -> CompiledTrainStep:
    from repro.core.artifact import import_train_step  # lazy
    from repro.core.autodiff import TrainGraphs  # lazy
    phases, train, weights = import_train_step(doc)
    graphs = TrainGraphs(
        forward=phases["forward"].graph, backward=phases["backward"].graph,
        update=phases["update"].graph, loss=train["loss"],
        seeds=dict(train["seeds"]), residuals=list(train["residuals"]),
        grads=dict(train["grads"]), params=list(train["params"]),
        opt=dict(train["opt"]))
    progs = [CompiledProgram(c.graph, c, *_io_from_graph(c.graph))
             for c in (phases["forward"], phases["backward"],
                       phases["update"])]
    step = CompiledTrainStep(phases["forward"].graph, graphs, *progs)
    step._provenance = doc.get("provenance")
    if weights:
        step._initial_params = weights
    return step


# --------------------------------------------------------------------------
# Smoke CLI:  python -m repro.api gemm --cache-dir .codo_cache --run
# The CI compile-smoke job greps `cache_hit=False` / `cache_hit=True` from
# a cold + warm invocation pair to pin frontend/cache-key stability.
# --------------------------------------------------------------------------


def main(argv=None) -> int:
    from repro.models.dataflow_models import KERNEL_BENCHES
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Compile one Table II kernel through codo.compile().")
    ap.add_argument("workload", choices=sorted(KERNEL_BENCHES),
                    help="traced kernel workload to compile")
    ap.add_argument("--opt", default="opt5",
                    help="CodoOptions preset (default opt5)")
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir (cold/warm smoke)")
    ap.add_argument("--run", action="store_true",
                    help="also execute the design on random inputs and "
                         "verify against the oracle (imports jax)")
    ap.add_argument("--export", default="", metavar="PATH",
                    help="export the design as a JSON artifact")
    args = ap.parse_args(argv)

    from repro.core.cache import CompileCache
    cache = (CompileCache(disk_dir=args.cache_dir) if args.cache_dir
             else _UNSET)
    graph = KERNEL_BENCHES[args.workload]()
    program = compile(graph, options=CodoOptions.preset(args.opt),
                      cache=cache)
    print(program.report())
    print(f"codo.compile({args.workload}): cache_hit={program.cache_hit} "
          f"speedup={program.speedup:.1f}x "
          f"key={program.graph.structural_hash()[:12]}")
    if args.run:
        from repro.models.dataflow_models import random_inputs
        env = random_inputs(program.source)
        program.verify(**env)
        print(f"numerics verified against the oracle on "
              f"{sorted(n for n in env)} ✓")
    if args.export:
        program.export(args.export)
        print(f"artifact exported to {args.export}")
    return 0


__all__ = ["CodoOptions", "CompiledProgram", "CompiledTrainStep", "F",
           "ShapedBuffer",
           "TraceError", "buffer", "compile", "load", "trace"]


if __name__ == "__main__":
    raise SystemExit(main())
