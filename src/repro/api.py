"""repro.api — the ``codo`` frontend: one callable from function to design.

This is the primary public API of the reproduction (``import codo`` works
too, via the ``src/codo.py`` alias):

.. code-block:: python

    import codo

    def model(x):
        h = codo.F.fc(x, 512, relu=True)
        return codo.F.fc(h, 512) + x

    program = codo.compile(model, (64, 512))   # trace -> codo_opt
    y = program(x_array)                       # lower + execute
    program.export("design.json")              # portable artifact
    program.diagnostics.table()                # per-pass timings
    program.cost.total_cycles                  # modeled latency

``compile`` traces the function (:mod:`repro.core.frontend`), runs the
six-pass ``codo_opt`` pipeline, and wraps the result in a
:class:`CompiledProgram`.  Traced graphs are structurally identical to
hand-built ones, so they share the same content-addressed compile cache —
compiling a function whose graph was already compiled (by anyone, through
any road) is a cache hit.

Calling convention: positional arrays bind to the traced function's
parameters in order; keyword arrays override any buffer (inputs *or*
weights) by name.  Weight buffers created inside ops default to the same
deterministic shape-keyed initializer eager mode uses
(:func:`repro.core.frontend.weight_init`), so ``codo.compile(fn)(x)``
equals ``fn(x)`` exactly; bind real parameters with
:meth:`CompiledProgram.bind`.

The low-level road — build a :class:`~repro.core.graph.DataflowGraph` by
hand (``GB``) and call :func:`~repro.core.compiler.codo_opt` — remains
fully supported; ``compile`` accepts a ready graph too.

Smoke CLI (used by the CI compile-smoke job)::

    PYTHONPATH=src python -m repro.api gemm --cache-dir .codo_cache --run
"""

from __future__ import annotations

import argparse
from typing import Any, Callable, Sequence

import numpy as np

from repro.core import frontend
from repro.core.compiler import (CodoOptions, CompiledDataflow, _UNSET,
                                 codo_opt)
from repro.core.graph import DataflowGraph

# Re-exports: `codo.trace`, `codo.buffer`, `codo.ShapedBuffer`, and the op
# namespace as `codo.F` (also importable as `from repro.core import
# frontend as F`).
F = frontend
ShapedBuffer = frontend.ShapedBuffer
buffer = frontend.buffer
trace = frontend.trace
TraceError = frontend.TraceError


class CompiledProgram:
    """A compiled dataflow design with a function calling convention.

    Wraps the :class:`~repro.core.compiler.CompiledDataflow` the pipeline
    produced plus the trace's io contract (which argument is which input
    buffer, which buffer comes back).  Lowering to an executable jax
    program happens lazily on first call and is memoized by the lowering
    cache, keyed on the design's structural hash.
    """

    def __init__(self, source: DataflowGraph, compiled: CompiledDataflow,
                 input_names: Sequence[str], output_names: Sequence[str]):
        self.source = source                  # pre-pass graph (the oracle)
        self.compiled = compiled
        self.input_names = list(input_names)
        self.output_names = list(output_names)
        self._bindings: dict[str, Any] = {}
        self._lowered = None
        self._lowered_key = None
        self._sharding = None

    # ---- introspection ---------------------------------------------------
    @property
    def graph(self) -> DataflowGraph:
        """The optimized (post-pass) graph."""
        return self.compiled.graph

    @property
    def diagnostics(self):
        """Per-pass :class:`~repro.core.passes.CompileDiagnostics`."""
        return self.compiled.diagnostics

    @property
    def cost(self):
        """Modeled :class:`~repro.core.costmodel.GraphCost` of the design."""
        return self.compiled.final

    @property
    def speedup(self) -> float:
        return self.compiled.speedup

    @property
    def fifo_fraction(self) -> float:
        return self.compiled.fifo_fraction

    @property
    def compile_seconds(self) -> float:
        return self.compiled.compile_seconds

    @property
    def schedule_report(self):
        return self.compiled.schedule_report

    @property
    def cache_hit(self) -> bool:
        return self.compiled.cache_hit

    def report(self) -> str:
        return self.compiled.report()

    def __repr__(self) -> str:
        ins = ", ".join(self.input_names)
        outs = ", ".join(self.output_names)
        return (f"CompiledProgram({self.graph.name}: ({ins}) -> ({outs}), "
                f"speedup {self.speedup:.1f}x, "
                f"{'cache hit' if self.cache_hit else 'compiled'})")

    # ---- parameters ------------------------------------------------------
    def bind(self, **arrays) -> "CompiledProgram":
        """Attach concrete values for weight (or input) buffers by name.
        Unbound weights fall back to the deterministic shape-keyed
        initializer shared with eager mode."""
        for name, value in arrays.items():
            buf = self.graph.buffers.get(name)
            if buf is None or buf.kind not in ("weight", "input"):
                known = sorted(b.name for b in self.graph.buffers.values()
                               if b.kind in ("weight", "input"))
                raise KeyError(f"no bindable buffer {name!r}; "
                               f"inputs/weights: {known}")
            self._check(buf, value)
            self._bindings[name] = value
        return self

    @staticmethod
    def _check(buf, value) -> None:
        shape = tuple(getattr(value, "shape", ()))
        if shape != tuple(buf.shape):
            raise ValueError(f"buffer {buf.name!r} expects shape "
                             f"{tuple(buf.shape)}, got {shape}")

    # ---- sharding --------------------------------------------------------
    @property
    def sharding(self):
        """The :class:`~repro.distributed.plan.ShardingPlan`, or None for
        a single-device program."""
        return self._sharding

    def shard(self, mesh, strategy: str = "auto") -> "CompiledProgram":
        """Partition this design across ``mesh`` (a jax ``Mesh`` or a
        pure-data :class:`~repro.distributed.plan.MeshSpec`).  The plan
        enters the lowering memo key, travels in the v1.4 artifact, and
        subsequent calls execute via ``shard_map`` with the plan's
        collective schedule.  ``shard(None)`` reverts to single-device."""
        if mesh is None:
            self._sharding = None
        else:
            from repro.distributed.partition import partition
            self._sharding = partition(self.compiled, mesh, strategy)
        self._lowered = None
        return self

    # ---- execution -------------------------------------------------------
    def lower(self, jit: bool = True):
        """The lowered executable program (memoized per jit flag and
        sharding-plan digest)."""
        plan = self._sharding
        key = (bool(jit), plan.digest() if plan is not None else "")
        if self._lowered is None or self._lowered_key != key:
            from repro.core.lowering import lower  # lazy: jax
            self._lowered = lower(self.compiled, jit=jit, sharding=plan)
            self._lowered_key = key
        return self._lowered

    def make_env(self, *arrays, **named) -> dict[str, Any]:
        """The full execution environment for one call: positional arrays
        mapped onto the traced inputs, keyword overrides, bound weights,
        and shape-keyed defaults for the rest."""
        if len(arrays) > len(self.input_names):
            raise TypeError(f"{self.graph.name} takes {len(self.input_names)} "
                            f"positional inputs {self.input_names}, "
                            f"got {len(arrays)}")
        env = dict(self._bindings)
        for name, value in zip(self.input_names, arrays):
            self._check(self.graph.buffers[name], value)
            env[name] = value
        for name, value in named.items():
            buf = self.graph.buffers.get(name)
            if buf is None or buf.kind not in ("input", "weight"):
                known = sorted(b.name for b in self.graph.buffers.values()
                               if b.kind in ("input", "weight"))
                raise KeyError(f"no bindable buffer {name!r} (intermediates "
                               f"are produced by the design and cannot be "
                               f"overridden); inputs/weights: {known}")
            self._check(buf, value)
            env[name] = value
        missing = [n for n in self.input_names if n not in env]
        if missing:
            raise TypeError(f"missing inputs {missing} "
                            f"(signature: {self.input_names})")
        for b in self.graph.weights():
            if b.name not in env:
                env[b.name] = frontend.weight_init(b.shape, b.dtype)
        return env

    def __call__(self, *arrays, jit: bool = True, **named):
        """Run the compiled design.  Returns one array per traced output
        (a bare array for single-output programs, a tuple otherwise)."""
        out = self.lower(jit=jit)(self.make_env(*arrays, **named))
        vals = tuple(out[n] for n in self.output_names)
        return vals[0] if len(vals) == 1 else vals

    def verify(self, *arrays, rtol: float | None = None,
               atol: float | None = None, **named):
        """Check the lowered design against the un-optimized oracle (the
        source graph executed task by task) on these inputs.  A sharded
        program is verified through its multi-device lowering; the default
        tolerance widens to the documented fp-reassociation band (psum
        tree-reduces device partials, and local-shape matmuls may contract
        in a different order) — see ``lowering.verify_sharding``."""
        sharded = self._sharding is not None
        rtol = (1e-4 if sharded else 1e-5) if rtol is None else rtol
        atol = (5e-5 if sharded else 1e-5) if atol is None else atol
        env = self.make_env(*arrays, **named)
        got = self.lower(jit=False)(env)
        want = self.source.execute(env)
        for k in want:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(want[k]), rtol=rtol, atol=atol,
                err_msg=f"output {k} diverged after lowering")

    # ---- autotuning ------------------------------------------------------
    def autotune(self, *, repeats: int = 5, warmup: int = 2, seed: int = 0,
                 save_path: str | None = None) -> list:
        """Measure routed-vs-generic for every pattern-matched chain of
        this design (sweeping each kernel's tile candidates) and persist
        the winners in the process tuning database — subsequent
        :meth:`lower`/calls route on measurement instead of prediction
        (the tuning-DB digest is in the lowering memo key, so the switch
        is automatic).  Returns the new
        :class:`~repro.core.tuning.TuningRecord`\\ s."""
        from repro.core.tuning import autotune_compiled  # lazy: jax
        records = autotune_compiled(self.compiled, repeats=repeats,
                                    warmup=warmup, seed=seed,
                                    save_path=save_path)
        self._lowered = None            # re-route against the measurements
        return records

    # ---- artifacts -------------------------------------------------------
    def export(self, path: str | None = None, *,
               weights: "bool | dict | None" = None, sidecar: bool = False):
        """Write (or return) the versioned JSON artifact of this design
        (docs/artifact_format.md).  Tuning-database entries matching the
        design's chains travel in the v1.2 ``tuning`` section.

        ``weights=True`` embeds every weight buffer's concrete array —
        bound values first, the deterministic initializer for the rest —
        so the artifact is a *self-contained served model* (v1.3
        ``weights`` section; ``codo.load`` binds them back, no
        ``weight_init`` needed at the serving end).  Pass a dict to ship
        specific arrays, and ``sidecar=True`` to write them to
        ``<path>.weights.npz`` instead of base64-in-JSON.

        A sharded program additionally writes its :class:`ShardingPlan`
        into the v1.4 ``sharding`` section, so ``codo.load`` reproduces
        the multi-device program on any host with enough devices."""
        from repro.core.artifact import export_artifact  # lazy
        if weights is True:
            weights = {b.name: (self._bindings.get(b.name)
                                if b.name in self._bindings
                                else frontend.weight_init(b.shape, b.dtype))
                       for b in self.graph.weights()}
        return export_artifact(self.compiled, path, weights=weights,
                               weights_sidecar=sidecar,
                               sharding=self._sharding)


def _io_from_graph(graph: DataflowGraph) -> tuple[list[str], list[str]]:
    return ([b.name for b in graph.inputs()],
            [b.name for b in graph.outputs()])


def compile(fn: Callable | DataflowGraph, *specs,  # noqa: A001 — the API name
            options: CodoOptions | None = None, name: str | None = None,
            cache=_UNSET, autotune: bool = False, mesh=None,
            sharding_strategy: str = "auto",
            **codo_kwargs) -> CompiledProgram:
    """Trace ``fn`` over ``specs`` (shape tuples / :func:`buffer` protos)
    and compile it through the ``codo_opt`` pipeline.

    ``fn`` may also be a ready :class:`DataflowGraph` (then ``specs`` must
    be empty) — the escape hatch for hand-built graphs.  ``options``
    defaults to the full opt5 pipeline; ``cache=None`` disables
    memoization for this call.  ``autotune=True`` additionally measures
    routed-vs-generic for every pattern-matched chain right after the
    compile (see :meth:`CompiledProgram.autotune`) so the program routes
    on measurement instead of the cost model's prediction.  Extra keyword
    arguments are forwarded to :func:`~repro.core.compiler.codo_opt`.

    ``mesh`` (a jax ``Mesh`` or a
    :class:`~repro.distributed.plan.MeshSpec`) makes the result a
    *multi-device* program: the partitioner runs after the single-device
    pipeline (so the compile cache stays shared across meshes) and
    ``sharding_strategy`` picks the placement — ``"auto"`` prices every
    feasible candidate, or force one of ``replicate``/``dp``/``tp``/
    ``dp_tp``.  See docs/sharding.md.
    """
    if isinstance(fn, DataflowGraph):
        if specs:
            raise TraceError("compile(graph) takes no input specs — the "
                             "graph already declares its buffers")
        source, ins, outs = fn, *_io_from_graph(fn)
        if name is not None and name != source.name:
            raise TraceError(f"compile(graph, name={name!r}) cannot rename "
                             f"graph {source.name!r}")
    else:
        source, ins, outs = frontend.trace_io(fn, *specs, name=name)
    compiled = codo_opt(source, options, cache=cache, **codo_kwargs)
    program = CompiledProgram(source, compiled, ins, outs)
    if mesh is not None:
        program.shard(mesh, sharding_strategy)
    if autotune:
        program.autotune()
    return program


def load(path) -> CompiledProgram:
    """Reconstruct a :class:`CompiledProgram` from an exported artifact
    (path or parsed document) — no recompile, any process; op kinds
    resolve against this process's registry.  Bound-weight payloads (v1.3)
    are hash-verified and re-bound, so a weight-carrying artifact executes
    without ever reaching the shape-keyed initializer."""
    from repro.core.artifact import artifact_weights, import_artifact  # lazy
    compiled = import_artifact(path)
    # The artifact carries the optimized graph only; it is its own oracle.
    ins, outs = _io_from_graph(compiled.graph)
    program = CompiledProgram(compiled.graph, compiled, ins, outs)
    plan = getattr(compiled, "sharding_plan", None)
    if plan is not None:
        # v1.4 sharding section: restore the multi-device program as-is
        # (the jax Mesh is only rebuilt from the plan's MeshSpec at
        # execution time, so loading needs no devices).
        program._sharding = plan
    bound = artifact_weights(path)
    if bound:
        program.bind(**bound)
    return program


# --------------------------------------------------------------------------
# Smoke CLI:  python -m repro.api gemm --cache-dir .codo_cache --run
# The CI compile-smoke job greps `cache_hit=False` / `cache_hit=True` from
# a cold + warm invocation pair to pin frontend/cache-key stability.
# --------------------------------------------------------------------------


def main(argv=None) -> int:
    from repro.models.dataflow_models import KERNEL_BENCHES
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description="Compile one Table II kernel through codo.compile().")
    ap.add_argument("workload", choices=sorted(KERNEL_BENCHES),
                    help="traced kernel workload to compile")
    ap.add_argument("--opt", default="opt5",
                    help="CodoOptions preset (default opt5)")
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir (cold/warm smoke)")
    ap.add_argument("--run", action="store_true",
                    help="also execute the design on random inputs and "
                         "verify against the oracle (imports jax)")
    ap.add_argument("--export", default="", metavar="PATH",
                    help="export the design as a JSON artifact")
    args = ap.parse_args(argv)

    from repro.core.cache import CompileCache
    cache = (CompileCache(disk_dir=args.cache_dir) if args.cache_dir
             else _UNSET)
    graph = KERNEL_BENCHES[args.workload]()
    program = compile(graph, options=CodoOptions.preset(args.opt),
                      cache=cache)
    print(program.report())
    print(f"codo.compile({args.workload}): cache_hit={program.cache_hit} "
          f"speedup={program.speedup:.1f}x "
          f"key={program.graph.structural_hash()[:12]}")
    if args.run:
        from repro.models.dataflow_models import random_inputs
        env = random_inputs(program.source)
        program.verify(**env)
        print(f"numerics verified against the oracle on "
              f"{sorted(n for n in env)} ✓")
    if args.export:
        program.export(args.export)
        print(f"artifact exported to {args.export}")
    return 0


__all__ = ["CodoOptions", "CompiledProgram", "F", "ShapedBuffer",
           "TraceError", "buffer", "compile", "load", "trace"]


if __name__ == "__main__":
    raise SystemExit(main())
