"""Paper-benchmark workloads as CODO dataflow graphs (§VIII).

Every workload the paper evaluates is defined here — since the
traced-function frontend (:mod:`repro.core.frontend`) landed, the Table II
kernels and **every** DNN (ResNet-18, VGG-16, MobileNet, ZFNet,
YOLO-tiny, the GPT-2 block) plus the attention/recurrence routing
workloads are **plain Python functions** over symbolic
:class:`~repro.core.frontend.ShapedBuffer` arguments, traced into graphs
by :func:`~repro.core.frontend.trace`.  Only the architecture-config
block graphs still use the low-level :class:`~repro.core.frontend.GB`
builder directly — the documented escape hatch for graphs that want
manual control.

Both roads emit identical structure: a traced builder and its hand-built
twin produce the same ``structural_hash`` — the same compile-cache key —
which the ``HANDBUILT_BENCHES`` references at the bottom of this file
exist to prove (tests/test_frontend.py).  Each task carries a declarative
:class:`~repro.core.ops.OpSpec`, so compiled designs stay portable
artifacts: graphs built here survive the disk compile cache and
process-pool batch compiles fully executable.  Building graphs does not
import jax; only executing them does.

* Table II kernels: atax, gesummv, gemm, mvt, 3mm, residual-mlp,
  autoencoder, residual-block, dws-conv block, 3-layer conv, feed-forward,
  multi-head attention.
* Tables III/IV DNNs: ResNet-18, VGG-16, MobileNet(v1), ZFNet, YOLO-tiny —
  parameterized by input size (3×32×32 / 3×224×224 / 3×1280×384).
* GPT-2 block graph (Fig. 9 / Table VI workload).

Residual skips produce the single-producer-multi-consumer bypass pattern
(Fig. 4a); init/pad pairs produce multi-producer patterns; conv windows
produce stencil re-reads; matmul/pool reductions produce count mismatches —
i.e. these graphs exercise every violation class the paper names.
"""

from __future__ import annotations

import math

import numpy as np

from ..core import frontend as F
from ..core.frontend import GB, trace  # noqa: F401  (GB re-exported: legacy API)
from ..core.graph import DataflowGraph

# --------------------------------------------------------------------------
# Table II kernel-level applications — traced functions.  The *_fn bodies
# are the workload definitions (plain Python over ShapedBuffers; they also
# run eagerly on concrete arrays); the same-named public builders trace
# them at the paper's default sizes.  All are module-level, hence picklable
# for the process-pool batch driver.
# --------------------------------------------------------------------------


def atax_fn(A, x):
    tmp = F.mv(A, x)
    return F.mv(A, tmp, trans=True)


def atax(N: int = 400, M: int = 400) -> DataflowGraph:
    return trace(atax_fn, (M, N), (N,), name="atax")


def gesummv_fn(A, B, x):
    t1 = F.mv(A, x)
    t2 = F.mv(B, x)
    return F.vadd(t1, t2, alpha=1.5, beta=1.2)


def gesummv(N: int = 400) -> DataflowGraph:
    return trace(gesummv_fn, (N, N), (N, N), (N,), name="gesummv")


def gemm_fn(A, B):
    return F.scale(F.matmul(A, B), 1.5)


def gemm(M: int = 256, N: int = 256, K: int = 256) -> DataflowGraph:
    return trace(gemm_fn, (M, K), (K, N), name="gemm")


def mvt_fn(A, y1, y2):
    x1 = F.mv(A, y1)
    x2 = F.mv(A, y2, trans=True)
    return F.vadd(x1, x2)


def mvt(N: int = 400) -> DataflowGraph:
    return trace(mvt_fn, (N, N), (N,), (N,), name="mvt")


def three_mm_fn(A, B, C, D):
    E = F.matmul(A, B)
    Fm = F.matmul(C, D)
    return F.matmul(E, Fm)


def three_mm(M: int = 256) -> DataflowGraph:
    return trace(three_mm_fn, (M, M), (M, M), (M, M), (M, M), name="3mm")


def residual_mlp_fn(x):
    """h = relu(fc(x)); out = relu(fc(h) + x) — the bypass pattern
    (Fig. 4a): x feeds both the first fc and the skip add."""
    D = x.shape[1]
    x = F.load(x)
    h = F.fc(x, D, relu=True)
    h2 = F.fc(h, D)
    return F.relu(F.add(h2, x))


def residual_mlp(B: int = 64, D: int = 512) -> DataflowGraph:
    return trace(residual_mlp_fn, (B, D), name="residual_mlp")


def autoencoder_fn(x):
    D = x.shape[1]
    h = F.fc(x, 256, relu=True)
    h = F.fc(h, 64, relu=True)
    h = F.fc(h, 256, relu=True)
    return F.fc(h, D)


def autoencoder(B: int = 64, D: int = 784) -> DataflowGraph:
    return trace(autoencoder_fn, (B, D), name="autoencoder")


def residual_block_fn(x):
    C = x.shape[1]
    x = F.load(x)
    h = F.conv(x, C, 3, relu=True)
    h = F.conv(h, C, 3, relu=False)
    return F.relu(F.add(h, x))       # skip: SPMC on x


def residual_block(N: int = 1, C: int = 64, H: int = 32) -> DataflowGraph:
    return trace(residual_block_fn, (N, C, H, H), name="residual_block")


def dws_conv_block_fn(x):
    C = x.shape[1]
    h = F.conv(x, C, 3, depthwise=True)
    return F.conv(h, 2 * C, 1, pad=0)


def dws_conv_block(N: int = 1, C: int = 64, H: int = 32) -> DataflowGraph:
    return trace(dws_conv_block_fn, (N, C, H, H), name="dwsconv")


def conv3_block_fn(x):
    h = F.conv(x, 32, 3)
    h = F.conv(h, 32, 3)
    return F.conv(h, 64, 3)


def conv3_block(N: int = 1, C: int = 3, H: int = 34) -> DataflowGraph:
    return trace(conv3_block_fn, (N, C, H, H), name="conv3")


def feed_forward_fn(x):
    D = x.shape[1]
    h = F.fc(x, 4 * D)
    h = F.gelu(h)
    return F.fc(h, D)


def feed_forward(B: int = 128, D: int = 512) -> DataflowGraph:
    return trace(feed_forward_fn, (B, D), name="feed_forward")


def multi_head_attention_fn(x):
    """Single-head attention core (the multi-head loop is the batch ring):
    x feeds Q/K/V projections (SPMC), Q@K^T needs a transpose (order
    violation), softmax is the reduction producer."""
    D = x.shape[1]
    q = F.fc(x, D)
    k = F.fc(x, D)
    v = F.fc(x, D)
    kt = F.transpose(k)
    s = F.matmul(q, kt)
    s = F.scale(s, 1.0 / math.sqrt(D))
    p = F.softmax(s)
    att = F.matmul(p, v)
    return F.fc(att, D)


def multi_head_attention(S: int = 128, D: int = 256) -> DataflowGraph:
    return trace(multi_head_attention_fn, (S, D), name="mha")


# --------------------------------------------------------------------------
# DNN models (Tables III/IV)
# --------------------------------------------------------------------------


def resnet18_fn(x):
    H = x.shape[2]
    if H >= 224:
        h = F.conv(x, 64, 7, stride=2, pad=3)
        h = F.maxpool(h, 2)
    else:
        h = F.conv(x, 64, 3)
    for stage, (c, blocks) in enumerate([(64, 2), (128, 2), (256, 2), (512, 2)]):
        for blk in range(blocks):
            stride = 2 if (stage > 0 and blk == 0) else 1
            inp = h
            h1 = F.conv(inp, c, 3, stride=stride)
            h2 = F.conv(h1, c, 3, relu=False)
            if stride != 1 or inp.shape[1] != c:
                skip = F.conv(inp, c, 1, stride=stride, pad=0, relu=False)
            else:
                skip = inp
            h = F.relu(F.add(h2, skip))
    h = F.global_avgpool(h)
    return F.fc(h, 1000)


def resnet18(H: int = 32) -> DataflowGraph:
    return trace(resnet18_fn, (1, 3, H, H), name=f"resnet18_{H}")


def vgg16_fn(x):
    h = x
    for c, reps in [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]:
        for _ in range(reps):
            h = F.conv(h, c, 3)
        h = F.maxpool(h, 2)
    h = F.flatten(h)
    h = F.fc(h, 512, relu=True)
    h = F.fc(h, 512, relu=True)
    return F.fc(h, 1000)


def vgg16(H: int = 32) -> DataflowGraph:
    return trace(vgg16_fn, (1, 3, H, H), name=f"vgg16_{H}")


def mobilenet_fn(x):
    H = x.shape[2]
    h = F.conv(x, 32, 3, stride=2 if H >= 224 else 1)
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + \
           [(512, 1)] * 5 + [(1024, 2), (1024, 1)]
    for c, s in plan:
        h = F.conv(h, 0, 3, stride=s, depthwise=True)
        h = F.conv(h, c, 1, pad=0)
    h = F.global_avgpool(h)
    return F.fc(h, 1000)


def mobilenet(H: int = 32) -> DataflowGraph:
    return trace(mobilenet_fn, (1, 3, H, H), name=f"mobilenet_{H}")


def zfnet_fn(x):
    h = F.conv(x, 96, 7, stride=2, pad=3)
    h = F.maxpool(h, 2)
    h = F.conv(h, 256, 5, stride=2, pad=2)
    h = F.maxpool(h, 2)
    h = F.conv(h, 384, 3)
    h = F.conv(h, 384, 3)
    h = F.conv(h, 256, 3)
    h = F.maxpool(h, 2)
    h = F.flatten(h)
    h = F.fc(h, 4096, relu=True)
    h = F.fc(h, 4096, relu=True)
    return F.fc(h, 1000)


def zfnet(H: int = 224) -> DataflowGraph:
    return trace(zfnet_fn, (1, 3, H, H), name=f"zfnet_{H}")


def yolo_tiny_fn(x):
    h = x
    c = 16
    for _ in range(6):
        h = F.conv(h, c, 3)
        h = F.maxpool(h, 2)
        c = min(c * 2, 512)
    h = F.conv(h, 512, 3)
    h = F.conv(h, 256, 1, pad=0)
    return F.conv(h, 255, 1, pad=0, relu=False)


def yolo_tiny(H: int = 384, W: int = 1280) -> DataflowGraph:
    return trace(yolo_tiny_fn, (1, 3, H, W), name="yolo")


def gpt2_block_fn(x):
    """One GPT-2 block: LN -> MHA(+skip) -> LN -> FF(+skip) — the repeated
    unit of the paper's GPT-2 accelerator (LN folded into projections for
    graph purposes)."""
    D = x.shape[1]
    x = F.load(x)
    q = F.fc(x, D)
    k = F.fc(x, D)
    v = F.fc(x, D)
    kt = F.transpose(k)
    s = F.scale(F.matmul(q, kt), 1.0 / math.sqrt(D // 16))
    p = F.softmax(s)
    att = F.matmul(p, v)
    proj = F.fc(att, D)
    h = F.add(proj, x)              # skip 1: SPMC on x
    f = F.fc(h, 4 * D)
    f = F.gelu(f)
    f = F.fc(f, D)
    return F.add(f, h)              # skip 2: SPMC on h


def gpt2_block(S: int = 128, D: int = 1024) -> DataflowGraph:
    return trace(gpt2_block_fn, (S, D), name="gpt2_block")


def gpt2_block_loss_fn(x, target):
    """MSE training objective over one GPT-2 block — the single (1, 1)
    loss output a ``codo.compile(..., grad=True)`` train step seeds."""
    d = F.sub(gpt2_block_fn(x), target)
    return F.mean_all(F.mul(d, d))


def gpt2_block_loss(S: int = 128, D: int = 1024) -> DataflowGraph:
    return trace(gpt2_block_loss_fn, (S, D), (S, D), name="gpt2_block_loss")


# --------------------------------------------------------------------------
# Attention / recurrence families (ROADMAP item 4).  The workload bodies
# live next to their reference models (models/transformer.py, rglru.py,
# ssm.py); the builders below trace them at routing-bench sizes.  The
# model modules import jax at top level, hence the lazy imports — building
# these graphs still does not require jax.
# --------------------------------------------------------------------------


def mha_batched(BH: int = 4, S: int = 64, hd: int = 32) -> DataflowGraph:
    """One attention head over (BH, S, hd) operands — the batched
    matmul->scale->softmax->matmul chain the flashattn pattern routes."""
    from .transformer import mha_batched_fn
    sh = (BH, S, hd)
    return trace(mha_batched_fn, sh, sh, sh, name="mha_batched")


def rglru_block(B: int = 2, S: int = 128, D: int = 64) -> DataflowGraph:
    """Gated linear recurrence + residual (RG-LRU core)."""
    from .rglru import rglru_block_fn
    sh = (B, S, D)
    return trace(rglru_block_fn, sh, sh, sh, name="rglru_block")


def ssd_block(nc: int = 8, BH: int = 8, P: int = 32, N: int = 32) -> DataflowGraph:
    """SSD inter-chunk state recurrence + residual combine."""
    from .ssm import ssd_block_fn
    return trace(ssd_block_fn, (nc, BH, P, N), (nc, BH, 1, 1),
                 name="ssd_block")


# --------------------------------------------------------------------------
# Architecture configs -> dataflow graphs (the batch-compile grid)
# --------------------------------------------------------------------------


def _attn_block(b: GB, x: str, D: int, hd: int, enc: str | None = None) -> str:
    """Self- (or, given ``enc``, cross-) attention + projection + residual."""
    q = b.fc(x, D)
    kv_src = enc if enc is not None else x
    k = b.fc(kv_src, D)
    v = b.fc(kv_src, D)
    kt = b.transpose(k)
    s = b.scale(b.matmul(q, kt), 1.0 / math.sqrt(max(hd, 1)))
    p = b.softmax(s)
    att = b.matmul(p, v)
    proj = b.fc(att, D)
    return b.add(proj, x)                  # residual: SPMC on x


def _ffn_block(b: GB, x: str, cfg) -> str:
    """(Gated) FFN + residual; MoE adds the router dispatch/combine
    side-chain so expert traffic shows up in the dataflow."""
    D = cfg.d_model
    if cfg.glu:
        gate = b.gelu(b.fc(x, cfg.d_ff))
        up = b.fc(x, cfg.d_ff)
        mixed = b.add(gate, up)            # gating proxy (same dataflow shape)
    else:
        mixed = b.gelu(b.fc(x, cfg.d_ff))
    down = b.fc(mixed, D)
    out = b.add(down, x)
    if cfg.moe is not None:
        router = b.softmax(b.fc(x, cfg.moe.num_experts))
        combined = b.fc(router, D)         # combine back into the stream
        out = b.add(out, combined)
    return out


def _recurrent_block(b: GB, x: str, D: int, expand: int = 2) -> str:
    """SSM / RG-LRU style block: in-proj + gate, state mixing, out-proj,
    residual.  The chunked recurrence appears as a dense state-mix task —
    the dataflow (streams, reuse, reductions) is what the compiler sees."""
    d_in = D * max(expand, 1)
    u = b.fc(x, d_in)
    gate = b.gelu(b.fc(x, d_in))
    mix = b.fc(u, d_in)
    gated = b.add(mix, gate)
    out = b.fc(gated, D)
    return b.add(out, x)


def arch_block_graph(cfg, S: int = 64) -> DataflowGraph:
    """One representative backbone block of ``cfg`` (an
    :class:`repro.configs.base.ArchConfig`) as a CODO dataflow graph.

    This is the unit the batch compiler drives across the opt1..opt5 grid:
    real model dims (d_model/d_ff/experts), one block per distinct kind in
    the architecture's pattern.  Multimodal prefixes are folded into ``S``
    upstream — the dataflow structure is identical.
    """
    b = GB(cfg.name.replace("-", "_").replace(".", "_"))
    D = cfg.d_model
    x = b.load(b.input("x", (S, D)))
    h = x
    if cfg.ssm is not None:
        h = _recurrent_block(b, h, D, cfg.ssm.expand)
    elif "rglru" in cfg.block_pattern:      # hybrid: recurrent + local attn
        h = _recurrent_block(b, h, D)
        h = _attn_block(b, h, D, cfg.hd)
    else:
        h = _attn_block(b, h, D, cfg.hd)
    if cfg.enc_dec:                         # whisper-style cross attention
        enc = b.load(b.input("enc_out", (min(cfg.enc_frames, 128), D)))
        h = _attn_block(b, h, D, cfg.hd, enc=enc)
    if cfg.ssm is None:
        h = _ffn_block(b, h, cfg)
    b.mark_output(h)
    return b.g


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

KERNEL_BENCHES = {
    "atax": atax, "gesummv": gesummv, "gemm": gemm, "mvt": mvt, "3mm": three_mm,
    "residual_mlp": residual_mlp, "autoencoder": autoencoder,
    "residual_block": residual_block, "dws_conv_block": dws_conv_block,
    "conv3_block": conv3_block, "feed_forward": feed_forward,
    "multi_head_attention": multi_head_attention,
}

# name -> the traced function each public kernel builder traces (all
# module-level: a BatchJob carrying one pickles into worker processes).
KERNEL_FNS = {
    "atax": atax_fn, "gesummv": gesummv_fn, "gemm": gemm_fn, "mvt": mvt_fn,
    "3mm": three_mm_fn, "residual_mlp": residual_mlp_fn,
    "autoencoder": autoencoder_fn, "residual_block": residual_block_fn,
    "dws_conv_block": dws_conv_block_fn, "conv3_block": conv3_block_fn,
    "feed_forward": feed_forward_fn,
    "multi_head_attention": multi_head_attention_fn,
}

DNN_BENCHES = {
    "resnet18": resnet18, "vgg16": vgg16, "mobilenet": mobilenet,
    "zfnet": zfnet, "yolo": yolo_tiny, "gpt2_block": gpt2_block,
}

# Attention / recurrence routing workloads (ROADMAP item 4): traced
# builders whose chains the flashattn / rglru / ssd kernel patterns claim.
RECURRENCE_BENCHES = {
    "mha_batched": mha_batched, "rglru_block": rglru_block,
    "ssd_block": ssd_block,
}


def random_inputs(graph: DataflowGraph, seed: int = 0) -> dict:
    """Fan-in-normalized random inputs/weights: deep CNN oracles stay O(1)
    in magnitude so fp32 comparisons remain meaningful."""
    import jax.numpy as jnp  # lazy: graph building stays jax-free

    rng = np.random.default_rng(seed)
    env = {}
    for buf in graph.buffers.values():
        if buf.kind == "input":
            env[buf.name] = jnp.asarray(
                rng.standard_normal(buf.shape), jnp.float32)
        elif buf.kind == "weight":
            fan_in = int(np.prod(buf.shape[1:])) if len(buf.shape) > 1 \
                else buf.shape[0]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            env[buf.name] = jnp.asarray(
                rng.standard_normal(buf.shape) * std, jnp.float32)
    return env


# --------------------------------------------------------------------------
# Hand-built references.  These are the original task-by-task GB builders
# the traced functions above replaced; they are kept (not exported in
# KERNEL_BENCHES) as the ground truth the frontend is checked against:
# tests assert traced.structural_hash() == handbuilt.structural_hash() for
# every pair, i.e. tracing changes *how* graphs are written, not *what*
# the compiler sees — including the compile-cache key.
# --------------------------------------------------------------------------


def atax_handbuilt(N: int = 400, M: int = 400) -> DataflowGraph:
    b = GB("atax")
    A = b.input("A", (M, N)); x = b.input("x", (N,))
    tmp = b.mv(A, x)
    y = b.mv(A, tmp, trans=True)
    b.mark_output(y)
    return b.g


def gesummv_handbuilt(N: int = 400) -> DataflowGraph:
    b = GB("gesummv")
    A = b.input("A", (N, N)); Bm = b.input("B", (N, N)); x = b.input("x", (N,))
    t1 = b.mv(A, x)
    t2 = b.mv(Bm, x)
    y = b.vadd(t1, t2, alpha=1.5, beta=1.2)
    b.mark_output(y)
    return b.g


def gemm_handbuilt(M: int = 256, N: int = 256, K: int = 256) -> DataflowGraph:
    b = GB("gemm")
    A = b.input("A", (M, K)); Bm = b.input("B", (K, N))
    C = b.matmul(A, Bm)
    C = b.scale(C, 1.5)
    b.mark_output(C)
    return b.g


def mvt_handbuilt(N: int = 400) -> DataflowGraph:
    b = GB("mvt")
    A = b.input("A", (N, N)); y1 = b.input("y1", (N,)); y2 = b.input("y2", (N,))
    x1 = b.mv(A, y1)
    x2 = b.mv(A, y2, trans=True)
    o = b.vadd(x1, x2)
    b.mark_output(o)
    return b.g


def three_mm_handbuilt(M: int = 256) -> DataflowGraph:
    b = GB("3mm")
    A = b.input("A", (M, M)); Bm = b.input("B", (M, M))
    C = b.input("C", (M, M)); D = b.input("D", (M, M))
    E = b.matmul(A, Bm)
    Fm = b.matmul(C, D)
    G = b.matmul(E, Fm)
    b.mark_output(G)
    return b.g


def residual_mlp_handbuilt(B: int = 64, D: int = 512) -> DataflowGraph:
    b = GB("residual_mlp")
    x = b.load(b.input("x", (B, D)))
    h = b.fc(x, D, relu=True)
    h2 = b.fc(h, D)
    o = b.relu(b.add(h2, x))
    b.mark_output(o)
    return b.g


def autoencoder_handbuilt(B: int = 64, D: int = 784) -> DataflowGraph:
    b = GB("autoencoder")
    x = b.input("x", (B, D))
    h = b.fc(x, 256, relu=True)
    h = b.fc(h, 64, relu=True)
    h = b.fc(h, 256, relu=True)
    o = b.fc(h, D)
    b.mark_output(o)
    return b.g


def residual_block_handbuilt(N: int = 1, C: int = 64, H: int = 32) -> DataflowGraph:
    b = GB("residual_block")
    x = b.load(b.input("x", (N, C, H, H)))
    h = b.conv(x, C, 3, relu=True)
    h = b.conv(h, C, 3, relu=False)
    o = b.relu(b.add(h, x))          # skip: SPMC on x
    b.mark_output(o)
    return b.g


def dws_conv_block_handbuilt(N: int = 1, C: int = 64, H: int = 32) -> DataflowGraph:
    b = GB("dwsconv")
    x = b.input("x", (N, C, H, H))
    h = b.conv(x, C, 3, depthwise=True)
    o = b.conv(h, 2 * C, 1, pad=0)
    b.mark_output(o)
    return b.g


def conv3_block_handbuilt(N: int = 1, C: int = 3, H: int = 34) -> DataflowGraph:
    b = GB("conv3")
    x = b.input("x", (N, C, H, H))
    h = b.conv(x, 32, 3)
    h = b.conv(h, 32, 3)
    h = b.conv(h, 64, 3)
    b.mark_output(h)
    return b.g


def feed_forward_handbuilt(B: int = 128, D: int = 512) -> DataflowGraph:
    b = GB("feed_forward")
    x = b.input("x", (B, D))
    h = b.fc(x, 4 * D)
    h = b.gelu(h)
    o = b.fc(h, D)
    b.mark_output(o)
    return b.g


def multi_head_attention_handbuilt(S: int = 128, D: int = 256) -> DataflowGraph:
    b = GB("mha")
    x = b.input("x", (S, D))
    q = b.fc(x, D)
    k = b.fc(x, D)
    v = b.fc(x, D)
    kt = b.transpose(k)
    s = b.matmul(q, kt)
    s = b.scale(s, 1.0 / math.sqrt(D))
    p = b.softmax(s)
    att = b.matmul(p, v)
    o = b.fc(att, D)
    b.mark_output(o)
    return b.g


def resnet18_handbuilt(H: int = 32) -> DataflowGraph:
    b = GB(f"resnet18_{H}")
    x = b.input("x", (1, 3, H, H))
    if H >= 224:
        h = b.conv(x, 64, 7, stride=2, pad=3)
        h = b.maxpool(h, 2)
    else:
        h = b.conv(x, 64, 3)
    for stage, (c, blocks) in enumerate([(64, 2), (128, 2), (256, 2), (512, 2)]):
        for blk in range(blocks):
            stride = 2 if (stage > 0 and blk == 0) else 1
            inp = h
            h1 = b.conv(inp, c, 3, stride=stride)
            h2 = b.conv(h1, c, 3, relu=False)
            if stride != 1 or b.shape[inp][1] != c:
                skip = b.conv(inp, c, 1, stride=stride, pad=0, relu=False)
            else:
                skip = inp
            h = b.relu(b.add(h2, skip))
    h = b.global_avgpool(h)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def gpt2_block_handbuilt(S: int = 128, D: int = 1024) -> DataflowGraph:
    b = GB("gpt2_block")
    x = b.load(b.input("x", (S, D)))
    q = b.fc(x, D)
    k = b.fc(x, D)
    v = b.fc(x, D)
    kt = b.transpose(k)
    s = b.scale(b.matmul(q, kt), 1.0 / math.sqrt(D // 16))
    p = b.softmax(s)
    att = b.matmul(p, v)
    proj = b.fc(att, D)
    h = b.add(proj, x)
    f = b.fc(h, 4 * D)
    f = b.gelu(f)
    f = b.fc(f, D)
    o = b.add(f, h)
    b.mark_output(o)
    return b.g


def vgg16_handbuilt(H: int = 32) -> DataflowGraph:
    b = GB(f"vgg16_{H}")
    x = b.input("x", (1, 3, H, H))
    h = x
    for c, reps in [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]:
        for _ in range(reps):
            h = b.conv(h, c, 3)
        h = b.maxpool(h, 2)
    h = b.flatten(h)
    h = b.fc(h, 512, relu=True)
    h = b.fc(h, 512, relu=True)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def mobilenet_handbuilt(H: int = 32) -> DataflowGraph:
    b = GB(f"mobilenet_{H}")
    x = b.input("x", (1, 3, H, H))
    h = b.conv(x, 32, 3, stride=2 if H >= 224 else 1)
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + \
           [(512, 1)] * 5 + [(1024, 2), (1024, 1)]
    for c, s in plan:
        h = b.conv(h, 0, 3, stride=s, depthwise=True)
        h = b.conv(h, c, 1, pad=0)
    h = b.global_avgpool(h)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def zfnet_handbuilt(H: int = 224) -> DataflowGraph:
    b = GB(f"zfnet_{H}")
    x = b.input("x", (1, 3, H, H))
    h = b.conv(x, 96, 7, stride=2, pad=3)
    h = b.maxpool(h, 2)
    h = b.conv(h, 256, 5, stride=2, pad=2)
    h = b.maxpool(h, 2)
    h = b.conv(h, 384, 3)
    h = b.conv(h, 384, 3)
    h = b.conv(h, 256, 3)
    h = b.maxpool(h, 2)
    h = b.flatten(h)
    h = b.fc(h, 4096, relu=True)
    h = b.fc(h, 4096, relu=True)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def yolo_tiny_handbuilt(H: int = 384, W: int = 1280) -> DataflowGraph:
    b = GB("yolo")
    x = b.input("x", (1, 3, H, W))
    h = x
    c = 16
    for _ in range(6):
        h = b.conv(h, c, 3)
        h = b.maxpool(h, 2)
        c = min(c * 2, 512)
    h = b.conv(h, 512, 3)
    h = b.conv(h, 256, 1, pad=0)
    o = b.conv(h, 255, 1, pad=0, relu=False)
    b.mark_output(o)
    return b.g


def mha_batched_handbuilt(BH: int = 4, S: int = 64, hd: int = 32) -> DataflowGraph:
    b = GB("mha_batched")
    q = b.input("q", (BH, S, hd))
    k = b.input("k", (BH, S, hd))
    v = b.input("v", (BH, S, hd))
    kt = b.transpose(k)
    s = b.scale(b.matmul(q, kt), 1.0 / math.sqrt(hd))
    p = b.softmax(s)
    o = b.matmul(p, v)
    b.mark_output(o)
    return b.g


def rglru_block_handbuilt(B: int = 2, S: int = 128, D: int = 64) -> DataflowGraph:
    b = GB("rglru_block")
    a = b.input("a", (B, S, D))
    gate = b.input("gate", (B, S, D))
    x = b.input("x", (B, S, D))
    bb = b.mul(gate, x)
    h = b.rglru_scan(a, bb)
    o = b.add(h, x)
    b.mark_output(o)
    return b.g


def ssd_block_handbuilt(nc: int = 8, BH: int = 8, P: int = 32,
                        N: int = 32) -> DataflowGraph:
    b = GB("ssd_block")
    states = b.input("states", (nc, BH, P, N))
    decay = b.input("decay", (nc, BH, 1, 1))
    prev = b.ssd_scan(states, decay)
    o = b.add(prev, states)
    b.mark_output(o)
    return b.g


# name -> (traced builder, hand-built twin); both zero-arg-callable at the
# paper's default sizes.  tests/test_frontend.py asserts hash parity.
HANDBUILT_BENCHES = {
    "atax": (atax, atax_handbuilt),
    "gesummv": (gesummv, gesummv_handbuilt),
    "gemm": (gemm, gemm_handbuilt),
    "mvt": (mvt, mvt_handbuilt),
    "3mm": (three_mm, three_mm_handbuilt),
    "residual_mlp": (residual_mlp, residual_mlp_handbuilt),
    "autoencoder": (autoencoder, autoencoder_handbuilt),
    "residual_block": (residual_block, residual_block_handbuilt),
    "dws_conv_block": (dws_conv_block, dws_conv_block_handbuilt),
    "conv3_block": (conv3_block, conv3_block_handbuilt),
    "feed_forward": (feed_forward, feed_forward_handbuilt),
    "multi_head_attention": (multi_head_attention, multi_head_attention_handbuilt),
    "resnet18": (resnet18, resnet18_handbuilt),
    "gpt2_block": (gpt2_block, gpt2_block_handbuilt),
    "vgg16": (vgg16, vgg16_handbuilt),
    "mobilenet": (mobilenet, mobilenet_handbuilt),
    "zfnet": (zfnet, zfnet_handbuilt),
    "yolo": (yolo_tiny, yolo_tiny_handbuilt),
    "mha_batched": (mha_batched, mha_batched_handbuilt),
    "rglru_block": (rglru_block, rglru_block_handbuilt),
    "ssd_block": (ssd_block, ssd_block_handbuilt),
}
