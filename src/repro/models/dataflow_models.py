"""Paper-benchmark workloads as CODO dataflow graphs (§VIII).

Every workload the paper evaluates is built here as a :class:`DataflowGraph`
of affine tasks with *declarative* numeric semantics — each task carries an
:class:`~repro.core.ops.OpSpec` (op kind + operand names + plain-data
attrs) that the op registry materializes into jnp on demand — so the
compiler runs on the *same* graphs the paper compiles, and every compiled
design is a portable artifact: graphs built here survive the disk compile
cache and process-pool batch compiles fully executable.  Building graphs
does not import jax; only executing them does.

* Table II kernels: atax, gesummv, gemm, mvt, 3mm, residual-mlp,
  autoencoder, residual-block, dws-conv block, 3-layer conv, feed-forward,
  multi-head attention.
* Tables III/IV DNNs: ResNet-18, VGG-16, MobileNet(v1), ZFNet, YOLO-tiny —
  parameterized by input size (3×32×32 / 3×224×224 / 3×1280×384).
* GPT-2 block graph (Fig. 9 / Table VI workload).

Residual skips produce the single-producer-multi-consumer bypass pattern
(Fig. 4a); init/pad pairs produce multi-producer patterns; conv windows
produce stencil re-reads; matmul/pool reductions produce count mismatches —
i.e. these graphs exercise every violation class the paper names.
"""

from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from ..core.graph import (Access, DataflowGraph, Loop, Task, conv2d_task,
                          ewise_task, full_index, idx, matmul_task, pad_task,
                          pool_task)
from ..core.ops import OpSpec

# --------------------------------------------------------------------------
# Builder
# --------------------------------------------------------------------------


class GB:
    """Graph-builder: tracks shapes, emits tasks with declarative specs."""

    def __init__(self, name: str):
        self.g = DataflowGraph(name)
        self.n = 0
        self.shape: dict[str, tuple[int, ...]] = {}

    def fresh(self, prefix: str) -> str:
        self.n += 1
        return f"{prefix}{self.n}"

    def buf(self, name: str, shape, kind="intermediate") -> str:
        self.g.buffer(name, shape, kind=kind)
        self.shape[name] = tuple(shape)
        return name

    def input(self, name: str, shape) -> str:
        return self.buf(name, shape, "input")

    def weight(self, name: str, shape) -> str:
        return self.buf(name, shape, "weight")

    def mark_output(self, name: str) -> None:
        self.g.buffers[name].kind = "output"

    # ---- CNN ops ---------------------------------------------------------

    def pad(self, x: str, p: int) -> str:
        n, c, h, w = self.shape[x]
        out = self.buf(self.fresh("pad"), (n, c, h + 2 * p, w + 2 * p))
        self.g.add_task(pad_task(
            self.fresh("padding"), out, x, n, c, h, w, p,
            spec=OpSpec("pad2d", (x,), (out,), {"pad": p})))
        return out

    def conv(self, x: str, co: int, k: int, stride: int = 1, pad: int = -1,
             relu: bool = True, depthwise: bool = False) -> str:
        if pad < 0:
            pad = k // 2
        if pad:
            x = self.pad(x, pad)
        n, ci, hp, wp = self.shape[x]
        oh, ow = (hp - k) // stride + 1, (wp - k) // stride + 1
        groups = ci if depthwise else 1
        co_eff = ci if depthwise else co
        wname = self.weight(self.fresh("w"),
                            (co_eff, 1 if depthwise else ci, k, k))
        out = self.buf(self.fresh("conv"), (n, co_eff, oh, ow))

        conv_spec = OpSpec("conv2d", (x, wname), (out,),
                           {"stride": stride, "groups": groups})

        if depthwise:
            t = Task(self.fresh("dwconv"),
                     loops=[Loop("n", n), Loop("c", co_eff), Loop("h", oh),
                            Loop("w", ow), Loop("kh", k), Loop("kw", k)],
                     reads=[Access(x, (idx("n"), idx("c"),
                                       idx(("h", stride), "kh"),
                                       idx(("w", stride), "kw")), False),
                            Access(wname, (idx("c"), (), idx("kh"), idx("kw")),
                                   False)],
                     writes=[Access(out, (idx("n"), idx("c"), idx("h"),
                                          idx("w")), True)],
                     op="conv", flops_per_iter=2.0, spec=conv_spec)
            self.g.add_task(t)
        else:
            self.g.add_task(conv2d_task(self.fresh("conv2d"), out, x, wname,
                                        n, co_eff, ci, oh, ow, k, k,
                                        spec=conv_spec, stride=stride))
        if relu:
            out = self.relu(out)
        return out

    def relu(self, x: str) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("relu"), shp)
        dims = ["n", "c", "h", "w"][:len(shp)] if len(shp) == 4 else None
        self.g.add_task(ewise_task(
            self.fresh("relu_t"), out, [x], shp, op="ewise",
            spec=OpSpec("relu", (x,), (out,)), dim_names=dims))
        return out

    def gelu(self, x: str) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("gelu"), shp)
        self.g.add_task(ewise_task(
            self.fresh("gelu_t"), out, [x], shp, op="ewise", flops_per_iter=8.0,
            spec=OpSpec("gelu", (x,), (out,))))
        return out

    def add(self, a: str, b: str) -> str:
        shp = self.shape[a]
        out = self.buf(self.fresh("add"), shp)
        dims = ["n", "c", "h", "w"][:len(shp)] if len(shp) == 4 else None
        self.g.add_task(ewise_task(
            self.fresh("add_t"), out, [a, b], shp, op="ewise",
            spec=OpSpec("add", (a, b), (out,)), dim_names=dims))
        return out

    def maxpool(self, x: str, k: int) -> str:
        n, c, h, w = self.shape[x]
        oh, ow = h // k, w // k
        out = self.buf(self.fresh("pool"), (n, c, oh, ow))
        self.g.add_task(pool_task(
            self.fresh("maxpool"), out, x, n, c, oh, ow, k,
            spec=OpSpec("maxpool2d", (x,), (out,), {"k": k})))
        return out

    def global_avgpool(self, x: str) -> str:
        n, c, h, w = self.shape[x]
        out = self.buf(self.fresh("gap"), (n, c))
        t = Task(self.fresh("gap_t"),
                 loops=[Loop("n", n), Loop("c", c), Loop("h", h), Loop("w", w)],
                 reads=[Access(x, full_index(["n", "c", "h", "w"]), False)],
                 writes=[Access(out, (idx("n"), idx("c")), True)],
                 op="pool", flops_per_iter=1.0,
                 spec=OpSpec("mean", (x,), (out,), {"axes": (2, 3)}))
        self.g.add_task(t)
        return out

    def flatten(self, x: str) -> str:
        n, c, h, w = self.shape[x]
        out = self.buf(self.fresh("flat"), (n, c * h * w))
        t = Task(self.fresh("flatten_t"),
                 loops=[Loop("n", n), Loop("c", c), Loop("h", h), Loop("w", w)],
                 reads=[Access(x, full_index(["n", "c", "h", "w"]), False)],
                 writes=[Access(out, (idx("n"),
                                      idx(("c", h * w), ("h", w), "w")), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("reshape", (x,), (out,), {"shape": (n, -1)}))
        self.g.add_task(t)
        return out

    # ---- dense ops ---------------------------------------------------------

    def fc(self, x: str, dout: str | int, relu: bool = False,
           weight: str | None = None) -> str:
        m, k = self.shape[x]
        nname = int(dout)
        wname = weight or self.weight(self.fresh("wfc"), (k, nname))
        out = self.buf(self.fresh("fc"), (m, nname))
        self.g.add_task(matmul_task(
            self.fresh("fc_t"), out, x, wname, m, nname, k,
            spec=OpSpec("matmul", (x, wname), (out,))))
        if relu:
            out = self.relu(out)
        return out

    def matmul(self, a: str, b: str) -> str:
        m, k = self.shape[a]
        k2, n = self.shape[b]
        assert k == k2, (self.shape[a], self.shape[b])
        out = self.buf(self.fresh("mm"), (m, n))
        self.g.add_task(matmul_task(
            self.fresh("mm_t"), out, a, b, m, n, k,
            spec=OpSpec("matmul", (a, b), (out,))))
        return out

    def transpose(self, x: str) -> str:
        m, n = self.shape[x]
        out = self.buf(self.fresh("tr"), (n, m))
        t = Task(self.fresh("transpose_t"),
                 loops=[Loop("i", m), Loop("j", n)],
                 reads=[Access(x, (idx("i"), idx("j")), False)],
                 writes=[Access(out, (idx("j"), idx("i")), True)],
                 op="copy", flops_per_iter=0.0,
                 spec=OpSpec("transpose", (x,), (out,)))
        self.g.add_task(t)
        return out

    def softmax(self, x: str) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("sm"), shp)
        self.g.add_task(ewise_task(
            self.fresh("softmax_t"), out, [x], shp, op="softmax",
            flops_per_iter=5.0,
            spec=OpSpec("softmax", (x,), (out,), {"axis": -1})))
        return out

    def scale(self, x: str, s: float) -> str:
        shp = self.shape[x]
        out = self.buf(self.fresh("scale"), shp)
        # The scale factor is an OpSpec attr — plain data that enters
        # structural_signature(), so graphs differing only in `s` key the
        # compile cache apart (no const: tag needed, unlike closures).
        self.g.add_task(ewise_task(
            self.fresh("scale_t"), out, [x], shp, op="ewise",
            spec=OpSpec("scale", (x,), (out,), {"s": float(s)})))
        return out

    def mv(self, A: str, x: str, trans: bool = False) -> str:
        """y = A @ x (or A.T @ x): PolyBench building block."""
        m, k = self.shape[A]
        if trans:
            m, k = k, m
        out = self.buf(self.fresh("mv"), (m,))
        loops = [Loop("m", m), Loop("k", k)]
        a_idx = (idx("k"), idx("m")) if trans else (idx("m"), idx("k"))
        t = Task(self.fresh("mv_t"), loops,
                 reads=[Access(A, a_idx, False), Access(x, (idx("k"),), False)],
                 writes=[Access(out, (idx("m"),), True)],
                 op="matmul", flops_per_iter=2.0,
                 spec=OpSpec("mv", (A, x), (out,), {"trans": bool(trans)}))
        self.g.add_task(t)
        return out

    def load(self, x: str) -> str:
        """Explicit off-chip→on-chip stream task (the DMA 'load' node every
        HLS dataflow design starts with).  Makes downstream skip connections
        read an *intermediate* buffer, exercising the bypass pattern."""
        shp = self.shape[x]
        out = self.buf(self.fresh("ld"), shp)
        dims = ["n", "c", "h", "w"][:len(shp)] if len(shp) == 4 else None
        self.g.add_task(ewise_task(
            self.fresh("load_t"), out, [x], shp, op="copy", flops_per_iter=0.0,
            spec=OpSpec("identity", (x,), (out,)), dim_names=dims))
        return out

    def vadd(self, a: str, b: str, alpha: float = 1.0, beta: float = 1.0) -> str:
        shp = self.shape[a]
        out = self.buf(self.fresh("vadd"), shp)
        # alpha/beta are structural via OpSpec.attrs (see scale()).
        self.g.add_task(ewise_task(
            self.fresh("vadd_t"), out, [a, b], shp, op="ewise",
            spec=OpSpec("vadd", (a, b), (out,),
                        {"alpha": float(alpha), "beta": float(beta)})))
        return out


# --------------------------------------------------------------------------
# Table II kernel-level applications
# --------------------------------------------------------------------------


def atax(N: int = 400, M: int = 400) -> DataflowGraph:
    b = GB("atax")
    A = b.input("A", (M, N)); x = b.input("x", (N,))
    tmp = b.mv(A, x)
    y = b.mv(A, tmp, trans=True)
    b.mark_output(y)
    return b.g


def gesummv(N: int = 400) -> DataflowGraph:
    b = GB("gesummv")
    A = b.input("A", (N, N)); Bm = b.input("B", (N, N)); x = b.input("x", (N,))
    t1 = b.mv(A, x)
    t2 = b.mv(Bm, x)
    y = b.vadd(t1, t2, alpha=1.5, beta=1.2)
    b.mark_output(y)
    return b.g


def gemm(M: int = 256, N: int = 256, K: int = 256) -> DataflowGraph:
    b = GB("gemm")
    A = b.input("A", (M, K)); Bm = b.input("B", (K, N))
    C = b.matmul(A, Bm)
    C = b.scale(C, 1.5)
    b.mark_output(C)
    return b.g


def mvt(N: int = 400) -> DataflowGraph:
    b = GB("mvt")
    A = b.input("A", (N, N)); y1 = b.input("y1", (N,)); y2 = b.input("y2", (N,))
    x1 = b.mv(A, y1)
    x2 = b.mv(A, y2, trans=True)
    o = b.vadd(x1, x2)
    b.mark_output(o)
    return b.g


def three_mm(M: int = 256) -> DataflowGraph:
    b = GB("3mm")
    A = b.input("A", (M, M)); Bm = b.input("B", (M, M))
    C = b.input("C", (M, M)); D = b.input("D", (M, M))
    E = b.matmul(A, Bm)
    F = b.matmul(C, D)
    G = b.matmul(E, F)
    b.mark_output(G)
    return b.g


def residual_mlp(B: int = 64, D: int = 512) -> DataflowGraph:
    """h = relu(fc(x)); out = relu(fc(h) + x) — the bypass pattern (Fig. 4a):
    x feeds both the first fc and the skip add."""
    b = GB("residual_mlp")
    x = b.load(b.input("x", (B, D)))
    h = b.fc(x, D, relu=True)
    h2 = b.fc(h, D)
    o = b.relu(b.add(h2, x))
    b.mark_output(o)
    return b.g


def autoencoder(B: int = 64, D: int = 784) -> DataflowGraph:
    b = GB("autoencoder")
    x = b.input("x", (B, D))
    h = b.fc(x, 256, relu=True)
    h = b.fc(h, 64, relu=True)
    h = b.fc(h, 256, relu=True)
    o = b.fc(h, D)
    b.mark_output(o)
    return b.g


def residual_block(N: int = 1, C: int = 64, H: int = 32) -> DataflowGraph:
    b = GB("residual_block")
    x = b.load(b.input("x", (N, C, H, H)))
    h = b.conv(x, C, 3, relu=True)
    h = b.conv(h, C, 3, relu=False)
    o = b.relu(b.add(h, x))          # skip: SPMC on x
    b.mark_output(o)
    return b.g


def dws_conv_block(N: int = 1, C: int = 64, H: int = 32) -> DataflowGraph:
    b = GB("dwsconv")
    x = b.input("x", (N, C, H, H))
    h = b.conv(x, C, 3, depthwise=True)
    o = b.conv(h, 2 * C, 1, pad=0)
    b.mark_output(o)
    return b.g


def conv3_block(N: int = 1, C: int = 3, H: int = 34) -> DataflowGraph:
    b = GB("conv3")
    x = b.input("x", (N, C, H, H))
    h = b.conv(x, 32, 3)
    h = b.conv(h, 32, 3)
    h = b.conv(h, 64, 3)
    b.mark_output(h)
    return b.g


def feed_forward(B: int = 128, D: int = 512) -> DataflowGraph:
    b = GB("feed_forward")
    x = b.input("x", (B, D))
    h = b.fc(x, 4 * D)
    h = b.gelu(h)
    o = b.fc(h, D)
    b.mark_output(o)
    return b.g


def multi_head_attention(S: int = 128, D: int = 256) -> DataflowGraph:
    """Single-head attention core (the multi-head loop is the batch ring):
    x feeds Q/K/V projections (SPMC), Q@K^T needs a transpose (order
    violation), softmax is the reduction producer."""
    b = GB("mha")
    x = b.input("x", (S, D))
    q = b.fc(x, D)
    k = b.fc(x, D)
    v = b.fc(x, D)
    kt = b.transpose(k)
    s = b.matmul(q, kt)
    s = b.scale(s, 1.0 / math.sqrt(D))
    p = b.softmax(s)
    att = b.matmul(p, v)
    o = b.fc(att, D)
    b.mark_output(o)
    return b.g


# --------------------------------------------------------------------------
# DNN models (Tables III/IV)
# --------------------------------------------------------------------------


def resnet18(H: int = 32) -> DataflowGraph:
    b = GB(f"resnet18_{H}")
    x = b.input("x", (1, 3, H, H))
    if H >= 224:
        h = b.conv(x, 64, 7, stride=2, pad=3)
        h = b.maxpool(h, 2)
    else:
        h = b.conv(x, 64, 3)
    for stage, (c, blocks) in enumerate([(64, 2), (128, 2), (256, 2), (512, 2)]):
        for blk in range(blocks):
            stride = 2 if (stage > 0 and blk == 0) else 1
            inp = h
            h1 = b.conv(inp, c, 3, stride=stride)
            h2 = b.conv(h1, c, 3, relu=False)
            if stride != 1 or b.shape[inp][1] != c:
                skip = b.conv(inp, c, 1, stride=stride, pad=0, relu=False)
            else:
                skip = inp
            h = b.relu(b.add(h2, skip))
    h = b.global_avgpool(h)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def vgg16(H: int = 32) -> DataflowGraph:
    b = GB(f"vgg16_{H}")
    x = b.input("x", (1, 3, H, H))
    h = x
    for c, reps in [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]:
        for _ in range(reps):
            h = b.conv(h, c, 3)
        h = b.maxpool(h, 2)
    h = b.flatten(h)
    h = b.fc(h, 512, relu=True)
    h = b.fc(h, 512, relu=True)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def mobilenet(H: int = 32) -> DataflowGraph:
    b = GB(f"mobilenet_{H}")
    x = b.input("x", (1, 3, H, H))
    h = b.conv(x, 32, 3, stride=2 if H >= 224 else 1)
    plan = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + \
           [(512, 1)] * 5 + [(1024, 2), (1024, 1)]
    for c, s in plan:
        h = b.conv(h, 0, 3, stride=s, depthwise=True)
        h = b.conv(h, c, 1, pad=0)
    h = b.global_avgpool(h)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def zfnet(H: int = 224) -> DataflowGraph:
    b = GB(f"zfnet_{H}")
    x = b.input("x", (1, 3, H, H))
    h = b.conv(x, 96, 7, stride=2, pad=3)
    h = b.maxpool(h, 2)
    h = b.conv(h, 256, 5, stride=2, pad=2)
    h = b.maxpool(h, 2)
    h = b.conv(h, 384, 3)
    h = b.conv(h, 384, 3)
    h = b.conv(h, 256, 3)
    h = b.maxpool(h, 2)
    h = b.flatten(h)
    h = b.fc(h, 4096, relu=True)
    h = b.fc(h, 4096, relu=True)
    o = b.fc(h, 1000)
    b.mark_output(o)
    return b.g


def yolo_tiny(H: int = 384, W: int = 1280) -> DataflowGraph:
    b = GB("yolo")
    x = b.input("x", (1, 3, H, W))
    h = x
    c = 16
    for i in range(6):
        h = b.conv(h, c, 3)
        h = b.maxpool(h, 2)
        c = min(c * 2, 512)
    h = b.conv(h, 512, 3)
    h = b.conv(h, 256, 1, pad=0)
    o = b.conv(h, 255, 1, pad=0, relu=False)
    b.mark_output(o)
    return b.g


def gpt2_block(S: int = 128, D: int = 1024) -> DataflowGraph:
    """One GPT-2 block: LN -> MHA(+skip) -> LN -> FF(+skip) — the repeated
    unit of the paper's GPT-2 accelerator."""
    b = GB("gpt2_block")
    x = b.load(b.input("x", (S, D)))
    # attention path (LN folded into projections for graph purposes)
    q = b.fc(x, D)
    k = b.fc(x, D)
    v = b.fc(x, D)
    kt = b.transpose(k)
    s = b.scale(b.matmul(q, kt), 1.0 / math.sqrt(D // 16))
    p = b.softmax(s)
    att = b.matmul(p, v)
    proj = b.fc(att, D)
    h = b.add(proj, x)              # skip 1: SPMC on x
    # mlp path
    f = b.fc(h, 4 * D)
    f = b.gelu(f)
    f = b.fc(f, D)
    o = b.add(f, h)                 # skip 2: SPMC on h
    b.mark_output(o)
    return b.g


# --------------------------------------------------------------------------
# Architecture configs -> dataflow graphs (the batch-compile grid)
# --------------------------------------------------------------------------


def _attn_block(b: GB, x: str, D: int, hd: int, enc: str | None = None) -> str:
    """Self- (or, given ``enc``, cross-) attention + projection + residual."""
    q = b.fc(x, D)
    kv_src = enc if enc is not None else x
    k = b.fc(kv_src, D)
    v = b.fc(kv_src, D)
    kt = b.transpose(k)
    s = b.scale(b.matmul(q, kt), 1.0 / math.sqrt(max(hd, 1)))
    p = b.softmax(s)
    att = b.matmul(p, v)
    proj = b.fc(att, D)
    return b.add(proj, x)                  # residual: SPMC on x


def _ffn_block(b: GB, x: str, cfg) -> str:
    """(Gated) FFN + residual; MoE adds the router dispatch/combine
    side-chain so expert traffic shows up in the dataflow."""
    D = cfg.d_model
    if cfg.glu:
        gate = b.gelu(b.fc(x, cfg.d_ff))
        up = b.fc(x, cfg.d_ff)
        mixed = b.add(gate, up)            # gating proxy (same dataflow shape)
    else:
        mixed = b.gelu(b.fc(x, cfg.d_ff))
    down = b.fc(mixed, D)
    out = b.add(down, x)
    if cfg.moe is not None:
        router = b.softmax(b.fc(x, cfg.moe.num_experts))
        combined = b.fc(router, D)         # combine back into the stream
        out = b.add(out, combined)
    return out


def _recurrent_block(b: GB, x: str, D: int, expand: int = 2) -> str:
    """SSM / RG-LRU style block: in-proj + gate, state mixing, out-proj,
    residual.  The chunked recurrence appears as a dense state-mix task —
    the dataflow (streams, reuse, reductions) is what the compiler sees."""
    d_in = D * max(expand, 1)
    u = b.fc(x, d_in)
    gate = b.gelu(b.fc(x, d_in))
    mix = b.fc(u, d_in)
    gated = b.add(mix, gate)
    out = b.fc(gated, D)
    return b.add(out, x)


def arch_block_graph(cfg, S: int = 64) -> DataflowGraph:
    """One representative backbone block of ``cfg`` (an
    :class:`repro.configs.base.ArchConfig`) as a CODO dataflow graph.

    This is the unit the batch compiler drives across the opt1..opt5 grid:
    real model dims (d_model/d_ff/experts), one block per distinct kind in
    the architecture's pattern.  Multimodal prefixes are folded into ``S``
    upstream — the dataflow structure is identical.
    """
    b = GB(cfg.name.replace("-", "_").replace(".", "_"))
    D = cfg.d_model
    x = b.load(b.input("x", (S, D)))
    h = x
    if cfg.ssm is not None:
        h = _recurrent_block(b, h, D, cfg.ssm.expand)
    elif "rglru" in cfg.block_pattern:      # hybrid: recurrent + local attn
        h = _recurrent_block(b, h, D)
        h = _attn_block(b, h, D, cfg.hd)
    else:
        h = _attn_block(b, h, D, cfg.hd)
    if cfg.enc_dec:                         # whisper-style cross attention
        enc = b.load(b.input("enc_out", (min(cfg.enc_frames, 128), D)))
        h = _attn_block(b, h, D, cfg.hd, enc=enc)
    if cfg.ssm is None:
        h = _ffn_block(b, h, cfg)
    b.mark_output(h)
    return b.g


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

KERNEL_BENCHES = {
    "atax": atax, "gesummv": gesummv, "gemm": gemm, "mvt": mvt, "3mm": three_mm,
    "residual_mlp": residual_mlp, "autoencoder": autoencoder,
    "residual_block": residual_block, "dws_conv_block": dws_conv_block,
    "conv3_block": conv3_block, "feed_forward": feed_forward,
    "multi_head_attention": multi_head_attention,
}

DNN_BENCHES = {
    "resnet18": resnet18, "vgg16": vgg16, "mobilenet": mobilenet,
    "zfnet": zfnet, "yolo": yolo_tiny, "gpt2_block": gpt2_block,
}


def random_inputs(graph: DataflowGraph, seed: int = 0) -> dict:
    """Fan-in-normalized random inputs/weights: deep CNN oracles stay O(1)
    in magnitude so fp32 comparisons remain meaningful."""
    import jax.numpy as jnp  # lazy: graph building stays jax-free

    rng = np.random.default_rng(seed)
    env = {}
    for buf in graph.buffers.values():
        if buf.kind == "input":
            env[buf.name] = jnp.asarray(
                rng.standard_normal(buf.shape), jnp.float32)
        elif buf.kind == "weight":
            fan_in = int(np.prod(buf.shape[1:])) if len(buf.shape) > 1 \
                else buf.shape[0]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            env[buf.name] = jnp.asarray(
                rng.standard_normal(buf.shape) * std, jnp.float32)
    return env
