"""Model assembly for every assigned architecture family.

One entry-point pair per execution mode:

* ``init_params(cfg, key)``            — parameter pytree (layer stacks are
  *stacked over pattern groups* so the forward is a ``lax.scan`` — HLO size
  stays O(1) in depth, which keeps 88-layer dry-runs compilable).
* ``loss_fn(params, batch, cfg)``      — next-token CE (training cells).
* ``prefill(params, batch, cfg)``      — full forward, last-position logits.
* ``decode_step(params, tokens, cache, cfg)`` — one new token against the
  cache/state (decode cells).  The cache pytree mirrors the param group
  structure, so one ``lax.scan`` threads (params, cache) together.

Families: ``dense`` (gemma/qwen/starcoder2/mistral/gpt2), ``moe`` (mixtral,
moonshot), ``ssm`` (mamba2), ``hybrid`` (recurrentgemma rglru:rglru:attn),
``audio`` (whisper enc-dec, frame embeddings stubbed), ``vlm`` (internvl,
patch embeddings stubbed).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import BATCH, shard_hint
from . import rglru as rg
from . import ssm as ssm_mod
from .layers import (Params, apply_norm, attention_decode, attention_train,
                     cross_attention, dense_init, embed, encode_kv, ffn_apply,
                     init_attention, init_embed, init_ffn, init_moe, linear,
                     moe_apply, norm_init, unembed)

# --------------------------------------------------------------------------
# Per-block init/apply
# --------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    if kind == "attn":
        p = {"norm1": norm_init(cfg.d_model, cfg.norm, dt),
             "attn": init_attention(ks[0], cfg),
             "norm2": norm_init(cfg.d_model, cfg.norm, dt)}
        p["mlp"] = init_moe(ks[1], cfg) if cfg.moe else init_ffn(ks[1], cfg)
        return p
    if kind == "rglru":
        return {"rec": rg.init_rglru_block(ks[0], cfg),
                "norm2": norm_init(cfg.d_model, cfg.norm, dt),
                "mlp": init_ffn(ks[1], cfg)}
    if kind == "ssm":
        return {"ssm": ssm_mod.init_ssm_block(ks[0], cfg)}
    if kind == "xattn":  # whisper decoder block
        return {"norm1": norm_init(cfg.d_model, cfg.norm, dt),
                "attn": init_attention(ks[0], cfg),
                "norm_x": norm_init(cfg.d_model, cfg.norm, dt),
                "xattn": init_attention(ks[1], cfg),
                "norm2": norm_init(cfg.d_model, cfg.norm, dt),
                "mlp": init_ffn(ks[2], cfg)}
    raise ValueError(kind)


def _mlp(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.moe:
        return moe_apply(p, x, cfg)
    return ffn_apply(p, x, cfg)


def _attn_window(cfg: ArchConfig, kind: str) -> int:
    if kind not in ("attn",):
        return 0
    if len(cfg.block_pattern) > 1:          # hybrid local-attn blocks
        return cfg.local_window
    return cfg.window


def _block_train(p: Params, x: jax.Array, cfg: ArchConfig, kind: str,
                 *, enc_out=None) -> jax.Array:
    if kind == "attn":
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attention_train(p["attn"], h, cfg, causal=True,
                                window=_attn_window(cfg, kind))
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + _mlp(p["mlp"], h, cfg)
    if kind == "rglru":
        x = rg.rglru_block_train(p["rec"], x, cfg)
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + ffn_apply(p["mlp"], h, cfg)
    if kind == "ssm":
        return ssm_mod.ssm_block_train(p["ssm"], x, cfg)
    if kind == "xattn":
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attention_train(p["attn"], h, cfg, causal=True)
        h = apply_norm(p["norm_x"], x, cfg.norm)
        ekv = encode_kv(p["xattn"], enc_out, cfg)
        x = x + cross_attention(p["xattn"], h, ekv, cfg)
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + ffn_apply(p["mlp"], h, cfg)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Parameter assembly (stacked pattern groups)
# --------------------------------------------------------------------------


def _group_counts(cfg: ArchConfig) -> tuple[int, int]:
    plen = len(cfg.block_pattern)
    return cfg.n_layers // plen, cfg.n_layers % plen


def init_params(cfg: ArchConfig, key) -> Params:
    n_groups, leftover = _group_counts(cfg)
    keys = jax.random.split(key, n_groups + leftover + 4)

    def group_params(k):
        sub = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}_{kind}": _init_block(sk, cfg, kind)
                for i, (kind, sk) in enumerate(zip(cfg.block_pattern, sub))}

    p: Params = {
        "embed": init_embed(keys[-1], cfg),
        "tail": {f"t{i}": _init_block(keys[n_groups + i], cfg, cfg.block_pattern[i])
                 for i in range(leftover)},
        "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.jdtype),
    }
    if cfg.enc_dec:
        ek = jax.random.split(keys[-3], cfg.n_enc_layers)
        enc_blocks = [{"norm1": norm_init(cfg.d_model, cfg.norm, cfg.jdtype),
                       "attn": init_attention(ek[i], cfg),
                       "norm2": norm_init(cfg.d_model, cfg.norm, cfg.jdtype),
                       "mlp": init_ffn(ek[i], cfg)}
                      for i in range(cfg.n_enc_layers)]
        p["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks)
        p["enc_norm"] = norm_init(cfg.d_model, cfg.norm, cfg.jdtype)
        dk = jax.random.split(keys[-4], cfg.n_layers)
        dec = [_init_block(dk[i], cfg, "xattn") for i in range(cfg.n_layers)]
        p["decoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dec)
        p["groups"] = {}
    else:
        p["groups"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[group_params(keys[i]) for i in range(n_groups)]) if n_groups else {}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[-2], cfg.d_model, cfg.padded_vocab, cfg.jdtype)
    return p


def param_shapes(cfg: ArchConfig) -> Any:
    """Shape/dtype pytree without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_count(params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# Forward (training / prefill-scoring)
# --------------------------------------------------------------------------


def _encode(params: Params, frames: jax.Array, cfg: ArchConfig,
            remat: bool) -> jax.Array:
    def body(h, lp):
        hh = apply_norm(lp["norm1"], h, cfg.norm)
        h = h + attention_train(lp["attn"], hh, cfg, causal=False)
        hh = apply_norm(lp["norm2"], h, cfg.norm)
        return h + ffn_apply(lp["mlp"], hh, cfg), None

    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, frames, params["encoder"])
    return apply_norm(params["enc_norm"], h, cfg.norm)


# REPRO_REMAT_POLICY=dots  save matmul outputs across the remat boundary:
# the backward replay skips re-gathering + re-computing every weight matmul
# (one fewer FSDP all-gather sweep) at the cost of storing dot outputs.
_REMAT_POLICY = __import__("os").environ.get("REPRO_REMAT_POLICY", "")


def _checkpoint(fn):
    if _REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# optimization_barrier has no differentiation rule (through jax 0.4.x), so
# give it one: identity VJP with the barrier applied to the cotangent too —
# the backward pass needs the same hoisting fence as the forward.
@jax.custom_vjp
def _diffable_barrier(x):
    return jax.lax.optimization_barrier(x)


def _diffable_barrier_fwd(x):
    return _diffable_barrier(x), None


def _diffable_barrier_bwd(_res, g):
    return (jax.lax.optimization_barrier(g),)


_diffable_barrier.defvjp(_diffable_barrier_fwd, _diffable_barrier_bwd)


def _backbone(params: Params, x: jax.Array, cfg: ArchConfig, *,
              remat: bool, enc_out=None) -> jax.Array:
    if cfg.enc_dec:
        def dec_body(h, lp):
            h = shard_hint(h, BATCH, "model", None)
            return shard_hint(_block_train(lp, h, cfg, "xattn", enc_out=enc_out),
                              BATCH, "model", None), None
        body = _checkpoint(dec_body) if remat else dec_body
        x, _ = jax.lax.scan(body, x, params["decoder"])
        return x

    n_groups, leftover = _group_counts(cfg)

    def group_body(h, gp):
        # carry arrives sequence-sharded (Megatron-SP posture: the remat-
        # saved per-layer activation is (B/dp, S/tp, D)); blocks gather the
        # seq dim internally where attention needs it.  The barrier pins
        # the bf16->f32 norm convert inside the loop — without it XLA
        # hoists the convert and materializes an f32 copy of the whole
        # saved-carry stack (2x remat memory).
        h = _diffable_barrier(h)
        h = shard_hint(h, BATCH, "model", None)
        for i, kind in enumerate(cfg.block_pattern):
            h = _block_train(gp[f"b{i}_{kind}"], h, cfg, kind)
        return shard_hint(h, BATCH, "model", None), None

    body = _checkpoint(group_body) if remat else group_body
    if n_groups:
        x, _ = jax.lax.scan(body, x, params["groups"])
    for i in range(leftover):
        x = _block_train(params["tail"][f"t{i}"], x, cfg, cfg.block_pattern[i])
    return x


def forward_hidden(params: Params, batch: dict, cfg: ArchConfig,
                   remat: bool = True) -> jax.Array:
    """batch -> final-norm hidden states (B, S_text, d_model)."""
    x = shard_hint(embed(params["embed"], batch["tokens"], cfg), BATCH, None, None)
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(params, batch["frames"], cfg, remat)
    if cfg.n_patches:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    x = _backbone(params, x, cfg, remat=remat, enc_out=enc_out)
    if cfg.n_patches:
        x = x[:, cfg.n_patches:]
    return apply_norm(params["final_norm"], x, cfg.norm)


def forward(params: Params, batch: dict, cfg: ArchConfig,
            remat: bool = True) -> jax.Array:
    """batch -> logits (B, S_text, padded_vocab)."""
    x = forward_hidden(params, batch, cfg, remat)
    logits = unembed(params["embed"], params.get("lm_head"), x, cfg)
    return shard_hint(logits, BATCH, None, "model")


def _loss_chunk(s: int, target: int = 2048) -> int:
    """Largest divisor of ``s`` not exceeding ``target``."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


def loss_fn(params: Params, batch: dict, cfg: ArchConfig,
            remat: bool = True, loss_chunk: int = 2048) -> jax.Array:
    """Next-token CE with **sequence-chunked** logits: the (B, S, vocab)
    f32 logit tensor never materializes — each chunk computes its unembed
    matmul, reduces to per-token NLL, and is rematerialized on backward.
    (Without this, a 150k-vocab 4k-seq step needs tens of GiB of logits —
    the same access-count-mismatch lesson as the paper's Fig. 5, applied
    to the loss: reduce within the chunk, emit only the accumulator.)"""
    x = forward_hidden(params, batch, cfg, remat=remat)
    labels = batch["labels"]
    B, S, D = x.shape
    c = _loss_chunk(S, loss_chunk)
    nc = S // c
    xs = x.reshape(B, nc, c, D).swapaxes(0, 1)          # (nc, B, c, D)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(carry, inp):
        tot, cnt = carry
        xc, lc = inp
        logits = unembed(params["embed"], params.get("lm_head"), xc, cfg)
        logits = shard_hint(logits, BATCH, None, "model").astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (lc >= 0).astype(jnp.float32)
        return (tot + ((logz - gold) * mask).sum(), cnt + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk_nll, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def prefill(params: Params, batch: dict, cfg: ArchConfig) -> jax.Array:
    """Prefill scoring: full forward, last-position logits."""
    return forward(params, batch, cfg, remat=False)[:, -1]


# --------------------------------------------------------------------------
# Decode (serve_step)
# --------------------------------------------------------------------------


def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    """Ring-buffer length: windowed archs cap the KV cache at the window."""
    if len(cfg.block_pattern) > 1 and "attn" in cfg.block_pattern:
        return min(seq_len, cfg.local_window)
    if cfg.window:
        return min(seq_len, cfg.window)
    return seq_len


def _init_block_cache(cfg: ArchConfig, batch: int, C: int, kind: str) -> Params:
    dt = cfg.jdtype
    if kind in ("attn", "xattn"):
        shape = (batch, C, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if kind == "rglru":
        return {"h": jnp.zeros((batch, cfg.d_model), jnp.float32),
                "conv": jnp.zeros((batch, 3, cfg.d_model), dt)}
    if kind == "ssm":
        s = cfg.ssm
        d_in = cfg.d_model * s.expand
        nheads = d_in // s.head_dim
        return {"state": jnp.zeros((batch, nheads, s.head_dim, s.d_state),
                                   jnp.float32),
                "conv": jnp.zeros((batch, s.conv_width - 1,
                                   d_in + 2 * s.d_state), dt)}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int) -> Params:
    n_groups, leftover = _group_counts(cfg)
    C = cache_len_for(cfg, seq_len)
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.enc_dec:
        one = {"k": jnp.zeros((cfg.n_layers, batch, C, cfg.n_kv_heads, cfg.hd),
                              cfg.jdtype)}
        one["v"] = one["k"]
        cache["layers"] = one
        cache["enc_out"] = jnp.zeros((batch, cfg.enc_frames, cfg.d_model),
                                     cfg.jdtype)
        return cache
    group = {f"b{i}_{kind}": _init_block_cache(cfg, batch, C, kind)
             for i, kind in enumerate(cfg.block_pattern)}
    if n_groups:
        cache["groups"] = jax.tree.map(
            lambda x: jnp.zeros((n_groups,) + x.shape, x.dtype), group)
    cache["tail"] = {f"t{i}": _init_block_cache(cfg, batch, C,
                                                cfg.block_pattern[i])
                     for i in range(leftover)}
    return cache


def _block_decode(p: Params, x, cfg: ArchConfig, kind: str, bc: Params,
                  pos, enc_out=None):
    """Returns (x, updated block cache)."""
    if kind == "attn":
        window = _attn_window(cfg, kind)
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, kc, vc = attention_decode(p["attn"], h, cfg, k_cache=bc["k"],
                                     v_cache=bc["v"], pos=pos, window=window)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + _mlp(p["mlp"], h, cfg), {"k": kc, "v": vc}
    if kind == "rglru":
        x, hs, cb = rg.rglru_block_decode(p["rec"], x, cfg, h_state=bc["h"],
                                          conv_buf=bc["conv"])
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + ffn_apply(p["mlp"], h, cfg), {"h": hs, "conv": cb}
    if kind == "ssm":
        x, st, cb = ssm_mod.ssm_block_decode(p["ssm"], x, cfg, state=bc["state"],
                                             conv_buf=bc["conv"])
        return x, {"state": st, "conv": cb}
    if kind == "xattn":
        h = apply_norm(p["norm1"], x, cfg.norm)
        y, kc, vc = attention_decode(p["attn"], h, cfg, k_cache=bc["k"],
                                     v_cache=bc["v"], pos=pos, window=0)
        x = x + y
        h = apply_norm(p["norm_x"], x, cfg.norm)
        ekv = encode_kv(p["xattn"], enc_out, cfg)
        x = x + cross_attention(p["xattn"], h, ekv, cfg)
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + ffn_apply(p["mlp"], h, cfg), {"k": kc, "v": vc}
    raise ValueError(kind)


_DECODE_WSTAT = __import__("os").environ.get("REPRO_DECODE_WSTAT", "0") == "1"


def decode_step(params: Params, tokens: jax.Array, cache: Params,
                cfg: ArchConfig):
    """tokens: (B,) int32.  Returns (logits (B, vocab), new cache)."""
    pos = cache["pos"]
    x = embed(params["embed"], tokens[:, None], cfg,
              positions=pos[None, None] if cfg.pos == "learned" else None)
    if _DECODE_WSTAT:
        # §Perf H3 — weight-stationary decode: shard the hidden's model dim
        # over `data` so FSDP-sharded weights contract locally and only the
        # tiny (B,1,·) partial sums cross the mesh, instead of per-step
        # whole-weight all-gathers.
        x = shard_hint(x, None, None, "data")
    new_cache = {"pos": pos + 1}

    if cfg.enc_dec:
        enc_out = cache["enc_out"]

        def body(h, xs):
            lp, bc = xs
            h, up = _block_decode(lp, h, cfg, "xattn", bc, pos, enc_out)
            return h, up

        x, ups = jax.lax.scan(body, x, (params["decoder"], cache["layers"]))
        new_cache["layers"] = ups
        new_cache["enc_out"] = enc_out
    else:
        n_groups, leftover = _group_counts(cfg)
        if n_groups:
            def body(h, xs):
                gp, gc = xs
                nc = {}
                for i, kind in enumerate(cfg.block_pattern):
                    key = f"b{i}_{kind}"
                    h, nc[key] = _block_decode(gp[key], h, cfg, kind, gc[key], pos)
                return h, nc

            x, new_groups = jax.lax.scan(
                body, x, (params["groups"], cache["groups"]))
            new_cache["groups"] = new_groups
        new_tail = {}
        for i in range(leftover):
            kind = cfg.block_pattern[i]
            x, new_tail[f"t{i}"] = _block_decode(
                params["tail"][f"t{i}"], x, cfg, kind, cache["tail"][f"t{i}"], pos)
        new_cache["tail"] = new_tail

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], params.get("lm_head"), x[:, 0], cfg)
    return shard_hint(logits, BATCH, "model"), new_cache


# --------------------------------------------------------------------------
# CODO traced form (ROADMAP item 4): one attention head over batched
# (BH, S, hd) operands, expressed in the dataflow-frontend vocabulary so
# ``codo.compile`` sees the matmul -> scale -> softmax -> matmul chain the
# flashattn kernel pattern routes.
# --------------------------------------------------------------------------


def mha_batched_fn(q, k, v):
    """Batched single-head attention ``softmax(q kᵀ / √hd) v``; operands
    are ``(BH, S, hd)`` (heads folded into the leading batch dim)."""
    import math

    from ..core import frontend as F
    hd = q.shape[-1]
    kt = F.transpose(k)                       # (BH, hd, S)
    s = F.scale(F.matmul(q, kt), 1.0 / math.sqrt(hd))
    p = F.softmax(s)
    return F.matmul(p, v)
