"""Transformer layer library: norms, RoPE, GQA/MQA attention (blockwise,
sliding-window, KV-cache), gated/plain FFN, MoE, embeddings.

Everything is functional (params are plain dict pytrees) so the same code
paths run under jit / shard_map / eval_shape.  Attention for long
sequences is *blockwise with online softmax* (the flash-attention
recurrence) implemented in pure jnp via nested ``lax.scan`` — the memory-
bounded oracle; the Pallas kernel in ``kernels/flashattn`` implements the
same recurrence for the TPU target and is validated against this.

Dataflow-compiler tie-in: the online-softmax recurrence *is* CODO's
reduction-operation rewriting (Fig. 5) applied to the softmax/PV chain —
the KV axis is the reduction dim, the running (m, l, acc) triple is the
temporary accumulator, and the rescaled tile is emitted exactly once.
"""

from __future__ import annotations

import math
import os
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

Params = dict

# --- §Perf hillclimb switches (read at trace time; set by launch/dryrun) ---
# REPRO_ATTN_OPT=1     repeat KV to query heads and shard the merged head
#                      dim over `model` (GQA under TP: trades a small KV
#                      repeat for un-replicated attention compute).
# REPRO_ATTN_SEQSHARD=1  additionally shard q-blocks over `model`
#                      (sequence-parallel attention for head-starved archs).
_ATTN_OPT = os.environ.get("REPRO_ATTN_OPT", "0") == "1"
_ATTN_SEQSHARD = os.environ.get("REPRO_ATTN_SEQSHARD", "0") == "1"
# REPRO_BF16_BWD=1  cast matmul cotangents to the weight dtype before the
#                   backward dots: keeps weight all-gathers and activation-
#                   grad all-reduces in bf16 instead of f32 (halves the
#                   dominant collective payloads; standard mixed-precision
#                   training practice).
_BF16_BWD = os.environ.get("REPRO_BF16_BWD", "0") == "1"
# REPRO_MOE_BF16DISPATCH=1  run the MoE dispatch/one-hot einsums in bf16:
#                   the dispatch matrix is {0,1}-valued (bf16-exact) and
#                   each token lands in exactly one capacity slot, so
#                   dispatch is lossless; only the f32 combine weights
#                   stay f32.  Halves the dominant MoE dispatch traffic.
_MOE_BF16 = os.environ.get("REPRO_MOE_BF16DISPATCH", "0") == "1"
# REPRO_MOE_CHUNK=N  token-chunk size of the MoE dispatch scan.  Each chunk
#                   re-reads the full expert weight bank, so fewer/larger
#                   chunks trade dispatch-tensor size for weight traffic.
_MOE_CHUNK = int(os.environ.get("REPRO_MOE_CHUNK", "0"))


@jax.custom_vjp
def dot_bf16bwd(x, w):
    return x @ w


def _dot_fwd(x, w):
    return x @ w, (x, w)


def _dot_bwd(res, g):
    x, w = res
    gb = g.astype(w.dtype)
    dx = jnp.einsum("...f,df->...d", gb, w)
    dw = jnp.einsum("...d,...f->df", x, gb).astype(w.dtype)
    return dx.astype(x.dtype), dw


dot_bf16bwd.defvjp(_dot_fwd, _dot_bwd)


# --------------------------------------------------------------------------
# Initializers / linear
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: float | None = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = dot_bf16bwd(x, p["w"]) if _BF16_BWD else x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    var = (xf ** 2).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def activation(x: jax.Array, act: str) -> jax.Array:
    if act in ("silu", "swish"):
        return jax.nn.silu(x)
    if act in ("gelu", "geglu"):
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    ang = ang[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, d_model: int | None = None) -> Params:
    d = d_model or cfg.d_model
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    return {
        "wq": dense_init(ks[0], d, cfg.q_dim, dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, cfg.kv_dim, dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, cfg.kv_dim, dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.q_dim, d, dt),
    }


def _qkv(p: Params, x: jax.Array, cfg: ArchConfig):
    B, S, _ = x.shape
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.hd)
    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool, window: int = 0,
                        q_offset: int = 0,
                        block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp.

    q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd) with Hq = G·Hkv.
    Nested scans over (q blocks × kv blocks) keep live memory at
    O(B·H·bq·bk) regardless of sequence length.
    """
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if _ATTN_OPT and Hq > Hkv:
        # repeat KV to query heads: the merged head dim then shards over
        # `model` regardless of the (small) KV-head count — attention
        # compute stops replicating across the TP axis (§Perf H1)
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        Hkv = Hq
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)

    if _ATTN_SEQSHARD:
        # §Perf H2 (revised): sharding the *scanned* q-block axis makes
        # GSPMD gather it (a scan is sequential) — instead group q blocks
        # (outer scanned, inner P-parallel) and shard the inner group dim
        # over `model`: each device owns nq/P q-blocks per outer step.
        return _blockwise_seqshard(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, bq=bq, bk=bk)

    qb = q.reshape(B, nq, bq, Hkv, G, hd).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,Hkv,G,bq,hd)
    kb = k.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 3, 2, 4)        # (nk,B,Hkv,bk,hd)
    vb = v.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 3, 2, 4)
    if _ATTN_OPT:
        from ..distributed.sharding import BATCH, shard_hint
        qb = shard_hint(qb, None, BATCH, "model", None, None, None)
        kb = shard_hint(kb, None, BATCH, "model", None, None)
        vb = shard_hint(vb, None, BATCH, "model", None, None)

    q_pos_base = jnp.arange(bq)
    k_pos_base = jnp.arange(bk)

    def q_block(qi, qtile):
        q_pos = q_offset + qi * bq + q_pos_base                  # (bq,)

        @jax.checkpoint
        def kv_block(carry, inp):
            m, l, acc = carry
            ki, ktile, vtile = inp
            k_pos = ki * bk + k_pos_base                         # (bk,)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qtile.astype(jnp.float32),
                           ktile.astype(jnp.float32)) * scale
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vtile.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    q_block = jax.checkpoint(q_block)   # bwd re-streams KV per q-block
    _, out = jax.lax.scan(
        lambda _c, inp: (None, q_block(*inp)), None, (jnp.arange(nq), qb))
    # out: (nq, B, Hkv, G, bq, hd) -> (B, Sq, Hq, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def _blockwise_seqshard(q, k, v, *, causal: bool, window: int,
                        q_offset: int, bq: int, bk: int) -> jax.Array:
    """Sequence-parallel blockwise attention: q blocks grouped (outer
    scanned × inner P-parallel), the inner group dim sharded over `model`.
    Numerically identical to blockwise_attention."""
    from ..distributed.sharding import BATCH, shard_hint

    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    nq, nk = Sq // bq, Sk // bk
    try:
        mesh = jax.sharding.get_abstract_mesh()
        P = mesh.shape.get("model", 1) if mesh is not None else 1
    except Exception:
        P = 1
    if nq % max(P, 1) != 0 or P <= 1:
        P = 1
    no = nq // P

    # q blocks: index = o*P + p  (o scanned, p parallel/sharded)
    qb = q.reshape(B, no, P, bq, Hkv, G, hd).transpose(1, 2, 0, 4, 5, 3, 6)
    # (no, P, B, Hkv, G, bq, hd)
    qb = shard_hint(qb, None, "model", BATCH, None, None, None, None)
    kb = k.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 3, 2, 4)

    q_pos_base = jnp.arange(bq)
    k_pos_base = jnp.arange(bk)

    def outer(oi, qtile):                      # qtile: (P,B,Hkv,G,bq,hd)
        q_pos = (q_offset + (oi * P + jnp.arange(P)[:, None]) * bq
                 + q_pos_base[None, :])        # (P, bq)

        @jax.checkpoint
        def kv_block(carry, inp):
            m, l, acc = carry
            ki, ktile, vtile = inp
            k_pos = ki * bk + k_pos_base
            s = jnp.einsum("pbhgqd,bhkd->pbhgqk", qtile.astype(jnp.float32),
                           ktile.astype(jnp.float32)) * scale
            mask = jnp.ones((P, bq, bk), bool)
            if causal:
                mask &= q_pos[:, :, None] >= k_pos[None, None, :]
            if window:
                mask &= q_pos[:, :, None] - k_pos[None, None, :] < window
            s = jnp.where(mask[:, None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p_.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "pbhgqk,bhkd->pbhgqd", p_, vtile.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((P, B, Hkv, G, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((P, B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((P, B, Hkv, G, bq, hd), jnp.float32)
        m0, l0, a0 = (shard_hint(t, "model", BATCH, None, None, None)
                      if t.ndim == 5 else
                      shard_hint(t, "model", BATCH, None, None, None, None)
                      for t in (m0, l0, a0))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    outer = jax.checkpoint(outer)
    _, out = jax.lax.scan(
        lambda _c, inp: (None, outer(*inp)), None, (jnp.arange(no), qb))
    # (no, P, B, Hkv, G, bq, hd) -> (B, Sq, Hq, hd)
    out = out.transpose(2, 0, 1, 5, 3, 4, 6).reshape(B, Sq, Hq, hd)
    return out.astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   q_positions=None, k_positions=None) -> jax.Array:
    """Unblocked reference (small S / decode).  Same signature semantics."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qp = q_positions if q_positions is not None else jnp.arange(Sq)
    kp = k_positions if k_positions is not None else jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window:
        mask &= qp[:, None] - kp[None, :] < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def attention_train(p: Params, x: jax.Array, cfg: ArchConfig, *,
                    causal: bool = True, window: int = 0,
                    positions: jax.Array | None = None) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if cfg.pos == "rope":
        pos = positions if positions is not None else jnp.arange(S)[None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if S > 2048:
        o = blockwise_attention(q, k, v, causal=causal, window=window)
    else:
        o = full_attention(q, k, v, causal=causal, window=window)
    return linear(p["wo"], o.reshape(B, S, cfg.q_dim))


def init_kv_cache(cfg: ArchConfig, batch: int, cache_len: int, layers: int,
                  dtype=None) -> Params:
    dt = dtype or cfg.jdtype
    shape = (layers, batch, cache_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def attention_decode(p: Params, x: jax.Array, cfg: ArchConfig, *,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, window: int = 0):
    """One-token decode.  x: (B, 1, D); caches: (B, C, Hkv, hd); pos: ()
    current absolute position.  Returns (y, k_cache, v_cache).

    With a sliding window the cache is a ring buffer of length C=window;
    otherwise C >= seq_len and slot = pos.
    """
    B, _, _ = x.shape
    C = k_cache.shape[1]
    q, k, v = _qkv(p, x, cfg)
    if cfg.pos == "rope":
        q = apply_rope(q, pos[None, None], cfg.rope_theta)
        k = apply_rope(k, pos[None, None], cfg.rope_theta)
    slot = jnp.where(window > 0, pos % jnp.maximum(C, 1), pos)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
    # positions held in each cache slot (ring-aware)
    slots = jnp.arange(C)
    if window > 0:
        base = jnp.maximum(pos + 1 - C, 0)
        cand = slots + (pos + 1 - C // 2)  # not used; compute exact below
        # absolute position stored in slot s: the largest p <= pos with p % C == s
        kpos = pos - ((pos - slots) % C)
        valid = kpos >= jnp.maximum(pos - window + 1, 0)
        kpos = jnp.where(valid, kpos, -1)
    else:
        kpos = jnp.where(slots <= pos, slots, -1)
    qg = q.reshape(B, 1, cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads, cfg.hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(cfg.hd)
    s = jnp.where((kpos >= 0)[None, None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.q_dim).astype(x.dtype)
    return linear(p["wo"], o), k_cache, v_cache


def init_cross_attention(key, cfg: ArchConfig) -> Params:
    return init_attention(key, cfg)


def cross_attention(p: Params, x: jax.Array, enc_kv: tuple[jax.Array, jax.Array],
                    cfg: ArchConfig) -> jax.Array:
    """Decoder->encoder attention; enc_kv precomputed (B, F, Hkv, hd)."""
    B, S, _ = x.shape
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.hd)
    k, v = enc_kv
    o = full_attention(q, k, v, causal=False)
    return linear(p["wo"], o.reshape(B, S, cfg.q_dim))


def encode_kv(p: Params, enc_out: jax.Array, cfg: ArchConfig):
    B, F, _ = enc_out.shape
    k = linear(p["wk"], enc_out).reshape(B, F, cfg.n_kv_heads, cfg.hd)
    v = linear(p["wv"], enc_out).reshape(B, F, cfg.n_kv_heads, cfg.hd)
    return k, v


# --------------------------------------------------------------------------
# FFN (dense + MoE)
# --------------------------------------------------------------------------


def init_ffn(key, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    ff = d_ff or cfg.d_ff
    dt = cfg.jdtype
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], cfg.d_model, ff, dt),
         "w_out": dense_init(ks[1], ff, cfg.d_model, dt)}
    if cfg.glu:
        p["w_gate"] = dense_init(ks[2], cfg.d_model, ff, dt)
    return p


def ffn_apply(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = linear(p["w_in"], x)
    if cfg.glu:
        h = activation(linear(p["w_gate"], x), cfg.act) * h
    else:
        h = activation(h, cfg.act)
    return linear(p["w_out"], h)


def init_moe(key, cfg: ArchConfig) -> Params:
    assert cfg.moe is not None
    E, ff, d, dt = cfg.moe.num_experts, cfg.d_ff, cfg.d_model, cfg.jdtype
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, dt, scale=0.02),
        "w_in": (jax.random.normal(ks[1], (E, d, ff)) * s).astype(dt),
        "w_out": (jax.random.normal(ks[2], (E, ff, d)) * (1 / math.sqrt(ff))).astype(dt),
    }
    if cfg.glu:
        p["w_gate"] = (jax.random.normal(ks[3], (E, d, ff)) * s).astype(dt)
    return p


def moe_apply(p: Params, x: jax.Array, cfg: ArchConfig,
              chunk: int = 1024) -> jax.Array:
    if _MOE_CHUNK:
        chunk = _MOE_CHUNK
    """Capacity-based dense dispatch, chunked over tokens so the one-hot
    dispatch tensor stays O(chunk · E · C) — the dataflow-compiler lesson
    applied to MoE: stream token blocks through the expert "tasks" instead
    of materializing the full routing matrix (a ping-pong→FIFO conversion).

    Expert dim is sharded over the ``model`` mesh axis (EP); GSPMD inserts
    the all-to-all pair around the expert computation.
    """
    assert cfg.moe is not None
    B, S, D = x.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    T = B * S
    xt = x.reshape(T, D)
    chunk = min(chunk, T)
    n_chunks = T // chunk
    assert T % chunk == 0, (T, chunk)
    C = max(1, int(chunk * K * cfg.moe.capacity_factor / E))

    logits = (xt @ p["router"]["w"]).astype(jnp.float32)         # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, K)                          # (T, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    disp_dt = jnp.bfloat16 if _MOE_BF16 else jnp.float32

    def one_chunk(carry, inp):
        xc, wc, ic = inp                                          # (c,D),(c,K),(c,K)
        # position of each (token, k) within its expert's capacity buffer
        onehot = jax.nn.one_hot(ic, E, dtype=jnp.float32)         # (c,K,E)
        flat = onehot.reshape(-1, E)                              # (c*K,E)
        pos_in_e = (jnp.cumsum(flat, axis=0) - flat).reshape(chunk, K, E)
        pos = jnp.einsum("cke,cke->ck", pos_in_e, onehot).astype(jnp.int32)
        keep = pos < C
        # dispatch tensor (c, E, C): {0,1}-valued, exact in bf16
        disp = jnp.einsum("cke,ckp->cep",
                          (onehot * keep[..., None]).astype(disp_dt),
                          jax.nn.one_hot(pos, C, dtype=disp_dt))
        xe = jnp.einsum("cep,cd->epd", disp,
                        xc.astype(disp_dt)).astype(xc.dtype)
        h = jnp.einsum("epd,edf->epf", xe, p["w_in"])
        if cfg.glu:
            g = jnp.einsum("epd,edf->epf", xe, p["w_gate"])
            h = activation(g, cfg.act) * h
        else:
            h = activation(h, cfg.act)
        ye = jnp.einsum("epf,efd->epd", h, p["w_out"])
        comb = jnp.einsum("cke,ckp,ck->cep", onehot * keep[..., None],
                          jax.nn.one_hot(pos, C, dtype=jnp.float32),
                          wc.astype(jnp.float32))
        yc = jnp.einsum("cep,epd->cd", comb, ye.astype(jnp.float32))
        return carry, yc.astype(xc.dtype)

    xcs = xt.reshape(n_chunks, chunk, D)
    wcs = topw.reshape(n_chunks, chunk, K)
    ics = topi.reshape(n_chunks, chunk, K)
    _, ys = jax.lax.scan(one_chunk, None, (xcs, wcs, ics))
    return ys.reshape(B, S, D)


def moe_aux_loss(logits: jax.Array, topi: jax.Array, E: int) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = gates.mean(axis=tuple(range(gates.ndim - 1)))
    ce = jax.nn.one_hot(topi[..., 0], E).mean(
        axis=tuple(range(topi.ndim - 1)))
    return E * jnp.sum(me * ce)


# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------


def init_embed(key, cfg: ArchConfig) -> Params:
    dt = cfg.jdtype
    p = {"tok": (jax.random.normal(key, (cfg.padded_vocab, cfg.d_model)) * 0.02).astype(dt)}
    if cfg.pos == "learned":
        p["pos"] = (jax.random.normal(key, (8192, cfg.d_model)) * 0.01).astype(dt)
    return p


def embed(p: Params, tokens: jax.Array, cfg: ArchConfig,
          positions: jax.Array | None = None) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.pos == "learned":
        pos = positions if positions is not None else jnp.arange(tokens.shape[-1])
        pe = jnp.take(p["pos"], jnp.clip(pos, 0, p["pos"].shape[0] - 1), axis=0)
        x = x + pe
    if cfg.family in ("dense", "hybrid") and cfg.name.startswith(("gemma", "recurrentgemma")):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(embed_p: Params, head_p: Params | None, x: jax.Array,
            cfg: ArchConfig) -> jax.Array:
    w = embed_p["tok"].T if (cfg.tie_embeddings or head_p is None) \
        else head_p["w"]
    return dot_bf16bwd(x, w) if _BF16_BWD else x @ w
