"""Mamba-2 SSD (state-space duality) blocks — arXiv:2405.21060.

The chunked SSD algorithm is the clearest instance of the paper's
reduction-operation rewriting at sequence scale: the recurrence over time
is split into intra-chunk (dense, matmul-shaped — MXU-friendly) and
inter-chunk (a tiny scan over per-chunk states).  States are the
"temporary array" of Fig. 5; each chunk's output is emitted exactly once.

Shapes follow the minimal reference implementation:
  x: (B, S, H, P)   heads H = expand·d_model / P
  dt: (B, S, H)     per-head step size (softplus of a projection)
  B, C: (B, S, N)   shared across heads (G = 1 group)
  A: (H,)           negative decay rates
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import Params, apply_norm, dense_init, linear, norm_init


def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise cumulative sums: out[i,j] = sum_{j<k<=i} a_k."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int):
    """Returns (y, final_state).  final_state: (B, H, P, N)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    a = dt * A[None, None, :]                      # (b, s, h) log-decay (negative)
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    # intra-chunk (diagonal blocks): attention-like with decay kernel
    L = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))          # (b, nc, h, q, q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)          # (b, nc, q, q)
    y_diag = jnp.einsum("bcqk,bchqk,bckh,bckhp->bcqhp", scores, L, dtc, xc)

    # end-of-chunk states
    decay_states = jnp.exp(ac.cumsum(2)[:, :, -1:, :] - ac.cumsum(2))  # (b,nc,q,h)
    states = jnp.einsum("bckn,bckh,bckh,bckhp->bchpn",
                        Bc, decay_states, dtc, xc)                      # (b,nc,h,p,n)

    # inter-chunk recurrence over nc states
    chunk_decay = jnp.exp(ac.sum(2))                                    # (b, nc, h)

    def step(hprev, inp):
        st, dec = inp                                                   # (b,h,p,n),(b,h)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)                            # (b,nc,h,p,n)

    # contribution of carried-in state to each position
    state_decay = jnp.exp(ac.cumsum(2))                                 # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc,
                       hprevs.astype(Cc.dtype), state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), hlast


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array):
    """One-token recurrence.  state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    B/C: (B,N)."""
    dec = jnp.exp(dt * A[None, :])                                      # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x, Bm)
    state = state * dec[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, state.astype(Cm.dtype))
    return y.astype(x.dtype), state


# --------------------------------------------------------------------------
# Block (norm -> in_proj -> conv1d -> SSD -> gate -> out_proj)
# --------------------------------------------------------------------------


def init_ssm_block(key, cfg: ArchConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_in = d * s.expand
    nheads = d_in // s.head_dim
    dt_ = cfg.jdtype
    ks = jax.random.split(key, 5)
    return {
        "norm": norm_init(d, cfg.norm, dt_),
        # projects to [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * s.d_state + nheads, dt_),
        "conv": (jax.random.normal(ks[1], (s.conv_width, d_in + 2 * s.d_state))
                 * 0.2).astype(dt_),
        "A_log": jnp.zeros((nheads,), jnp.float32) + math.log(1.0),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "out_proj": dense_init(ks[2], d_in, d, dt_),
    }


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nheads = d_in // s.head_dim
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * s.d_state], axis=-1)
    return z, xbc, dt, d_in, nheads


def ssm_block_train(p: Params, xin: jax.Array, cfg: ArchConfig) -> jax.Array:
    s = cfg.ssm
    Bsz, S, _ = xin.shape
    h = apply_norm(p["norm"], xin, cfg.norm)
    z, xbc, dtp, d_in, nheads = _split_proj(cfg, linear(p["in_proj"], h))
    # causal depthwise conv over (x, B, C)
    w = p["conv"]
    pad = jnp.pad(xbc, ((0, 0), (s.conv_width - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * w[i][None, None, :]
               for i in range(s.conv_width))
    conv = jax.nn.silu(conv)
    x, Bm, Cm = jnp.split(conv, [d_in, d_in + s.d_state], axis=-1)
    x = x.reshape(Bsz, S, nheads, s.head_dim)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(x, dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                       min(s.chunk, S))
    y = y + x * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(Bsz, S, d_in) * jax.nn.silu(z)
    return xin + linear(p["out_proj"], y)


def init_ssm_cache(cfg: ArchConfig, batch: int, layers: int) -> Params:
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nheads = d_in // s.head_dim
    return {
        "state": jnp.zeros((layers, batch, nheads, s.head_dim, s.d_state),
                           jnp.float32),
        "conv": jnp.zeros((layers, batch, s.conv_width - 1,
                           d_in + 2 * s.d_state), cfg.jdtype),
    }


def ssm_block_decode(p: Params, xin: jax.Array, cfg: ArchConfig, *,
                     state: jax.Array, conv_buf: jax.Array):
    """xin: (B, 1, D).  Returns (y, state, conv_buf)."""
    s = cfg.ssm
    Bsz = xin.shape[0]
    h = apply_norm(p["norm"], xin, cfg.norm)
    z, xbc, dtp, d_in, nheads = _split_proj(cfg, linear(p["in_proj"], h))
    xbc = xbc[:, 0]                                   # (B, d_in+2N)
    hist = jnp.concatenate([conv_buf, xbc[:, None]], axis=1)  # (B, cw, *)
    conv = jnp.einsum("bcw,cw->bw", hist.astype(jnp.float32),
                      p["conv"].astype(jnp.float32))
    conv = jax.nn.silu(conv).astype(xin.dtype)
    conv_buf = hist[:, 1:]
    x, Bm, Cm = jnp.split(conv, [d_in, d_in + s.d_state], axis=-1)
    x = x.reshape(Bsz, nheads, s.head_dim)
    dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_decode_step(state, x.astype(jnp.float32), dt, A,
                               Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_in).astype(xin.dtype) * jax.nn.silu(z)
    return xin + linear(p["out_proj"], y), state, conv_buf


# --------------------------------------------------------------------------
# CODO traced form (ROADMAP item 4): the SSD inter-chunk state recurrence
# as a dataflow-frontend function, so the ``ssd_scan`` op reaches the
# chunked-scan kernel through routing.
# --------------------------------------------------------------------------


def ssd_block_fn(states, decay):
    """Inter-chunk SSD recurrence over per-chunk end ``states
    (nc, BH, P, N)`` and scalar ``decay (nc, BH, 1, 1)``; returns the
    carried-in states combined with the locals (residual form)."""
    from ..core import frontend as F
    prev = F.ssd_scan(states, decay)
    return F.add(prev, states)
