"""RG-LRU recurrent blocks (RecurrentGemma / Griffin, arXiv:2402.19427).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Λ) * r_t      (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

A *purely streaming* op — each element is produced and consumed exactly
once in order, i.e. FIFO-native in CODO terms (DESIGN.md §4).  Training
uses an associative scan over the (a, b) affine composition; decode is a
single-step update with O(1) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers import Params, apply_norm, dense_init, linear, norm_init

_C = 8.0


def init_rglru_block(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    dt = cfg.jdtype
    ks = jax.random.split(key, 6)
    return {
        "norm": norm_init(d, cfg.norm, dt),
        "in_x": dense_init(ks[0], d, d, dt),      # recurrent branch
        "in_y": dense_init(ks[1], d, d, dt),      # gate branch (GeLU)
        "conv": (jax.random.normal(ks[2], (4, d)) * 0.2).astype(dt),
        "w_a": dense_init(ks[3], d, d, dt),
        "w_i": dense_init(ks[4], d, d, dt),
        "lam": jnp.full((d,), 2.0, jnp.float32),  # softplus(2) ~ healthy decay
        "out": dense_init(ks[5], d, d, dt),
    }


def _gates(p: Params, x: jax.Array):
    r = jax.nn.sigmoid(linear(p["w_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["w_i"], x).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
    return a, b


def _conv1d(p: Params, x: jax.Array) -> jax.Array:
    w = p["conv"]
    cw = w.shape[0]
    S = x.shape[1]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    return sum(pad[:, i:i + S, :] * w[i][None, None, :] for i in range(cw))


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array | None = None):
    """h_t = a_t h_{t-1} + b_t over axis 1, via associative scan."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        b_s = b_s + a_s * h0[:, None, :]
    return b_s


def rglru_block_train(p: Params, xin: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = apply_norm(p["norm"], xin, cfg.norm)
    gate = jax.nn.gelu(linear(p["in_y"], h))
    x = linear(p["in_x"], h)
    x = _conv1d(p, x)
    a, b = _gates(p, x)
    y = rglru_scan(a, b).astype(xin.dtype)
    y = y * gate
    return xin + linear(p["out"], y)


def init_rglru_cache(cfg: ArchConfig, batch: int, layers: int) -> Params:
    d = cfg.d_model
    return {
        "h": jnp.zeros((layers, batch, d), jnp.float32),
        "conv": jnp.zeros((layers, batch, 3, d), cfg.jdtype),
    }


def rglru_block_decode(p: Params, xin: jax.Array, cfg: ArchConfig, *,
                       h_state: jax.Array, conv_buf: jax.Array):
    """xin: (B, 1, D); h_state: (B, D); conv_buf: (B, cw-1, D)."""
    h = apply_norm(p["norm"], xin, cfg.norm)
    gate = jax.nn.gelu(linear(p["in_y"], h))
    x = linear(p["in_x"], h)[:, 0]                       # (B, D)
    hist = jnp.concatenate([conv_buf, x[:, None]], axis=1)
    x = jnp.einsum("bcd,cd->bd", hist.astype(jnp.float32),
                   p["conv"].astype(jnp.float32)).astype(xin.dtype)
    conv_buf = hist[:, 1:]
    a, b = _gates(p, x[:, None])
    hnew = a[:, 0] * h_state + b[:, 0]
    y = (hnew.astype(xin.dtype) * gate[:, 0])[:, None]
    return xin + linear(p["out"], y), hnew, conv_buf


# --------------------------------------------------------------------------
# CODO traced form (ROADMAP item 4): the gated recurrence core as a
# dataflow-frontend function, so the ``rglru_scan`` op reaches the
# chunked-scan kernel through routing.
# --------------------------------------------------------------------------


def rglru_block_fn(a, gate, x):
    """Gated linear-recurrence block over ``(B, S, D)`` operands:
    ``h = scan(a, gate*x)`` with a residual skip."""
    from ..core import frontend as F
    b = F.mul(gate, x)
    h = F.rglru_scan(a, b)
    return F.add(h, x)
