"""Model zoo facade + batch construction for every (arch × shape) cell."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec
from . import layers, rglru, ssm, transformer
from .transformer import (cache_len_for, decode_step, forward, init_cache,
                          init_params, loss_fn, param_count, param_shapes,
                          prefill)


def batch_spec(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell
    (the dry-run's ``input_specs()``)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B,), i32)}
    s_text = S - cfg.n_patches if cfg.n_patches else S
    out = {"tokens": jax.ShapeDtypeStruct((B, s_text), i32)}
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    if cfg.n_patches:
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), cfg.jdtype)
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), cfg.jdtype)
    return out


def make_batch(cfg: ArchConfig, *, batch: int, seq: int, kind: str,
               seed: int = 0) -> dict:
    """Concrete synthetic batch (smoke tests / examples).  The audio/vision
    frontends are stubs: frames/patch embeddings are generated directly."""
    rng = np.random.default_rng(seed)
    if kind == "decode":
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch,)),
                                      jnp.int32)}
    s_text = seq - cfg.n_patches if cfg.n_patches else seq
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, s_text)),
                                 jnp.int32)}
    if kind == "train":
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, s_text)),
                                    jnp.int32)
    if cfg.n_patches:
        out["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)) * 0.02,
            cfg.jdtype)
    if cfg.enc_dec:
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_frames, cfg.d_model)) * 0.02,
            cfg.jdtype)
    return out


__all__ = ["batch_spec", "cache_len_for", "decode_step", "forward",
           "init_cache", "init_params", "layers", "loss_fn", "make_batch",
           "param_count", "param_shapes", "prefill", "rglru", "ssm",
           "transformer"]
