"""Assemble EXPERIMENTS.md §Dry-run and §Roofline sections from
results/dryrun/*.json (run after `python -m repro.launch.dryrun`).

    PYTHONPATH=src python -m benchmarks.report > results/roofline_report.md
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.roofline import (RooflineRow, build_table, markdown_table,
                                 what_would_help)


def dryrun_section(cells: list[dict]) -> str:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    lines = [
        "## §Dry-run",
        "",
        f"Cells: **{len(ok)} compiled**, {len(skipped)} skipped "
        f"(documented), {len(err)} errors.  Meshes: single-pod (16,16) "
        "(data,model) = 256 chips; multi-pod (2,16,16) (pod,data,model) = "
        "512 chips — 512 host devices via "
        "`--xla_force_host_platform_device_count=512`.",
        "",
        "| arch | shape | mesh | FLOPs/dev | HBM bytes/dev | collective "
        "B/dev (#ops) | peak GiB/dev | lower+compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        m = c["memory"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{c['flops_per_device']:.2e} | {c['bytes_per_device']:.2e} | "
            f"{c['collective']['total']:.2e} ({int(c['collective']['count'])}) | "
            f"{m['peak_per_device']/2**30:.2f} | "
            f"{c['lower_s']+c['compile_s']:.1f} |")
    lines.append("")
    if skipped:
        lines.append("Skipped cells (all long_500k on pure full-attention "
                     "archs — no sub-quadratic path; DESIGN.md §4):")
        for c in sorted(skipped, key=lambda c: c["arch"]):
            lines.append(f"* {c['arch']} × {c['shape']} × {c['mesh']}")
    return "\n".join(lines)


def roofline_section(rows: list[RooflineRow]) -> str:
    ok = [r for r in rows if r.status == "ok"]
    lines = [
        "## §Roofline",
        "",
        "Terms (seconds, per chip): compute = HLO_FLOPs/197e12; memory = "
        "HLO_bytes/819e9; collective = link_bytes/50e9 (ring-algorithm "
        "accounting, busiest-link bound).  HLO quantities come from the "
        "loop-aware walker over the compiled HLO "
        "(`launch/hlo_analysis.py`); `useful` = MODEL_FLOPS/HLO_FLOPs; "
        "`roofline` = useful-compute-time / max(term).",
        "",
        markdown_table(rows),
        "",
        "### Dominant-term notes (what would move it down)",
        "",
    ]
    seen = set()
    for r in sorted(ok, key=lambda r: r.roofline_fraction):
        key = (r.arch, r.shape)
        if key in seen or r.mesh != "single":
            continue
        seen.add(key)
        lines.append(f"* **{r.arch} × {r.shape}** (dominant: {r.dominant}, "
                     f"roofline {r.roofline_fraction:.2f}): "
                     f"{what_would_help(r)}")
    return "\n".join(lines)


def main() -> None:
    results = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    cells = [json.loads(p.read_text()) for p in sorted(results.glob("*.json"))]
    rows = build_table(results)
    print(dryrun_section(cells))
    print()
    print(roofline_section(rows))


if __name__ == "__main__":
    main()
