"""Benchmark harness — one function per paper table/figure plus the
roofline report.  Prints ``name,value,derived`` CSV and writes
results/bench/*.csv; ``--json`` additionally collects every suite into
one machine-readable document (what the nightly CI job uploads).

    PYTHONPATH=src python -m benchmarks.run              # everything
    PYTHONPATH=src python -m benchmarks.run --only table2,roofline
    PYTHONPATH=src python -m benchmarks.run --json results/bench/bench.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks import (roofline, routing_bench, serving_bench,  # noqa: E402
                        sharding_bench, tables, train_bench)

OUT = Path(__file__).resolve().parents[1] / "results" / "bench"

SUITES = {
    "table2": tables.table2_kernels,
    "table3": tables.table3_dnns,
    "table4": tables.table4_dnns,
    "gpt2": tables.gpt2_eval,
    "fig10": tables.ablation,
    "table7": tables.table7_batch,
    "fig11": tables.parallelism_sweep,
    "table8": tables.fifo_percentage,
    "micro": tables.kernel_microbench,
    # per-group pallas-vs-xla latency pairs; also writes
    # results/bench/routing_groups.json (uploaded by the nightly CI job)
    "routing": routing_bench.routing_groups,
    # batched-vs-sequential serving throughput + p50/p99; also writes
    # results/bench/serving.json (uploaded by the nightly CI job)
    "serving": serving_bench.serving_rows,
    # per-device-count sharded scaling on gpt2_medium; also writes
    # results/bench/sharding.json (uploaded by the sharding-smoke CI job)
    "sharding": sharding_bench.sharding_rows,
    # compiled-vs-plain-jit training step time (graph-level autodiff); also
    # writes results/bench/training.json (uploaded by the training-smoke job)
    "training": train_bench.training_rows,
}


def run_roofline() -> int:
    rows = roofline.build_table()
    if not rows:
        print("roofline: no dry-run results found — run "
              "`python -m repro.launch.dryrun` first", file=sys.stderr)
        return 0
    OUT.mkdir(parents=True, exist_ok=True)
    csv = [roofline.CSV_HEADER] + [r.csv() for r in rows]
    (OUT / "roofline.csv").write_text("\n".join(csv) + "\n")
    ok = [r for r in rows if r.status == "ok"]
    for r in ok:
        print(f"roofline/{r.arch}/{r.shape}/{r.mesh},"
              f"{r.roofline_fraction:.4f},dominant={r.dominant};"
              f"useful={r.useful_ratio:.2f};peak_GiB={r.peak_gib:.1f}")
    n_fit = sum(1 for r in ok if r.fits_hbm)
    print(f"roofline/summary,{len(ok)},ok_cells;fits_hbm={n_fit}/{len(ok)}")
    return len(ok)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help=f"comma list of {sorted(SUITES)} + roofline")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write every suite's rows to one JSON file")
    args = ap.parse_args(argv)
    wanted = None if args.only == "all" else set(args.only.split(","))

    OUT.mkdir(parents=True, exist_ok=True)
    report: dict[str, object] = {}
    print("name,value,derived")
    for name, fn in SUITES.items():
        if wanted is not None and name not in wanted:
            continue
        t0 = time.time()
        rows = fn()
        lines = [r.csv() for r in rows]
        (OUT / f"{name}.csv").write_text("name,value,derived\n"
                                         + "\n".join(lines) + "\n")
        for line in lines:
            print(line)
        elapsed = time.time() - t0
        print(f"{name}/elapsed_s,{elapsed:.2f},")
        report[name] = {
            "elapsed_s": round(elapsed, 3),
            "rows": [{"name": r.name, "value": r.value, "derived": r.derived}
                     for r in rows],
        }
    if wanted is None or "roofline" in wanted:
        run_roofline()
    if args.json:
        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
        print(f"wrote {path} ({len(report)} suites)", file=sys.stderr)


if __name__ == "__main__":
    main()
