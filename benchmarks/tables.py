"""Benchmark implementations, one per paper table/figure (§VIII).

Latency numbers are the cost model's cycle estimates (the role HLS
synthesis reports play in the paper); wall-clock microbenches cover the
runnable kernels.  Every function returns a list of CSV rows
(name, value, derived).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import ABLATION_PRESETS, CodoOptions, PassManager, codo_opt
from repro.models import dataflow_models as dm

# For rows that *report* compile time as a paper metric: a real pipeline run
# (no cache) without the diagnostics census (two whole-graph violation scans
# per pass, ~25% of a large compile) that the default manager adds.
_TIMING_MANAGER = PassManager(census=False)


@dataclass
class Row:
    name: str
    value: float
    derived: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.value:.6g},{self.derived}"


# --------------------------------------------------------------------------
# Table II — kernel-level applications
# --------------------------------------------------------------------------

TABLE2 = {
    "atax": lambda: dm.atax(400, 400),
    "gesummv": lambda: dm.gesummv(400),
    "gemm": lambda: dm.gemm(256, 256, 256),
    "mvt": lambda: dm.mvt(400),
    "3mm": lambda: dm.three_mm(256),
    "residual_mlp": lambda: dm.residual_mlp(64, 512),
    "autoencoder": lambda: dm.autoencoder(64, 784),
    "residual_block": lambda: dm.residual_block(1, 64, 32),
    "dws_conv_block": lambda: dm.dws_conv_block(1, 64, 32),
    "conv3_block": lambda: dm.conv3_block(1, 3, 34),
    "feed_forward": lambda: dm.feed_forward(128, 512),
    "multi_head_attention": lambda: dm.multi_head_attention(128, 256),
}


def table2_kernels(budget: int = 900) -> list[Row]:
    rows = []
    speedups = []
    for name, build in TABLE2.items():
        g = build()
        # dse_s is a reported paper metric: real pipeline run, no census.
        c = codo_opt(g, CodoOptions(budget_units=budget), cache=None,
                     manager=_TIMING_MANAGER)
        speedups.append(c.speedup)
        rows.append(Row(
            f"table2/{name}", c.speedup,
            f"units={c.schedule_report.units_used};"
            f"fifo={c.fifo_fraction:.2f};"
            f"cycles={c.final.total_cycles:.0f};"
            f"dse_s={c.compile_seconds:.3f}"))
    geo = float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9)))))
    rows.append(Row("table2/geomean", geo, "latency speedup vs sequential"))
    return rows


# --------------------------------------------------------------------------
# Tables III & IV — DNN models
# --------------------------------------------------------------------------


def _dnn_row(tag: str, name: str, build, budget: int) -> Row:
    g = build()
    # compile_s is part of the reported row (see table2_kernels).
    c = codo_opt(g, CodoOptions(budget_units=budget), cache=None,
                 manager=_TIMING_MANAGER)
    return Row(
        f"{tag}/{name}", c.speedup,
        f"cycles={c.final.total_cycles:.3e};"
        f"compile_s={c.compile_seconds:.2f};"
        f"fifo={c.fifo_fraction:.2f};"
        f"units={c.schedule_report.units_used};"
        f"vmem_B={c.final.vmem_bytes}")


def table3_dnns(budget: int = 2048) -> list[Row]:
    models = {"resnet18": lambda: dm.resnet18(32),
              "vgg16": lambda: dm.vgg16(32),
              "mobilenet": lambda: dm.mobilenet(32)}
    return [_dnn_row("table3", n, b, budget) for n, b in models.items()]


def table4_dnns(budget: int = 2048) -> list[Row]:
    models = {"resnet18": lambda: dm.resnet18(224),
              "vgg16": lambda: dm.vgg16(224),
              "mobilenet": lambda: dm.mobilenet(224),
              "zfnet": lambda: dm.zfnet(224),
              "yolo": lambda: dm.yolo_tiny(384, 1280)}
    return [_dnn_row("table4", n, b, budget) for n, b in models.items()]


# --------------------------------------------------------------------------
# Fig. 9 / Table VI — GPT-2
# --------------------------------------------------------------------------


def gpt2_eval(budget: int = 2048) -> list[Row]:
    """Prefill (TTFT analogue) and per-token decode latency from the
    scheduled GPT-2 block graph × 24 layers."""
    rows = []
    n_layers = 24
    for s in (32, 64, 128):
        g = dm.gpt2_block(S=s, D=1024)
        c = codo_opt(g, CodoOptions(budget_units=budget))
        # blocks pipeline across layers: fill + steady-state
        block = c.final.total_cycles
        prefill_cycles = block * n_layers   # conservative: no inter-block overlap
        clock = c.options.hw.clock_hz
        ttft_ms = prefill_cycles / clock * 1e3
        rows.append(Row(f"gpt2/prefill_{s}", ttft_ms,
                        f"cycles={prefill_cycles:.3e};speedup={c.speedup:.1f}"))
    g1 = dm.gpt2_block(S=1, D=1024)
    c1 = codo_opt(g1, CodoOptions(budget_units=budget))
    per_tok_ms = c1.final.total_cycles * n_layers / c1.options.hw.clock_hz * 1e3
    rows.append(Row("gpt2/decode_tok_per_s", 1e3 / per_tok_ms,
                    f"per_tok_ms={per_tok_ms:.3f}"))
    return rows


# --------------------------------------------------------------------------
# Fig. 10 / Table VII — ablation
# --------------------------------------------------------------------------


def ablation(budget: int = 2048) -> list[Row]:
    rows = []
    workloads = {"resnet18": lambda: dm.resnet18(32),
                 "gpt2_block": lambda: dm.gpt2_block(128, 1024),
                 "yolo": lambda: dm.yolo_tiny(64, 64)}
    # Table VII's grid is data (repro.core.passes.ABLATION_PRESETS), so the
    # benchmark can never drift from the pipeline's definition of opt1..opt5.
    for wname, build in workloads.items():
        for oname in ABLATION_PRESETS:
            opt = CodoOptions.preset(oname, budget_units=budget)
            c = codo_opt(build(), opt)
            rows.append(Row(f"fig10/{wname}/{oname}", c.speedup,
                            f"fifo={c.fifo_fraction:.2f}"))
    return rows


# --------------------------------------------------------------------------
# Table VII batch grid — the compiler CLI's report + a bench suite
# --------------------------------------------------------------------------


def format_batch_grid(results) -> str:
    """Table VII-style text grid from ``codo_opt_batch`` results: one row
    per config, one speedup column per preset.  Cached cells are marked
    ``*`` (their compile time is the lookup, not a pipeline run)."""
    presets = sorted({r.preset for r in results},
                     key=lambda p: list(ABLATION_PRESETS).index(p)
                     if p in ABLATION_PRESETS else 99)
    configs = sorted({r.config for r in results})
    by_cell = {(r.config, r.preset): r for r in results}

    w = max([len(c) for c in configs] + [8])
    head = f"{'config':<{w}s} " + " ".join(f"{p:>12s}" for p in presets) \
        + "   fifo   compile_ms"
    lines = [head, "-" * len(head)]
    for cname in configs:
        cells, fifo, ms = [], "", 0.0
        for p in presets:
            r = by_cell.get((cname, p))
            if r is None or not r.ok:
                cells.append(f"{'ERR':>12s}")
                continue
            mark = "*" if r.cache_hit else ""
            cells.append(f"{r.compiled.speedup:>11.1f}{mark or 'x'}")
            fifo = f"{r.compiled.fifo_fraction:.2f}"
            ms += r.compiled.compile_seconds * 1e3
        lines.append(f"{cname:<{w}s} " + " ".join(cells)
                     + f"   {fifo:>4s}   {ms:>9.1f}")
    lines.append("(speedup vs sequential baseline; '*' = compile-cache hit; "
                 "fifo from the last preset column; compile_ms = row total "
                 "across preset columns)")
    return "\n".join(lines)


def batch_grid_rows(results) -> list[Row]:
    """CSV rows mirroring :func:`format_batch_grid` for results/bench.
    The derived string is BatchResult.derived() — one format, shared with
    the CLI's --csv output."""
    return [Row(f"table7/{r.config}/{r.preset}",
                r.compiled.speedup if r.ok else float("nan"),
                r.derived())
            for r in results]


def table7_batch(budget: int = 2048) -> list[Row]:
    """The full model-config × opt1..opt5 grid through the batch driver."""
    from repro.core import codo_opt_batch
    from repro.core.compiler import ablation_jobs, batch_workloads

    results = codo_opt_batch(ablation_jobs(batch_workloads(),
                                           budget_units=budget))
    return batch_grid_rows(results)


# --------------------------------------------------------------------------
# Fig. 11 — resource-performance trade-off
# --------------------------------------------------------------------------


def parallelism_sweep() -> list[Row]:
    rows = []
    for budget in (64, 128, 256, 512, 1024, 2048, 4096):
        c = codo_opt(dm.resnet18(32), CodoOptions(budget_units=budget))
        rows.append(Row(f"fig11/budget_{budget}", c.speedup,
                        f"units={c.schedule_report.units_used}"))
    return rows


# --------------------------------------------------------------------------
# Table VIII — FIFO percentage
# --------------------------------------------------------------------------


def fifo_percentage() -> list[Row]:
    workloads = {"gesummv": lambda: dm.gesummv(400),
                 "residual_block": lambda: dm.residual_block(1, 64, 32),
                 "multi_head_attention": lambda: dm.multi_head_attention(128, 256),
                 "mobilenet": lambda: dm.mobilenet(32),
                 "resnet18": lambda: dm.resnet18(32),
                 "gpt2_block": lambda: dm.gpt2_block(128, 1024)}
    rows = []
    for name, build in workloads.items():
        c = codo_opt(build())
        rows.append(Row(f"table8/{name}", c.fifo_fraction * 100, "% FIFO"))
    return rows


# --------------------------------------------------------------------------
# Kernel wall-clock microbench (runnable numbers on this host)
# --------------------------------------------------------------------------


def kernel_microbench(iters: int = 20) -> list[Row]:
    import jax
    import jax.numpy as jnp

    from repro.models.layers import blockwise_attention, full_attention

    rows = []
    rng = np.random.default_rng(0)

    def timeit(fn, *args):
        fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
            else jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6

    B, H, S, hd = 1, 4, 1024, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k, v = q, q
    blk = jax.jit(lambda q, k, v: blockwise_attention(q, k, v, causal=True))
    ful = jax.jit(lambda q, k, v: full_attention(q, k, v, causal=True))
    rows.append(Row("micro/blockwise_attn_us", timeit(blk, q, k, v),
                    f"S={S} flash-recurrence jnp"))
    rows.append(Row("micro/full_attn_us", timeit(ful, q, k, v),
                    f"S={S} materialized scores"))

    from repro.kernels.streamfuse import pad_conv_relu_ref
    x = jnp.asarray(rng.standard_normal((1, 16, 64, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 16, 3, 3)) * 0.1, jnp.float32)
    fused = jax.jit(lambda x, w: pad_conv_relu_ref(x, w))
    rows.append(Row("micro/pad_conv_relu_us", timeit(fused, x, w),
                    "xla-fused oracle"))
    return rows
