"""Roofline analysis (deliverable g): three-term roofline per
(arch × shape × mesh) from the dry-run's compiled artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
    memory term     = HLO_bytes_per_device / HBM_bw             [s]
    collective term = collective_bytes_per_device / (links·bw)  [s]

HLO_FLOPs / bytes / collective bytes come from the loop-aware HLO walker
(launch/hlo_analysis.py) over ``compiled.as_text()`` — XLA's own
cost_analysis counts while bodies once and is kept only as a reference
column.  Collective bytes use ring-algorithm multipliers with the
replica-group size parsed per op.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(3 usable link-pairs per chip on a 2-D torus; we charge the *busiest
single link* conservatively: links=1).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9
ICI_LINKS = 1          # conservative single-link bound (see module docstring)

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops_per_dev: float = 0.0
    hlo_flops_per_dev: float = 0.0
    useful_ratio: float = 0.0       # MODEL_FLOPS / HLO_FLOPs
    roofline_fraction: float = 0.0  # compute_s / total_bound_s
    peak_gib: float = 0.0
    fits_hbm: bool = True
    note: str = ""

    @property
    def step_bound_s(self) -> float:
        """Lower bound on step time: overlapped terms -> max; the dominant
        term IS the step time at perfect overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def csv(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},{self.status},"
                f"{self.compute_s:.6g},{self.memory_s:.6g},"
                f"{self.collective_s:.6g},{self.dominant},"
                f"{self.useful_ratio:.3f},{self.roofline_fraction:.3f},"
                f"{self.peak_gib:.2f},{self.fits_hbm},{self.note}")


CSV_HEADER = ("arch,shape,mesh,status,compute_s,memory_s,collective_s,"
              "dominant,useful_ratio,roofline_fraction,peak_GiB,fits_hbm,note")


def load_cells(results_dir: Path = RESULTS) -> list[dict]:
    cells = []
    for p in sorted(results_dir.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def roofline_row(rec: dict) -> RooflineRow:
    row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"], rec["status"])
    if rec["status"] != "ok":
        row.note = rec.get("skip_reason", rec.get("error", ""))[:80]
        return row
    chips = rec["chips"]
    row.hlo_flops_per_dev = rec["flops_per_device"]
    row.model_flops_per_dev = rec["model_flops"] / chips
    row.compute_s = rec["flops_per_device"] / PEAK_FLOPS
    row.memory_s = rec["bytes_per_device"] / HBM_BW
    row.collective_s = rec["collective"]["total"] / (ICI_LINKS * ICI_LINK_BW)
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.dominant = max(terms, key=terms.get)
    row.useful_ratio = (row.model_flops_per_dev
                        / max(row.hlo_flops_per_dev, 1e-30))
    # fraction of roofline: useful model compute time over the step bound
    useful_s = row.model_flops_per_dev / PEAK_FLOPS
    row.roofline_fraction = useful_s / max(row.step_bound_s, 1e-30)
    row.peak_gib = rec["memory"]["peak_per_device"] / 2**30
    row.fits_hbm = row.peak_gib <= 16.0
    return row


def build_table(results_dir: Path = RESULTS) -> list[RooflineRow]:
    return [roofline_row(rec) for rec in load_cells(results_dir)]


def what_would_help(row: RooflineRow) -> str:
    """One sentence per cell on moving the dominant term (EXPERIMENTS.md)."""
    if row.status != "ok":
        return ""
    if row.dominant == "compute":
        if row.useful_ratio < 0.4:
            return ("compute-bound with low useful ratio: cut remat/replicated "
                    "attention flops (seq-shard attention, causal block skip)")
        return "compute-bound near-useful: only more chips or lower precision help"
    if row.dominant == "memory":
        return ("memory-bound: fuse bandwidth-heavy chains (CODO FIFO groups), "
                "shrink KV/cache dtypes, raise arithmetic intensity via batching")
    return ("collective-bound: overlap collectives with compute, shard to cut "
            "gather volume (2D sharded activations), compress gradients")


def markdown_table(rows: list[RooflineRow]) -> str:
    lines = ["| arch | shape | mesh | compute s | memory s | collective s | "
             "dominant | useful | roofline | peak GiB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.status != "ok":
            lines.append(f"| {r.arch} | {r.shape} | {r.mesh} | — | — | — | "
                         f"skipped | — | — | — | — |")
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.2e} | "
            f"{r.memory_s:.2e} | {r.collective_s:.2e} | {r.dominant} | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.2f} | "
            f"{r.peak_gib:.1f} | {'y' if r.fits_hbm else 'N'} |")
    return "\n".join(lines)
