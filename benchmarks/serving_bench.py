"""Serving-runtime benchmark: batched vs sequential throughput + latency.

Drives the :class:`repro.serving.ServingRuntime` against the ``gpt2_block``
workload and measures the dynamic-batching win directly: N requests served
one at a time (the pre-runtime ``launch/serve.py`` regime) vs the same N
coalesced into leading-batch-dim dispatches of ``--batch`` (default 8).
Reports throughput (req/s) and p50/p99 request latency for both regimes —
under queued load for the batched path, so the tail includes queueing —
and writes the machine-readable document the nightly CI job uploads::

    results/bench/serving.json

CLI (the CI ``serving-smoke`` job runs ``--quick --min-speedup 2``)::

    PYTHONPATH=src python -m benchmarks.serving_bench --quick
    PYTHONPATH=src python -m benchmarks.serving_bench          # full load

``--quick`` shrinks the block (S=16, D=64) and the request count for PR
latency; the full run uses the paper-scale block at more requests.
``--min-speedup X`` exits 1 if batched throughput is below X× sequential
— the acceptance bar is 2× at batch 8 on CPU.

The suite is also registered in ``benchmarks.run`` as ``serving`` (quick
mode), so the nightly ``--json`` collection carries its rows.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "results" / "bench"


def _pctl(values: list[float], q: float) -> float:
    if not values:
        return 0.0
    ordered = sorted(values)
    idx = min(len(ordered) - 1, max(0, round(q * (len(ordered) - 1))))
    return ordered[idx]


def _lat_summary(lat_s: list[float], total_s: float) -> dict:
    return {
        "requests": len(lat_s),
        "total_s": round(total_s, 6),
        "throughput_rps": round(len(lat_s) / max(total_s, 1e-9), 3),
        "p50_ms": round(_pctl(lat_s, 0.50) * 1e3, 4),
        "p99_ms": round(_pctl(lat_s, 0.99) * 1e3, 4),
        "mean_ms": round(statistics.fmean(lat_s) * 1e3, 4)
        if lat_s else 0.0,
    }


def run_bench(*, quick: bool = False, batch: int = 8,
              requests: int | None = None, seed: int = 0) -> dict:
    """One measured comparison; returns the ``serving.json`` document."""
    import jax
    import numpy as np

    from repro import api as codo
    from repro.core.cache import CompileCache
    from repro.kernels import register_all
    from repro.models import dataflow_models as dm
    from repro.serving import ServeConfig, ServingRuntime

    register_all()
    S, D = (16, 64) if quick else (64, 256)
    if requests is None:
        requests = 4 * batch if quick else 16 * batch
    requests = max(batch, (requests // batch) * batch)   # whole windows

    cache = CompileCache()
    graph = dm.gpt2_block(S, D)
    program = codo.compile(graph, cache=cache)
    rng = np.random.default_rng(seed)
    envs = [{n: rng.standard_normal(
        tuple(graph.buffers[n].shape)).astype("float32")
        for n in program.input_names} for _ in range(requests)]

    # -- sequential per-request baseline (the old launch/serve.py regime) --
    low = program.lower(jit=True)
    jax.block_until_ready(low(program.make_env(**envs[0])))   # warm
    seq_lat: list[float] = []
    t0 = time.perf_counter()
    for env in envs:
        s = time.perf_counter()
        jax.block_until_ready(low(program.make_env(**env)))
        seq_lat.append(time.perf_counter() - s)
    seq_total = time.perf_counter() - t0

    # -- batched through the runtime (queued load: p99 includes queueing) --
    cfg = ServeConfig(batch_window_ms=5.0, max_batch=batch,
                      max_queue=max(1024, 2 * requests))
    with ServingRuntime(cfg, cache=cache) as rt:
        rt.add_model("bench", program, warm=False)
        # Warm one window untimed: compiles the leading-batch-dim design
        # (a one-time cost shared by every later window via the cache).
        warm = [rt.submit("bench", **envs[i % len(envs)])
                for i in range(batch)]
        for f in warm:
            f.result(timeout=600)
        bat_lat = []
        t0 = time.perf_counter()
        submit_at, futs = [], []
        for env in envs:
            submit_at.append(time.perf_counter())
            futs.append(rt.submit("bench", **env))
        for at, f in zip(submit_at, futs):
            f.result(timeout=600)
            bat_lat.append(time.perf_counter() - at)
        bat_total = time.perf_counter() - t0
        stats = rt.stats.snapshot()

    seq = _lat_summary(seq_lat, seq_total)
    bat = _lat_summary(bat_lat, bat_total)
    return {
        "workload": f"gpt2_block(S={S},D={D})",
        "backend": jax.default_backend(),
        "quick": quick,
        "batch": batch,
        "requests": requests,
        "sequential": seq,
        "batched": bat,
        "speedup": round(bat["throughput_rps"]
                         / max(seq["throughput_rps"], 1e-9), 3),
        "runtime_stats": stats,
    }


def serving_rows():
    """The ``benchmarks.run`` suite entry: quick-mode rows + serving.json."""
    from benchmarks.tables import Row
    doc = run_bench(quick=True)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "serving.json").write_text(
        json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return [
        Row("serving/sequential_rps", doc["sequential"]["throughput_rps"],
            f"p50_ms={doc['sequential']['p50_ms']};"
            f"p99_ms={doc['sequential']['p99_ms']}"),
        Row("serving/batched_rps", doc["batched"]["throughput_rps"],
            f"p50_ms={doc['batched']['p50_ms']};"
            f"p99_ms={doc['batched']['p99_ms']};batch={doc['batch']}"),
        Row("serving/speedup", doc["speedup"],
            f"{doc['workload']};backend={doc['backend']}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Batched-vs-sequential serving throughput/latency.")
    ap.add_argument("--quick", action="store_true",
                    help="small block + fewer requests (PR/CI latency)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (0 = scaled from --batch)")
    ap.add_argument("--json", default=str(OUT / "serving.json"),
                    metavar="PATH", help="output document path")
    ap.add_argument("--min-speedup", type=float, default=0.0,
                    help="exit 1 if batched/sequential throughput is "
                         "below this (CI gate; 0 disables)")
    args = ap.parse_args(argv)

    doc = run_bench(quick=args.quick, batch=args.batch,
                    requests=args.requests or None)
    path = Path(args.json)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")

    seq, bat = doc["sequential"], doc["batched"]
    print(f"serving {doc['workload']} [{doc['backend']}] "
          f"batch={doc['batch']} requests={doc['requests']}")
    print(f"  sequential: {seq['throughput_rps']:.1f} req/s  "
          f"p50 {seq['p50_ms']:.2f} ms  p99 {seq['p99_ms']:.2f} ms")
    print(f"  batched:    {bat['throughput_rps']:.1f} req/s  "
          f"p50 {bat['p50_ms']:.2f} ms  p99 {bat['p99_ms']:.2f} ms")
    print(f"  speedup:    {doc['speedup']:.2f}x  "
          f"(batched dispatches: "
          f"{doc['runtime_stats']['batched_requests']} requests in "
          f"{doc['runtime_stats']['batches']} batches)")
    print(f"wrote {path}", file=sys.stderr)
    if args.min_speedup and doc["speedup"] < args.min_speedup:
        print(f"FAIL: speedup {doc['speedup']:.2f}x < "
              f"--min-speedup {args.min_speedup}x", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
