"""Sharded-execution benchmark: per-device-count scaling on gpt2_medium.

Compiles the ``gpt2_block`` workload (dimensions derived from
``configs/gpt2_medium.py``) once per device count, partitions it over a
``data x model`` mesh with ``strategy="auto"``, and measures wall time of
the sharded program against the single-device lowering of the same
design.  Writes the machine-readable document the CI ``sharding-smoke``
job uploads::

    results/bench/sharding.json

CLI::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.sharding_bench --quick
    PYTHONPATH=src python -m benchmarks.sharding_bench    # full-size block

``--quick`` runs the smoke-scale block (S=32, D=64) at few iterations —
the PR-latency mode; the full run uses the gpt2_medium width (D=1024).
Device counts default to the powers of two available on the platform
(``--devices 1,2,4,8`` to override).  On CPU hosts the sharded program
is *not* expected to beat single-device wall time (every "device" shares
the same cores); the record captures collective structure + modeled
cycles per count, and the CI gate checks presence/shape, not speedup.

The suite is registered in ``benchmarks.run`` as ``sharding`` (quick
mode), so the nightly ``--json`` collection carries its rows.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "results" / "bench"


def _mesh_shape(n: int) -> tuple[int, int]:
    """(data, model) factorization for n devices: tensor axis capped at 2
    so every count >= 2 exercises both parallelism families."""
    if n <= 1:
        return (1, 1)
    return (n // 2, 2)


def _time_program(fn, env, iters: int) -> float:
    import jax
    jax.block_until_ready(fn(env))          # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(env)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def run_bench(*, quick: bool = False, devices: list[int] | None = None,
              iters: int | None = None, seed: int = 0) -> dict:
    """One scaling sweep; returns the ``sharding.json`` document."""
    import jax
    import numpy as np

    from repro import api as codo
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.models import dataflow_models as dm

    cfg = get_config("gpt2-medium")
    S, D = (32, 64) if quick else (128, cfg.d_model)
    iters = iters or (3 if quick else 10)
    avail = len(jax.devices())
    if devices is None:
        devices = [n for n in (1, 2, 4, 8) if n <= avail]
    bad = [n for n in devices if n > avail]
    if bad:
        raise SystemExit(
            f"device counts {bad} exceed the {avail} available — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={max(bad)}")

    graph = dm.gpt2_block(S, D)
    rng = np.random.default_rng(seed)
    base = codo.compile(graph)
    env = {n: rng.standard_normal(
        tuple(graph.buffers[n].shape)).astype("float32")
        for n in base.input_names}

    single = base.lower(jit=True)
    single_ms = _time_program(lambda e: single(base.make_env(**e)), env,
                              iters)

    records = []
    for n in devices:
        if n == 1:
            records.append({"devices": 1, "mesh": "1x1",
                            "strategy": "single", "ms": round(single_ms, 4),
                            "est_cycles": int(base.cost.total_cycles),
                            "collectives": 0, "collective_bytes": 0,
                            "speedup_vs_1": 1.0})
            continue
        dp, tp = _mesh_shape(n)
        mesh = make_debug_mesh((dp, tp), ("data", "model"))
        prog = codo.compile(graph, mesh=mesh)
        plan = prog.sharding
        low = prog.lower(jit=True)
        ms = _time_program(lambda e: low(prog.make_env(**e)), env, iters)
        records.append({
            "devices": n, "mesh": f"{dp}x{tp}",
            "strategy": plan.strategy, "ms": round(ms, 4),
            "est_cycles": int(plan.estimated_cycles),
            "collectives": len(plan.steps),
            "collective_bytes": int(plan.collective_bytes),
            "speedup_vs_1": round(single_ms / max(ms, 1e-9), 3),
        })

    return {
        "workload": f"gpt2_block(S={S},D={D})",
        "config": cfg.name,
        "backend": jax.default_backend(),
        "quick": quick,
        "iters": iters,
        "available_devices": avail,
        "single_device_ms": round(single_ms, 4),
        "records": records,
    }


def sharding_rows():
    """The ``benchmarks.run`` suite entry: quick-mode rows + sharding.json."""
    from benchmarks.tables import Row
    doc = run_bench(quick=True)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "sharding.json").write_text(
        json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return [
        Row(f"sharding/devices={r['devices']}", r["ms"],
            f"mesh={r['mesh']};strategy={r['strategy']};"
            f"collectives={r['collectives']};"
            f"est_cycles={r['est_cycles']};"
            f"speedup_vs_1={r['speedup_vs_1']}")
        for r in doc["records"]
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Per-device-count sharded execution scaling.")
    ap.add_argument("--quick", action="store_true",
                    help="smoke-scale block + few iterations (PR/CI mode)")
    ap.add_argument("--devices", default="",
                    help="comma list of device counts (default: powers of "
                         "two up to the platform's device count)")
    ap.add_argument("--iters", type=int, default=0,
                    help="timed iterations per count (0 = mode default)")
    ap.add_argument("--json", default=str(OUT / "sharding.json"),
                    metavar="PATH", help="output document path")
    args = ap.parse_args(argv)

    devices = ([int(x) for x in args.devices.split(",") if x.strip()]
               or None)
    doc = run_bench(quick=args.quick, devices=devices,
                    iters=args.iters or None)
    path = Path(args.json)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")

    print(f"sharding {doc['workload']} [{doc['backend']}] "
          f"iters={doc['iters']} devices_available="
          f"{doc['available_devices']}")
    for r in doc["records"]:
        print(f"  {r['devices']:>2d} dev ({r['mesh']:>4s} {r['strategy']:<9s})"
              f"  {r['ms']:8.3f} ms  est {r['est_cycles']:>12,d} cyc  "
              f"{r['collectives']} collectives "
              f"({r['collective_bytes']:,d} B)  "
              f"{r['speedup_vs_1']:.2f}x vs 1")
    print(f"wrote {path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
