"""Training-step benchmark: pipeline-compiled vs plain-jit step time.

Drives the graph-level-autodiff :class:`repro.api.CompiledTrainStep` on
the ``gpt2_block_loss`` workload and measures one full training step —
forward + backward + AdamW update — against the plain-jit reference
(``jax.value_and_grad`` of the traced loss graph's oracle execution plus
``training.optimizer.adamw_update``, one fused jit).  Both paths compute
the same numbers (checked before timing, within the documented fp band);
the comparison isolates what the pass pipeline's fusion/routing buys or
costs on this backend.  Writes the machine-readable document the nightly
CI job uploads::

    results/bench/training.json

CLI (the CI ``training-smoke`` job runs ``--quick``)::

    PYTHONPATH=src python -m benchmarks.train_bench --quick
    PYTHONPATH=src python -m benchmarks.train_bench       # full-size block

``--quick`` shrinks the block (S=32, D=64) and the step counts for PR
latency; the full run uses the paper-scale GPT-2 block (S=128, D=1024).
``--max-ratio X`` exits 1 if compiled/jit step time exceeds X (CI
regression gate; 0 disables).

The suite is also registered in ``benchmarks.run`` as ``training`` (quick
mode), so the nightly ``--json`` collection carries its rows.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "results" / "bench"


def _time_steps(fn, n: int, warmup: int) -> dict:
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return {
        "steps": n,
        "mean_ms": round(statistics.fmean(times) * 1e3, 4),
        "min_ms": round(min(times) * 1e3, 4),
        "p50_ms": round(sorted(times)[len(times) // 2] * 1e3, 4),
    }


def run_bench(*, quick: bool = False, steps: int | None = None,
              seed: int = 0) -> dict:
    """One measured comparison; returns the ``training.json`` document."""
    import jax
    import numpy as np

    from repro import api as codo
    from repro.kernels import register_all
    from repro.models.dataflow_models import gpt2_block_loss_fn
    from repro.training.optimizer import OptConfig, adamw_init, adamw_update

    register_all()
    S, D = (32, 64) if quick else (128, 1024)
    if steps is None:
        steps = 5 if quick else 10
    warmup = 2

    step = codo.compile(gpt2_block_loss_fn, (S, D), (S, D), grad=True,
                        name="gpt2_block_loss")
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((S, D)).astype(np.float32)
    target = rng.standard_normal((S, D)).astype(np.float32)
    params = step.init_params()
    opt_state = step.init_opt_state(params)

    # -- plain-jit reference: one fused value_and_grad + update ------------
    oc = OptConfig()
    src, g = step.source, step.graphs

    def loss_of(ps, bx, bt):
        return src.execute({"x": bx, "target": bt, **ps})[g.loss].reshape(())

    @jax.jit
    def jit_step(ps, st, bx, bt):
        loss, grads = jax.value_and_grad(loss_of)(ps, bx, bt)
        ps, st, metrics = adamw_update(grads, st, ps, oc)
        metrics["loss"] = loss
        return ps, st, metrics

    # Parity before timing: both paths must produce the same step.
    jp, js, jm = jax.block_until_ready(
        jit_step(params, adamw_init(params), x, target))
    cp, cs, cm = step.step(params, opt_state, x, target)
    np.testing.assert_allclose(float(cm["loss"]), float(jm["loss"]),
                               rtol=1e-5, atol=1e-6)
    for w in step.param_names:
        np.testing.assert_allclose(np.asarray(cp[w]), np.asarray(jp[w]),
                                   rtol=2e-3, atol=1e-4,
                                   err_msg=f"post-update {w} diverged")

    # -- timed loops (state held fixed so every step does the same work) --
    compiled = _time_steps(
        lambda: jax.block_until_ready(
            step.step(params, opt_state, x, target)[2]["loss"]),
        steps, warmup)
    st0 = adamw_init(params)
    jit = _time_steps(
        lambda: jax.block_until_ready(jit_step(params, st0, x, target)[2]),
        steps, warmup)

    return {
        "workload": f"gpt2_block_loss(S={S},D={D})",
        "backend": jax.default_backend(),
        "quick": quick,
        "params": len(step.param_names),
        "compiled": compiled,
        "plain_jit": jit,
        "ratio": round(compiled["mean_ms"] / max(jit["mean_ms"], 1e-9), 3),
        "backward_tasks": len(step.backward.compiled.graph.tasks),
    }


def training_rows():
    """The ``benchmarks.run`` suite entry: quick-mode rows + training.json."""
    from benchmarks.tables import Row
    doc = run_bench(quick=True)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "training.json").write_text(
        json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return [
        Row("training/compiled_ms", doc["compiled"]["mean_ms"],
            f"min_ms={doc['compiled']['min_ms']}"),
        Row("training/plain_jit_ms", doc["plain_jit"]["mean_ms"],
            f"min_ms={doc['plain_jit']['min_ms']}"),
        Row("training/ratio", doc["ratio"],
            f"{doc['workload']};backend={doc['backend']}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Compiled-vs-plain-jit training step time.")
    ap.add_argument("--quick", action="store_true",
                    help="small block + fewer steps (PR/CI latency)")
    ap.add_argument("--steps", type=int, default=0,
                    help="timed steps (0 = scaled from --quick)")
    ap.add_argument("--json", default=str(OUT / "training.json"),
                    metavar="PATH", help="output document path")
    ap.add_argument("--max-ratio", type=float, default=0.0,
                    help="exit 1 if compiled/jit step time exceeds this "
                         "(CI regression gate; 0 disables)")
    args = ap.parse_args(argv)

    doc = run_bench(quick=args.quick, steps=args.steps or None)
    path = Path(args.json)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")

    comp, jit = doc["compiled"], doc["plain_jit"]
    print(f"train_bench {doc['workload']} [{doc['backend']}] "
          f"params={doc['params']} bwd_tasks={doc['backward_tasks']}")
    print(f"  compiled:  {comp['mean_ms']:.2f} ms/step "
          f"(min {comp['min_ms']:.2f})")
    print(f"  plain-jit: {jit['mean_ms']:.2f} ms/step "
          f"(min {jit['min_ms']:.2f})")
    print(f"  compiled-vs-jit ratio={doc['ratio']:.2f}")
    print(f"wrote {path}", file=sys.stderr)
    if args.max_ratio and doc["ratio"] > args.max_ratio:
        print(f"FAIL: ratio {doc['ratio']:.2f} > "
              f"--max-ratio {args.max_ratio}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
