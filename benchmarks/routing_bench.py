"""Per-group pallas-vs-xla routing benchmark (the ISSUE-5 measurement).

For every fusion group the router maps to a Pallas kernel in the
acceptance workloads (``gpt2_block``, ``resnet18``), run the routed chain
**both ways** on identical inputs — the registered kernel step vs the
same tasks' jnp fns composed and jit'd (the ``xla-fused`` path) — and
report the per-group latency pair.  Besides the CSV rows every suite
emits, this one writes the machine-readable document the nightly CI job
uploads::

    results/bench/routing_groups.json

Backend note: on TPU the kernel step is the compiled Pallas kernel; on
CPU/GPU hosts it is the kernel's fused jnp reference under one jit (see
``repro/kernels/streamfuse/ops.py``), so both sides compile through XLA
and the comparison measures the fusion decision, not interpret-mode
overhead.  The JSON records the backend so readers can tell which regime
produced the numbers.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "results" / "bench"

WORKLOADS = {
    "gpt2_block": lambda dm: dm.gpt2_block(),
    "resnet18": lambda dm: dm.resnet18(32),
}

WARMUP = 3
REPS = 9


def _time_pair(fn_a, fn_b, arg, block) -> tuple[float, float]:
    """Best-of-N for two callables on the same input, reps *interleaved*
    so machine-load drift hits both sides equally."""
    for _ in range(WARMUP):
        block(fn_a(arg))
        block(fn_b(arg))
    best_a = best_b = float("inf")
    for rep in range(REPS):
        first, second = (fn_a, fn_b) if rep % 2 == 0 else (fn_b, fn_a)
        for fn in (first, second):
            t0 = time.perf_counter()
            block(fn(arg))
            dt = time.perf_counter() - t0
            if fn is fn_a:
                best_a = min(best_a, dt)
            else:
                best_b = min(best_b, dt)
    return best_a * 1e3, best_b * 1e3


def bench_workload(name: str, build) -> list[dict]:
    import jax

    from repro.core import CodoOptions, codo_opt, lower
    from repro.core.routing import registered_patterns
    from repro.models import dataflow_models as dm

    graph = build(dm)
    compiled = codo_opt(graph, CodoOptions.preset("opt5"), cache=None)
    low = lower(compiled, jit=False)
    pats = {p.name: p for p in registered_patterns()}

    # Full buffer scope: every intermediate value, produced task by task —
    # the routed chains' inputs are sliced out of it below.
    scope = dict(dm.random_inputs(compiled.graph))
    for t in compiled.graph.toposort():
        scope.update(t.fn(scope))

    records = []
    for group in low.groups:
        for route in group.routes:
            tasks = [compiled.graph.task(n) for n in route.tasks]
            interior = {t.writes[0].buffer for t in tasks[:-1]}
            ext = sorted({a.buffer for t in tasks for a in t.reads
                          if a.buffer not in interior})
            env = {b: scope[b] for b in ext}
            out_buf = tasks[-1].writes[0].buffer

            kernel_step = pats[route.kernel].factory(
                compiled.graph, group, tasks)
            fns = [t.fn for t in tasks]

            def xla_fused(e, _fns=fns, _out=out_buf):
                s = dict(e)
                for f in _fns:
                    s.update(f(s))
                return {_out: s[_out]}

            block = jax.block_until_ready
            pallas_ms, xla_ms = _time_pair(kernel_step, jax.jit(xla_fused),
                                           env, block)
            records.append({
                "workload": name, "gid": group.gid, "kernel": route.kernel,
                "tasks": list(route.tasks),
                "pallas_ms": round(pallas_ms, 4),
                "xla_ms": round(xla_ms, 4),
                "speedup": round(xla_ms / max(pallas_ms, 1e-9), 4),
            })
    return records


def routing_groups(write_json: bool = True):
    """Suite entry (``benchmarks.run`` registers it as ``routing``)."""
    import jax

    from benchmarks.tables import Row

    all_records = []
    for name, build in WORKLOADS.items():
        all_records.extend(bench_workload(name, build))

    # Same-computation parity on CPU hosts means speedups fluctuate around
    # 1.0 with machine noise; "no slower" is judged with this tolerance.
    tolerance = 0.05
    doc = {"backend": jax.default_backend(), "tolerance": tolerance,
           "records": all_records}
    if write_json:
        OUT.mkdir(parents=True, exist_ok=True)
        (OUT / "routing_groups.json").write_text(
            json.dumps(doc, indent=2, sort_keys=True) + "\n")

    rows = [Row(f"routing/{r['workload']}/g{r['gid']}/{r['kernel']}",
                r["speedup"],
                f"pallas_ms={r['pallas_ms']};xla_ms={r['xla_ms']};"
                f"tasks={len(r['tasks'])}")
            for r in all_records]
    routed = len(all_records)
    wins = sum(1 for r in all_records if r["speedup"] >= 1.0 - tolerance)
    rows.append(Row("routing/summary", routed,
                    f"groups_routed;no_slower={wins}/{routed}"
                    f"(tol={tolerance:.0%});backend={doc['backend']}"))
    return rows
