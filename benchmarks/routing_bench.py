"""Per-group pallas-vs-xla routing benchmark (ISSUE-5 measurement,
ISSUE-6 gate evidence).

For every *structurally matched* kernel chain in the acceptance workloads
(``gpt2_block``, ``resnet18``) — gate-free, so the measurement covers
chains the cost gate rejects as well as the ones it routes — run the
chain **both ways** on identical inputs: the registered kernel step vs
the same tasks' jnp fns composed and jit'd (the ``xla-fused`` path).
Each record carries the cost gate's verdict (``decision``, predicted
routed/generic cycles) next to the measured latency pair, so the JSON is
both a regression fixture and the calibration corpus for
:func:`repro.core.costmodel.calibrate_routing_params`.  Two documents are
written::

    results/bench/routing_groups.json        # measured pairs + decisions
    results/bench/routing_calibration.json   # predicted vs measured + fit

Backend note: on TPU the kernel step is the compiled Pallas kernel; on
CPU/GPU hosts it is the kernel's fused jnp reference under one jit (see
``repro/kernels/streamfuse/ops.py``), so both sides compile through XLA
and the comparison measures the fusion decision, not interpret-mode
overhead.  The JSON records the backend so readers can tell which regime
produced the numbers.

CLI (the CI ``routing-regression`` job)::

    PYTHONPATH=src python -m benchmarks.routing_bench --quick --check-gate

``--quick`` shrinks shapes/repeats for PR latency; ``--check-gate``
exits 1 if any chain the gate *accepts* measures more than ``tolerance``
slower than its xla-fused twin — i.e. the predictor let a loser through.
Best-of-5 CPU timings on shared runners still see >5% machine-noise
swings, so a first-pass offender is re-measured alone at a higher
best-of count and judged on that number; only a repeat offender fails
the job.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

OUT = Path(__file__).resolve().parents[1] / "results" / "bench"

WORKLOADS = {
    "gpt2_block": lambda dm: dm.gpt2_block(),
    "resnet18": lambda dm: dm.resnet18(32),
    # Attention/recurrence families (ROADMAP item 4): the flashattn chain
    # plus the two chunked-scan recurrences.  On CPU the gate predicts a
    # loss for the scans (sequential reference vs XLA's fused scan), so
    # they are measured as rejected evidence, not judged by --check-gate.
    "mha_batched": lambda dm: dm.mha_batched(BH=8, S=128, hd=64),
    "rglru_block": lambda dm: dm.rglru_block(B=4, S=256, D=128),
    "ssd_block": lambda dm: dm.ssd_block(nc=16, BH=16, P=32, N=32),
}

# PR-gate shapes: big enough that the gate's accepted set is non-trivial
# (resnet below H=32 falls entirely under the conv win threshold), small
# enough to keep the job in tens of seconds.
QUICK_WORKLOADS = {
    "gpt2_block": lambda dm: dm.gpt2_block(S=64),
    "resnet18": lambda dm: dm.resnet18(32),
    "mha_batched": lambda dm: dm.mha_batched(BH=4, S=64, hd=32),
    "rglru_block": lambda dm: dm.rglru_block(B=2, S=128, D=64),
    "ssd_block": lambda dm: dm.ssd_block(nc=8, BH=8, P=32, N=32),
}

WARMUP = 3
REPS = 9
QUICK_WARMUP = 2
QUICK_REPS = 5
# Gate offenders get one solo re-measurement at this best-of count
# before the job fails — parity chains sit at ~1.0x and best-of-5 noise
# alone trips the 5% line a few percent of the time per chain.
RECHECK_REPS = 21

# Same-computation parity on CPU hosts means speedups fluctuate around
# 1.0 with machine noise; "no slower" is judged with this tolerance.
TOLERANCE = 0.05


def _time_pair(fn_a, fn_b, arg, block, warmup=WARMUP,
               reps=REPS) -> tuple[float, float]:
    """Best-of-N for two callables on the same input, reps *interleaved*
    so machine-load drift hits both sides equally."""
    for _ in range(warmup):
        block(fn_a(arg))
        block(fn_b(arg))
    best_a = best_b = float("inf")
    for rep in range(reps):
        first, second = (fn_a, fn_b) if rep % 2 == 0 else (fn_b, fn_a)
        for fn in (first, second):
            t0 = time.perf_counter()
            block(fn(arg))
            dt = time.perf_counter() - t0
            if fn is fn_a:
                best_a = min(best_a, dt)
            else:
                best_b = min(best_b, dt)
    return best_a * 1e3, best_b * 1e3


def _record_key(r: dict) -> tuple:
    return (r["gid"], r["kernel"], tuple(r["tasks"]))


def bench_workload(name: str, build, *, warmup=WARMUP,
                   reps=REPS, only=None) -> list[dict]:
    import jax

    from repro.core import CodoOptions, codo_opt, lower
    from repro.core.routing import decide_route, match_group
    from repro.core.tuning import TuningDB
    from repro.models import dataflow_models as dm

    graph = build(dm)
    compiled = codo_opt(graph, CodoOptions.preset("opt5"), cache=None)
    low = lower(compiled, jit=False)
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}

    # Full buffer scope: every intermediate value, produced task by task —
    # the matched chains' inputs are sliced out of it below.
    scope = dict(dm.random_inputs(compiled.graph))
    for t in compiled.graph.toposort():
        scope.update(t.fn(scope))

    records = []
    fresh_db = TuningDB()            # gate verdicts from the predictor only
    for group in low.groups:
        # Gate-free structural matches: measure everything matchable, not
        # just what the gate routed — rejected chains are the evidence the
        # gate is *right* to reject them.
        for pat, tasks in match_group(compiled.graph, group.tasks, impl):
            if only is not None and (group.gid, pat.name,
                                     tuple(t.name for t in tasks)) not in only:
                continue
            route = decide_route(compiled.graph, tasks, pat,
                                 hw=compiled.options.hw, db=fresh_db)
            interior = {t.writes[0].buffer for t in tasks[:-1]}
            ext = sorted({a.buffer for t in tasks for a in t.reads
                          if a.buffer not in interior})
            env = {b: scope[b] for b in ext}
            out_buf = tasks[-1].writes[0].buffer

            kernel_step = pat.factory(compiled.graph, group, tasks)
            fns = [t.fn for t in tasks]

            def xla_fused(e, _fns=fns, _out=out_buf):
                s = dict(e)
                for f in _fns:
                    s.update(f(s))
                return {_out: s[_out]}

            block = jax.block_until_ready
            pallas_ms, xla_ms = _time_pair(kernel_step, jax.jit(xla_fused),
                                           env, block, warmup, reps)
            pred_r, pred_g = (route.predicted_routed_cycles,
                              route.predicted_generic_cycles)
            records.append({
                "workload": name, "gid": group.gid, "kernel": pat.name,
                "tasks": [t.name for t in tasks],
                "decision": route.decision,
                "routed": route.routed,
                "predicted_routed_cycles": round(pred_r, 1),
                "predicted_generic_cycles": round(pred_g, 1),
                "predicted_speedup": round(pred_g / max(pred_r, 1e-9), 4),
                "pallas_ms": round(pallas_ms, 4),
                "xla_ms": round(xla_ms, 4),
                "speedup": round(xla_ms / max(pallas_ms, 1e-9), 4),
            })
    return records


def _calibration_doc(doc: dict) -> dict:
    """Predicted-vs-measured per chain plus the constants a calibration
    pass would fit from this run (what the nightly CI job uploads)."""
    from repro.core.costmodel import calibrate_routing_params
    fitted = calibrate_routing_params(doc)
    return {
        "backend": doc["backend"],
        "tolerance": doc["tolerance"],
        "fitted_params": dataclasses.asdict(fitted),
        "records": [{k: r[k] for k in
                     ("workload", "gid", "kernel", "decision",
                      "predicted_speedup", "speedup")}
                    for r in doc["records"]],
    }


def build_doc(quick: bool = False) -> dict:
    import jax
    workloads = QUICK_WORKLOADS if quick else WORKLOADS
    warmup = QUICK_WARMUP if quick else WARMUP
    reps = QUICK_REPS if quick else REPS
    all_records = []
    for name, build in workloads.items():
        all_records.extend(bench_workload(name, build,
                                          warmup=warmup, reps=reps))
    return {"backend": jax.default_backend(), "tolerance": TOLERANCE,
            "quick": quick, "records": all_records}


def write_docs(doc: dict) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "routing_groups.json").write_text(
        json.dumps(doc, indent=2, sort_keys=True) + "\n")
    (OUT / "routing_calibration.json").write_text(
        json.dumps(_calibration_doc(doc), indent=2, sort_keys=True) + "\n")


def remeasure_offenders(doc: dict) -> dict:
    """Re-time only the chains :func:`check_gate` flagged, solo and at
    best-of-``RECHECK_REPS``, and patch the fresh numbers into the doc.
    A chain that is genuinely slower stays an offender; one that tripped
    the line on machine noise converges back above it."""
    tol = float(doc.get("tolerance", TOLERANCE))
    failing = [r for r in doc["records"]
               if r.get("routed") and r["speedup"] < 1.0 - tol]
    workloads = QUICK_WORKLOADS if doc.get("quick") else WORKLOADS
    for name in sorted({r["workload"] for r in failing}):
        only = {_record_key(r) for r in failing if r["workload"] == name}
        redone = {_record_key(r): r
                  for r in bench_workload(name, workloads[name],
                                          warmup=WARMUP, reps=RECHECK_REPS,
                                          only=only)}
        doc["records"] = [
            redone.get(_record_key(r), r) if r["workload"] == name else r
            for r in doc["records"]]
    return doc


def check_gate(doc: dict) -> list[str]:
    """Regression predicate for the CI gate job: every chain the cost
    gate routed must measure no more than ``tolerance`` slower than its
    xla-fused twin.  (Gate-rejected chains are measured but not judged —
    they run on the generic path in production.)"""
    tol = float(doc.get("tolerance", TOLERANCE))
    fails = []
    for r in doc["records"]:
        if r.get("routed") and r["speedup"] < 1.0 - tol:
            fails.append(
                f"{r['workload']}/g{r['gid']}/{r['kernel']}: routed chain "
                f"measured {r['speedup']:.3f}x vs xla (tolerance "
                f"{1 - tol:.2f}x, decision={r['decision']})")
    return fails


def _rows(doc: dict):
    from benchmarks.tables import Row
    records = doc["records"]
    rows = [Row(f"routing/{r['workload']}/g{r['gid']}/{r['kernel']}",
                r["speedup"],
                f"decision={r['decision']};pred={r['predicted_speedup']};"
                f"pallas_ms={r['pallas_ms']};xla_ms={r['xla_ms']};"
                f"tasks={len(r['tasks'])}")
            for r in records]
    routed = [r for r in records if r.get("routed")]
    wins = sum(1 for r in routed if r["speedup"] >= 1.0 - doc["tolerance"])
    rows.append(Row("routing/summary", len(records),
                    f"chains_measured;routed={len(routed)};"
                    f"routed_no_slower={wins}/{len(routed)}"
                    f"(tol={doc['tolerance']:.0%});"
                    f"backend={doc['backend']}"))
    return rows


def routing_groups(write_json: bool = True):
    """Suite entry (``benchmarks.run`` registers it as ``routing``)."""
    doc = build_doc(quick=False)
    if write_json:
        write_docs(doc)
    return _rows(doc)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="pallas-vs-xla per-chain routing benchmark")
    ap.add_argument("--quick", action="store_true",
                    help="reduced shapes/repeats (the PR-gate mode)")
    ap.add_argument("--check-gate", action="store_true",
                    help="exit 1 if a gate-routed chain is >tolerance "
                         "slower than xla-fused")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing results/bench/*.json")
    args = ap.parse_args(argv)

    doc = build_doc(quick=args.quick)
    if not args.no_json:
        write_docs(doc)
    print("name,value,derived")
    for row in _rows(doc):
        print(row.csv())
    if args.check_gate:
        fails = check_gate(doc)
        if fails:
            print(f"gate: {len(fails)} suspect chain(s); re-measuring "
                  f"solo at best-of-{RECHECK_REPS}", file=sys.stderr)
            doc = remeasure_offenders(doc)
            if not args.no_json:
                write_docs(doc)
            fails = check_gate(doc)
        for f in fails:
            print(f"GATE FAIL: {f}", file=sys.stderr)
        if fails:
            return 1
        routed = sum(1 for r in doc["records"] if r.get("routed"))
        print(f"gate check: {routed} routed chains within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
