"""Parametrized smoke coverage for every entry in ``repro.configs``:
each config must build, expose sane derived quantities, yield valid
param specs on a host mesh (strictly, via its smoke variant), and
round-trip through the ``configs.base`` dataclass schema."""

import dataclasses

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import (ArchConfig, MoEConfig, SSMConfig, get_config,
                           list_configs)

ALL = list_configs()


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("name", ALL)
def test_config_builds_and_derives(name):
    cfg = get_config(name)
    assert cfg.name == name and isinstance(cfg, ArchConfig)
    assert cfg.padded_vocab >= cfg.vocab and cfg.padded_vocab % 256 == 0
    assert cfg.hd > 0 and cfg.q_dim == cfg.n_heads * cfg.hd
    assert cfg.param_count() > 0
    assert 0 < cfg.active_param_count() <= cfg.param_count()
    smoke = cfg.smoke()
    assert smoke.family == cfg.family and smoke.name == name + "-smoke"


@pytest.mark.parametrize("name", ALL)
def test_config_param_specs_on_host_mesh(name):
    """The smoke variant's parameter tree shards cleanly (strict mode) on
    a small host mesh — every large leaf gets at least one sharded dim."""
    from repro.distributed.sharding import param_specs
    from repro.models import transformer as tf
    cfg = get_config(name).smoke()
    mesh = FakeMesh({"data": 2, "model": 2})
    shapes = tf.param_shapes(cfg)
    specs = param_specs(shapes, mesh, cfg)   # lenient: placement preference
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert flat and all(isinstance(s, P) for s in flat)
    # strict mode must agree on a trivial mesh (axis size 1 divides all)
    strict = param_specs(shapes, FakeMesh({"data": 1, "model": 1}), cfg,
                         strict=True)
    assert jax.tree.structure(strict) == jax.tree.structure(specs)


@pytest.mark.parametrize("name", ALL)
def test_config_roundtrips_through_base_schema(name):
    cfg = get_config(name)
    doc = dataclasses.asdict(cfg)
    # nested dataclasses come back as dicts; rebuild them explicitly
    if doc["moe"] is not None:
        doc["moe"] = MoEConfig(**doc["moe"])
    if doc["ssm"] is not None:
        doc["ssm"] = SSMConfig(**doc["ssm"])
    doc["block_pattern"] = tuple(doc["block_pattern"])
    back = ArchConfig(**doc)
    assert back == cfg
    assert back.param_count() == cfg.param_count()
