"""Attention/recurrence family routing + diagnostics (the ISSUE-7 tentpole).

Covers the full-chain ``flashattn.mha`` superseding the old softmaxmm
tail inside gpt2_block, routed-vs-generic numerics for the three traced
recurrence workloads (reference backend and the true Pallas interpret
path), their ``rejected[]``/``RouteDecision`` diagnostics under the
un-forced CPU cost gate, the TPU parameterisation that flips those same
chains to predicted wins, and the pattern-registry epoch riding the
lowering memo key.  Kernel numerics live in ``tests/test_kernels.py``;
the gate's calibration in ``tests/test_costmodel_routing.py``.
"""

import pytest

from repro.core import CodoOptions, codo_opt
from repro.core.costmodel import DEFAULT_ROUTING_PARAMS, estimate_chain
from repro.core.lowering import (LOWER_CACHE_STATS, clear_lower_cache,
                                 fusion_groups, lower, verify_routing)
from repro.core.routing import (KernelPattern, match_group,
                                register_kernel_pattern, routing_epoch)
from repro.kernels import register_all
from repro.models import dataflow_models as dm

register_all()

# workload builder -> the kernel its recurrence chain must route to
FAMILIES = [
    ("mha_batched", dm.mha_batched, "flashattn.mha"),
    ("rglru_block", dm.rglru_block, "rglru.scan"),
    ("ssd_block", dm.ssd_block, "ssd.scan"),
]


def _compile(graph, budget=64):
    return codo_opt(graph, CodoOptions.preset("opt5", budget_units=budget),
                    cache=None)


def _matches(compiled):
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    out = []
    for g in fusion_groups(compiled.graph, impl):
        out.extend(match_group(compiled.graph, g.tasks, impl))
    return out


# --------------------------------------------------------------------------
# Longest-match-first: flashattn supersedes the softmaxmm tail
# --------------------------------------------------------------------------


def test_flashattn_supersedes_softmaxmm_in_gpt2():
    """gpt2's attention chain (matmul -> scale -> softmax -> matmul) must
    be claimed whole by flashattn.mha; the shorter softmaxmm tail and the
    mmchain starting at the q-projection would both overlap it and must
    lose the longest-match tie-break."""
    c = _compile(dm.gpt2_block(S=16, D=64))
    matched = _matches(c)
    names = {pat.name for pat, _tasks in matched}
    assert "flashattn.mha" in names
    assert "streamfuse.softmaxmm" not in names
    chain = next(ts for pat, ts in matched if pat.name == "flashattn.mha")
    assert [t.op for t in chain] == ["matmul", "ewise", "softmax", "matmul"]
    # the FFN mmchain survives on non-overlapping tasks
    assert "streamfuse.mmchain" in names
    ff = next(ts for pat, ts in matched
              if pat.name == "streamfuse.mmchain")
    assert {t.name for t in ff}.isdisjoint({t.name for t in chain})


def test_single_task_scan_chains_match():
    """The scan patterns opt into single-task chains (allow_single);
    everything else keeps the >= 2 floor."""
    c = _compile(dm.rglru_block(B=1, S=8, D=8))
    matched = _matches(c)
    scans = [ts for pat, ts in matched if pat.name == "rglru.scan"]
    assert scans and len(scans[0]) == 1 and scans[0][0].op == "scan"


# --------------------------------------------------------------------------
# Routed == generic, per family (reference backend + true Pallas interpret)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("wname,build,kernel", FAMILIES)
def test_recurrence_family_routes_and_verifies(monkeypatch, wname, build,
                                               kernel):
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = _compile(build())
    low = lower(c, jit=False)
    routed = {r.kernel for g in low.groups for r in g.routes}
    assert kernel in routed, f"{wname} must route its chain to {kernel}"
    verify_routing(c, dm.random_inputs(c.graph), rtol=3e-4, atol=3e-4)
    entries = c.diagnostics.group_kernels.values()
    hits = [rr for e in entries for rr in e["routes"]
            if rr["kernel"] == kernel]
    assert hits and all(rr["decision"] == "forced" for rr in hits)


@pytest.mark.parametrize("wname,build,kernel", FAMILIES)
def test_recurrence_family_true_pallas_interpret(monkeypatch, wname, build,
                                                 kernel):
    """CODO_PALLAS_INTERPRET=1 swaps the jnp references for the real
    Pallas kernel bodies (interpret mode on CPU) — parity must hold
    through the routed lowering for every family."""
    monkeypatch.setenv("CODO_PALLAS_INTERPRET", "1")
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")
    c = _compile(build())
    routed = verify_routing(c, dm.random_inputs(c.graph),
                            rtol=3e-4, atol=3e-4)
    assert any(r.kernel == kernel
               for g in routed.groups for r in g.routes)


# --------------------------------------------------------------------------
# Diagnostics under the un-forced CPU gate
# --------------------------------------------------------------------------


@pytest.mark.parametrize("wname,build,kernel", FAMILIES)
def test_cpu_gate_rejects_with_full_diagnostics(monkeypatch, wname, build,
                                                kernel):
    """On CPU the scan kernels are calibrated below break-even and the
    default mha_batched shape sits under the flashattn win threshold, so
    each chain lands in ``rejected[]`` as a fully-priced RouteDecision —
    not silently dropped by the matcher."""
    monkeypatch.delenv("CODO_FORCE_PALLAS", raising=False)
    monkeypatch.delenv("CODO_DISABLE_PALLAS", raising=False)
    monkeypatch.delenv("CODO_ROUTING_CALIBRATION", raising=False)
    monkeypatch.setenv("CODO_BACKEND", "cpu")
    c = _compile(build())
    low = lower(c, jit=False)
    assert all(r.kernel != kernel for g in low.groups for r in g.routes)
    rej = [r for g in low.groups for r in g.rejected if r.kernel == kernel]
    assert rej, f"{wname}: the {kernel} chain must still match structurally"
    for r in rej:
        assert r.decision == "predicted-loss" and not r.routed
        assert r.predicted_routed_cycles > 0
        assert r.predicted_generic_cycles > 0
        assert r.predicted_generic_cycles < r.predicted_routed_cycles
        assert all(c.graph.task(n) is not None for n in r.tasks)
    # ...and the verdict rides on the diagnostics
    entries = c.diagnostics.group_kernels.values()
    assert any(any(rr["kernel"] == kernel
                   and rr["decision"] == "predicted-loss"
                   and "predicted_generic_cycles" in rr
                   for rr in e["rejected"]) for e in entries)


@pytest.mark.parametrize("wname,build,kernel", [
    # nightly-bench sizes: big enough to amortize the fixed launch term
    ("mha_batched", lambda: dm.mha_batched(), "flashattn.mha"),
    ("rglru_block", lambda: dm.rglru_block(B=4, S=256, D=128), "rglru.scan"),
    ("ssd_block", lambda: dm.ssd_block(nc=16, BH=16, P=32, N=32), "ssd.scan"),
])
def test_tpu_params_predict_win_for_recurrences(wname, build, kernel):
    """Under the TPU gate parameters (pipelined VMEM stages, interior HBM
    round-trips on the generic path) the same chains price as wins — the
    CPU rejection above is a backend verdict, not a structural one."""
    c = _compile(build())
    impl = c.buffer_plan.impl if c.buffer_plan else {}
    chains = [ts for g in fusion_groups(c.graph, impl)
              for pat, ts in match_group(c.graph, g.tasks, impl)
              if pat.name == kernel]
    assert chains
    est = estimate_chain(c.graph, chains[0], kernel,
                         params=DEFAULT_ROUTING_PARAMS["tpu"])
    assert est.win and est.predicted_speedup > 1.0


# --------------------------------------------------------------------------
# Registry epoch rides the lowering memo key
# --------------------------------------------------------------------------


def test_pattern_registration_flips_memo_key():
    """Registering a pattern bumps the routing epoch, which is part of
    the lowering memo key — a program lowered against the old registry is
    never served after the registry changes."""
    c = _compile(dm.rglru_block(B=1, S=16, D=8))
    lower(c, jit=False)          # assigns fused_group ids (hash settles)
    clear_lower_cache()
    lower(c, jit=False)
    assert LOWER_CACHE_STATS["misses"] == 1
    lower(c, jit=False)                      # same key: a hit
    assert LOWER_CACHE_STATS["hits"] == 1

    before = routing_epoch()
    # an op kind no graph produces: match-inert, but epoch still bumps
    register_kernel_pattern(KernelPattern(
        "test.epoch-probe", ("matmul", "never_op"),
        factory=lambda *a, **k: None))
    assert routing_epoch() == before + 1
    lower(c, jit=False)                      # new epoch: must re-lower
    assert LOWER_CACHE_STATS["misses"] == 2
