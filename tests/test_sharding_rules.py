"""Direct unit tests for repro.distributed.sharding — the rule tables and
spec sanitizer (strict + lenient contracts), independent of any model."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (ShardingSpecError, batch_specs,
                                        cache_specs, param_specs,
                                        sanitize_spec, shard_hint)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 4, "model": 2})
CFG = get_config("gpt2-medium").smoke()


def sds(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), np.float32)


# --------------------------------------------------------------------------
# sanitize_spec
# --------------------------------------------------------------------------


def test_strict_is_the_default_and_rejects():
    with pytest.raises(ShardingSpecError, match="does not divide"):
        sanitize_spec(P("model"), (7,), MESH)


def test_strict_error_names_the_path():
    with pytest.raises(ShardingSpecError, match="embed/tok"):
        sanitize_spec(P("model"), (7,), MESH, path="embed/tok")


def test_strict_rejects_unknown_axis():
    with pytest.raises(ShardingSpecError, match="only has axes"):
        sanitize_spec(P("pod"), (8,), MESH)


def test_rank_mismatch_rejected_in_both_modes():
    with pytest.raises(ShardingSpecError, match="rank"):
        sanitize_spec(P("data", None, None), (8, 8), MESH)
    with pytest.raises(ShardingSpecError, match="rank"):
        sanitize_spec(P("data", None, None), (8, 8), MESH, strict=False)


def test_lenient_drops_only_the_offending_axis():
    assert sanitize_spec(P("data", "model"), (8, 7), MESH,
                         strict=False) == P("data", None)
    assert sanitize_spec(P("pod", "model"), (8, 8), MESH,
                         strict=False) == P(None, "model")


def test_lenient_tuple_entry_keeps_dividing_prefix():
    mesh = FakeMesh({"pod": 2, "data": 4})
    assert sanitize_spec(P(("pod", "data"),), (8,), mesh,
                         strict=False) == P(("pod", "data"))
    assert sanitize_spec(P(("pod", "data"),), (2,), mesh,
                         strict=False) == P("pod")


def test_clean_spec_passes_through_strict():
    assert sanitize_spec(P("data", "model"), (8, 8), MESH) \
        == P("data", "model")
    assert sanitize_spec(P(None, None), (3, 5), MESH) == P(None, None)


# --------------------------------------------------------------------------
# param_specs (rule table + strictness plumbing)
# --------------------------------------------------------------------------


def test_param_rule_table_megatron_pairing():
    # stacked group leaves carry a leading layer dim
    tree = {"groups": {"b0_attn": {
        "wq": {"w": sds(2, 64, 64)}, "wo": {"w": sds(2, 64, 64)},
        "mlp": {"w_in": {"w": sds(2, 64, 128)},
                "w_out": {"w": sds(2, 128, 64)}},
    }}}
    specs = param_specs(tree, MESH, CFG)
    g = specs["groups"]["b0_attn"]
    # column-parallel in, row-parallel out (leading stacked dim unsharded)
    assert g["wq"]["w"] == P(None, "data", "model")
    assert g["wo"]["w"] == P(None, "model", "data")
    assert g["mlp"]["w_in"]["w"] == P(None, "data", "model")
    assert g["mlp"]["w_out"]["w"] == P(None, "model", "data")


def test_param_specs_lenient_by_default_replicates_undivisible():
    tree = {"embed": {"tok": sds(7, 64)}}   # 7 not divisible by model=2
    specs = param_specs(tree, MESH, CFG)
    assert specs["embed"]["tok"] == P(None, "data")


def test_param_specs_strict_raises_with_param_path():
    tree = {"embed": {"tok": sds(7, 64)}}
    with pytest.raises(ShardingSpecError, match="embed/tok"):
        param_specs(tree, MESH, CFG, strict=True)


def test_param_specs_strict_passes_on_clean_shapes():
    from repro.models import transformer as tf
    mesh = FakeMesh({"data": 1, "model": 1})  # axis size 1 divides anything
    shapes = tf.param_shapes(CFG)
    specs = param_specs(shapes, mesh, CFG, strict=True)
    assert jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# batch / cache rule tables
# --------------------------------------------------------------------------


def test_batch_specs_shard_leading_dim_only():
    specs = batch_specs({"tokens": sds(8, 16), "scalar": sds()}, MESH)
    assert specs["tokens"] == P("data", None)
    assert specs["scalar"] == P()


def test_cache_specs_head_divisibility_switch():
    # kv heads divisible by model -> heads sharded; else sequence sharded
    kv_ok = {"groups": {"b0_attn": {"k": sds(2, 8, 32, 2, 16)}}}
    kv_odd = {"groups": {"b0_attn": {"k": sds(2, 8, 32, 3, 16)}}}
    ok = cache_specs(kv_ok, MESH, CFG)["groups"]["b0_attn"]["k"]
    odd = cache_specs(kv_odd, MESH, CFG)["groups"]["b0_attn"]["k"]
    assert ok == P(None, "data", None, "model", None)
    assert odd == P(None, "data", "model", None, None)


def test_shard_hint_is_identity_outside_mesh():
    x = np.ones((4, 4), np.float32)
    assert shard_hint(x, "data", None) is x
