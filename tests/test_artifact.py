"""Portable compiled-design artifacts (docs/artifact_format.md).

Covers the full contract: lossless round-trips (structural hash, cache
key, numerics), fresh-interpreter imports of exported ResNet/GPT-2
designs that lower + execute + verify, strict validation with
path-qualified errors, the forward-compat policy (unknown fields warn,
version-major mismatch fails), integrity/fusion cross-checks, the disk
cache's JSON mirror, and the compiler CLI verbs.
"""

import json
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import (ArtifactError, ArtifactWarning, CodoOptions,
                        CompileCache, artifact_summary, codo_opt,
                        export_artifact, import_artifact, lower,
                        lower_artifact, validate_artifact, verify_lowering)
from repro.core.compiler import main as compiler_main
from repro.core.compiler import profile_table
from repro.models import dataflow_models as dm

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _compile_block(budget=64):
    return codo_opt(dm.residual_block(1, 8, 12),
                    CodoOptions(budget_units=budget), cache=None)


# --------------------------------------------------------------------------
# Round-trip fidelity
# --------------------------------------------------------------------------


def test_roundtrip_preserves_structure_and_numerics(tmp_path):
    c = _compile_block()
    path = tmp_path / "design.json"
    doc = export_artifact(c, path)
    assert path.exists() and json.loads(path.read_text()) == doc

    r = import_artifact(path)
    assert r.graph.structural_hash() == c.graph.structural_hash()
    assert r.options == c.options
    assert r.options.cache_key() == c.options.cache_key()
    assert r.buffer_plan.impl == c.buffer_plan.impl
    assert r.transfer_plan.channel_of == c.transfer_plan.channel_of
    assert r.schedule_report.degrees == c.schedule_report.degrees
    assert list(r.schedule_report.stage_latencies) == \
        list(c.schedule_report.stage_latencies)
    assert r.diagnostics.pass_names == c.diagnostics.pass_names
    # costs recompute identically from the reconstructed graph
    np.testing.assert_allclose(r.final.total_cycles, c.final.total_cycles)
    np.testing.assert_allclose(r.speedup, c.speedup)

    # and the imported design executes + verifies against the oracle
    src = dm.residual_block(1, 8, 12)
    env = dm.random_inputs(src)
    verify_lowering(src, r, env, rtol=3e-4, atol=3e-4)


def test_reexport_is_idempotent(tmp_path):
    c = _compile_block()
    doc = export_artifact(c)
    doc2 = export_artifact(import_artifact(doc))
    # diagnostics/cost are carried through, graph bytes identical
    assert doc2["graph"] == doc["graph"]
    assert doc2["integrity"] == doc["integrity"]
    assert doc2["fusion"] == doc["fusion"]


def test_lower_artifact_shortcut(tmp_path):
    c = _compile_block()
    path = tmp_path / "d.json"
    export_artifact(c, path)
    low = lower_artifact(path, jit=False)
    env = dm.random_inputs(dm.residual_block(1, 8, 12))
    out = low(env)
    assert set(out) == {b.name for b in c.graph.outputs()}


def test_export_rejects_closure_tasks():
    from repro.core import DataflowGraph, ewise_task
    g = DataflowGraph("closure")
    g.buffer("x", (4,), kind="input")
    g.buffer("y", (4,), kind="output")
    g.add_task(ewise_task("t", "y", ["x"], (4,), fn=lambda env: {"y": env["x"]}))
    c = codo_opt(g, cache=None)
    with pytest.raises(ArtifactError, match="closure"):
        export_artifact(c)


# --------------------------------------------------------------------------
# Fresh-interpreter round-trips (the paper's hand-off property)
# --------------------------------------------------------------------------


def _fresh_interpreter(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600, env=env)


@pytest.mark.parametrize("workload", ["resnet", "gpt2"])
def test_fresh_interpreter_import_executes_and_verifies(tmp_path, workload):
    if workload == "resnet":
        build = "dm.resnet18(16)"
        g = dm.resnet18(16)
    else:
        from repro.core.compiler import batch_workloads
        build = 'batch_workloads(seq=8)["gpt2-medium"]()'
        g = batch_workloads(seq=8)["gpt2-medium"]()
    path = tmp_path / f"{workload}.json"
    c = codo_opt(g, CodoOptions(budget_units=64), cache=None)
    export_artifact(c, path)

    proc = _fresh_interpreter(f"""
        from repro.core import import_artifact, lower, verify_lowering
        from repro.core.compiler import batch_workloads
        from repro.core.passes import PASS_RUN_COUNTS
        from repro.models import dataflow_models as dm

        c = import_artifact({str(path)!r})
        assert not PASS_RUN_COUNTS, "import must not run any compile pass"
        assert all(t.fn is not None for t in c.graph.tasks)
        src = {build}
        env = dm.random_inputs(src)
        out = lower(c, jit=False)(env)
        assert set(out) == {{b.name for b in c.graph.outputs()}}
        verify_lowering(src, c, env, rtol=3e-4, atol=3e-4)
        print("ARTIFACT_IMPORT_OK", c.final.total_cycles)
    """)
    assert proc.returncode == 0, proc.stderr
    assert "ARTIFACT_IMPORT_OK" in proc.stdout
    # the cost model reproduces the exporter's estimate exactly
    reported = float(proc.stdout.split("ARTIFACT_IMPORT_OK")[1].split()[0])
    np.testing.assert_allclose(reported, c.final.total_cycles, rtol=1e-9)


# --------------------------------------------------------------------------
# Validation / compatibility policy
# --------------------------------------------------------------------------


def test_corrupted_artifacts_fail_with_paths(tmp_path):
    doc = export_artifact(_compile_block())

    bad = json.loads(json.dumps(doc))
    del bad["graph"]["tasks"][0]["loops"]
    bad["graph"]["buffers"][0]["shape"] = "oops"
    bad["graph"]["buffers"][1]["kind"] = "wat"
    with pytest.raises(ArtifactError) as e:
        validate_artifact(bad)
    msg = str(e.value)
    assert "graph.tasks[0].loops: missing required field" in msg
    assert "graph.buffers[0].shape: expected list, got str" in msg
    assert "graph.buffers[1].kind" in msg

    # dangling access reference
    bad = json.loads(json.dumps(doc))
    bad["graph"]["tasks"][0]["reads"][0]["buffer"] = "ghost"
    with pytest.raises(ArtifactError, match="not a declared graph buffer"):
        validate_artifact(bad)

    # truncated file
    trunc = tmp_path / "t.json"
    trunc.write_text(json.dumps(doc)[:80])
    with pytest.raises(ArtifactError, match="not valid JSON"):
        import_artifact(trunc)

    # not an artifact at all
    with pytest.raises(ArtifactError, match="JSON object"):
        validate_artifact([1, 2, 3])


def test_version_policy():
    doc = export_artifact(_compile_block())

    old = dict(doc, schema_version="2.0")   # different major: fail
    with pytest.raises(ArtifactError, match="schema_version"):
        import_artifact(old)

    with pytest.raises(ArtifactError, match="major"):
        validate_artifact(dict(doc, schema_version="0.9"))

    newer = dict(doc, schema_version="1.7")  # newer minor: warn + proceed
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import_artifact(newer)
    assert any("newer" in str(x.message) for x in w
               if issubclass(x.category, ArtifactWarning))

    with pytest.raises(ArtifactError, match="major.*minor"):
        validate_artifact(dict(doc, schema_version="one"))


def test_unknown_fields_warn_everywhere():
    doc = export_artifact(_compile_block())
    mod = json.loads(json.dumps(doc))
    mod["novel_top"] = 1
    mod["graph"]["tasks"][0]["novel_task_field"] = True
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import_artifact(mod)
    msgs = [str(x.message) for x in w if issubclass(x.category, ArtifactWarning)]
    assert any("artifact.novel_top" in m for m in msgs)
    assert any("graph.tasks[0].novel_task_field" in m for m in msgs)


def test_integrity_and_fusion_cross_checks():
    doc = export_artifact(_compile_block())

    tampered = json.loads(json.dumps(doc))
    tampered["graph"]["tasks"][0]["loops"][0]["trip"] += 1
    with pytest.raises(ArtifactError, match="integrity"):
        import_artifact(tampered)
    # ... unless the edit is deliberate
    c = import_artifact(tampered, check_integrity=False)
    assert c.graph.tasks[0].loops[0].trip == \
        tampered["graph"]["tasks"][0]["loops"][0]["trip"]

    inconsistent = json.loads(json.dumps(doc))
    inconsistent["fusion"]["groups"] = [[t["name"] for t in
                                        inconsistent["graph"]["tasks"]]]
    with pytest.raises(ArtifactError, match="fusion"):
        import_artifact(inconsistent)


def test_unregistered_op_kind_fails_actionably():
    doc = export_artifact(_compile_block())
    mod = json.loads(json.dumps(doc))
    for t in mod["graph"]["tasks"]:
        t["spec"]["kind"] = "never-registered"
    mod["integrity"] = None
    with pytest.raises(ArtifactError, match="no registered|register_op"):
        import_artifact(mod)


def test_fusion_kernels_forward_compat_both_directions(tmp_path, monkeypatch):
    """Satellite: the v1.1 `fusion.kernels` field must interoperate both
    ways — a v1.0-era document (without it) imports cleanly under this
    reader, and a document from a *newer* minor (with the field plus
    future extras) warns-and-runs rather than failing."""
    from repro.core import lower
    from repro.kernels import register_all
    register_all()
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = codo_opt(dm.gpt2_block(S=16, D=64), CodoOptions(budget_units=64),
                 cache=None)
    lower(c, jit=False)                          # record real routing
    doc = export_artifact(c)
    assert doc["schema_version"] == "1.5"
    assert len(doc["fusion"]["kernels"]) == len(doc["fusion"]["groups"])
    assert any(k.startswith("pallas:") for k in doc["fusion"]["kernels"])

    # direction 1: v1.0 document (no kernels field) -> imports, no warning
    old = json.loads(json.dumps(doc))
    del old["fusion"]["kernels"]
    old["schema_version"] = "1.0"
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = import_artifact(old)
    assert not [x for x in w if issubclass(x.category, ArtifactWarning)]
    assert r.graph.structural_hash() == c.graph.structural_hash()

    # direction 2: a newer minor with the field plus an unknown fusion
    # extra -> warns (newer version, unknown field) and still runs
    newer = json.loads(json.dumps(doc))
    newer["schema_version"] = "1.7"
    newer["fusion"]["novel_fusion_field"] = True
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r2 = import_artifact(newer)
    msgs = [str(x.message) for x in w
            if issubclass(x.category, ArtifactWarning)]
    assert any("newer" in m for m in msgs)
    assert any("fusion.novel_fusion_field" in m for m in msgs)
    assert all(t.fn is not None for t in r2.graph.tasks)

    # routing drift warns (advisory field), never fails
    drift = json.loads(json.dumps(doc))
    drift["fusion"]["kernels"] = ["xla-fused"] * len(drift["fusion"]["groups"])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import_artifact(drift)
    assert any("fusion.kernels drift" in str(x.message) for x in w
               if issubclass(x.category, ArtifactWarning))

    # ...but a misaligned kernels list is a hard validation error
    bad = json.loads(json.dumps(doc))
    bad["fusion"]["kernels"] = list(bad["fusion"]["kernels"]) + ["xla-fused"]
    with pytest.raises(ArtifactError, match="must align"):
        validate_artifact(bad)


def test_unknown_option_fields_warn_not_fail():
    """Forward compat reaches into `options`: a newer writer's extra knob
    is dropped with a warning, not a hard failure."""
    doc = export_artifact(_compile_block())
    mod = json.loads(json.dumps(doc))
    mod["options"]["novel_knob"] = 7
    mod["options"]["hw"]["novel_hw_field"] = 1.5
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = import_artifact(mod)
    msgs = [str(x.message) for x in w if issubclass(x.category, ArtifactWarning)]
    assert any("options.novel_knob" in m for m in msgs)
    assert any("options.hw.novel_hw_field" in m for m in msgs)
    assert r.options.budget_units == 64      # known fields still apply


def test_corrupted_section_values_fail_with_artifact_errors():
    doc = export_artifact(_compile_block())

    bad = json.loads(json.dumps(doc))
    bad["cost"]["final_cycles"] = "fast"
    with pytest.raises(ArtifactError, match="cost.final_cycles"):
        import_artifact(bad)

    bad = json.loads(json.dumps(doc))
    bad["integrity"]["structural_hash"] = 123
    with pytest.raises(ArtifactError, match="integrity.structural_hash"):
        import_artifact(bad)

    bad = json.loads(json.dumps(doc))
    bad["schedule"]["degrees"] = {"t": "many"}
    with pytest.raises(ArtifactError, match="schedule does not reconstruct"):
        import_artifact(bad)


def test_cost_drift_warns():
    doc = export_artifact(_compile_block())
    mod = json.loads(json.dumps(doc))
    mod["cost"]["final_cycles"] *= 2
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import_artifact(mod)
    assert any("cost-model drift" in str(x.message) for x in w)


# --------------------------------------------------------------------------
# Cache JSON mirror
# --------------------------------------------------------------------------


def test_cache_json_mirror_is_importable(tmp_path):
    cache = CompileCache(disk_dir=tmp_path, json_mirror=True)
    c = codo_opt(dm.residual_block(1, 8, 12), CodoOptions(budget_units=64),
                 cache=cache)
    jsons = list(tmp_path.glob("*.json"))
    assert len(jsons) == 1 and cache.stats.json_mirrors == 1
    r = import_artifact(jsons[0])
    assert r.graph.structural_hash() == c.graph.structural_hash()
    assert all(t.fn is not None for t in r.graph.tasks)
    # mirror rides with the pickle lifecycle
    cache.clear(disk=True)
    assert not list(tmp_path.glob("*.json")) and not list(tmp_path.glob("*.pkl"))


def test_cache_mirror_ships_to_process_pool_workers(tmp_path):
    from repro.core.compiler import ablation_jobs, batch_workloads, codo_opt_batch
    wl = batch_workloads(seq=8)
    jobs = ablation_jobs({"gpt2-medium": wl["gpt2-medium"]},
                         presets=["opt2", "opt5"], budget_units=64)
    cache = CompileCache(disk_dir=tmp_path, json_mirror=True)
    res = codo_opt_batch(jobs, cache=cache, max_workers=2, executor="process")
    assert all(r.ok for r in res)
    jsons = list(tmp_path.glob("*.json"))
    assert jsons, "workers must honour the parent's json_mirror flag"
    assert import_artifact(jsons[0]).graph.name


def test_cache_mirror_env_knob(tmp_path, monkeypatch):
    monkeypatch.setenv("CODO_CACHE_JSON", "1")
    cache = CompileCache(disk_dir=tmp_path)
    assert cache.json_mirror
    monkeypatch.delenv("CODO_CACHE_JSON")
    assert not CompileCache(disk_dir=tmp_path).json_mirror


# --------------------------------------------------------------------------
# CLI verbs + profile
# --------------------------------------------------------------------------


def test_cli_export_import_profile(tmp_path, capsys):
    art_dir = tmp_path / "arts"
    rc = compiler_main(["--configs", "gpt2-medium", "--opts", "opt5",
                        "--executor", "thread", "--jobs", "1", "--no-cache",
                        "--seq", "8", "--export", str(art_dir), "--profile"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "exported 1/1 artifacts" in out
    assert "pass profile" in out and "schedule" in out
    path = art_dir / "gpt2-medium-opt5.json"
    assert path.exists()

    rc = compiler_main(["--import-artifact", str(path), "--profile"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "artifact gpt2_medium (schema v1.5)" in out
    assert "== codo_opt(gpt2_medium) ==" in out
    assert "-- passes(gpt2_medium) --" in out

    assert "gpt2_medium" in artifact_summary(path)


def test_profile_table_skips_cache_hits():
    cache = CompileCache()
    a = codo_opt(dm.residual_block(1, 8, 12), CodoOptions(budget_units=64),
                 cache=cache)
    b = codo_opt(dm.residual_block(1, 8, 12), CodoOptions(budget_units=64),
                 cache=cache)
    assert b.cache_hit
    table = profile_table([a.diagnostics, b.diagnostics])
    assert "1 compiles" in table
    assert profile_table([b.diagnostics]).startswith("profile: no pass records")


def test_serve_artifact_mode(tmp_path):
    path = tmp_path / "d.json"
    export_artifact(_compile_block(), path)
    proc = _fresh_interpreter(f"""
        import repro.launch.serve as serve
        rc = serve.main(["--artifact", {str(path)!r}, "--requests", "2"])
        assert rc == 0
    """)
    assert proc.returncode == 0, proc.stderr
    assert "requests in" in proc.stdout


def test_lowered_artifact_matches_direct_lowering():
    c = _compile_block()
    direct = lower(c, jit=False)
    via_artifact = lower(import_artifact(export_artifact(c)), jit=False)
    env = dm.random_inputs(dm.residual_block(1, 8, 12))
    got, want = via_artifact(env), direct(env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-6, atol=1e-6)
    assert [g.tasks for g in via_artifact.groups] == \
        [g.tasks for g in direct.groups]


# --------------------------------------------------------------------------
# Bound-weight payloads (schema v1.3): self-contained served models
# --------------------------------------------------------------------------


def _bound_mlp():
    """A tiny compiled MLP with every weight bound to 1.5× the
    deterministic initializer — outputs observably differ from what an
    initializer fallback would produce."""
    import repro.api as codo
    from repro.core import frontend

    def mlp(x):
        h = frontend.fc(x, 8, relu=True)
        return frontend.fc(h, 4)

    p = codo.compile(mlp, (4, 6), cache=None)
    p.bind(**{b.name: np.float32(1.5)
              * frontend.weight_init(b.shape, b.dtype)
              for b in p.graph.weights()})
    return p


def test_v13_weights_roundtrip_embedded_and_sidecar(tmp_path):
    from repro.core.artifact import artifact_weights, sidecar_path
    p = _bound_mlp()
    want = dict(p._bindings)
    assert want                                     # the test is non-vacuous

    emb = tmp_path / "emb.json"
    p.export(str(emb), weights=True)
    doc = json.loads(emb.read_text())
    assert doc["schema_version"] == "1.5"
    assert doc["weights"]["format"] == "embedded"
    got = artifact_weights(emb)
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_array_equal(got[k], np.asarray(want[k]))

    sc = tmp_path / "sc.json"
    p.export(str(sc), weights=True, sidecar=True)
    assert sidecar_path(sc).exists()
    doc = json.loads(sc.read_text())
    assert doc["weights"]["format"] == "sidecar"
    assert doc["weights"]["file"] == sidecar_path(sc).name
    assert all("data" not in e for e in doc["weights"]["arrays"].values())
    got = artifact_weights(sc)
    for k in want:
        np.testing.assert_array_equal(got[k], np.asarray(want[k]))


def test_v13_fresh_interpreter_serves_without_weight_init(tmp_path):
    """The self-contained-model property: a weight-carrying artifact
    executes in a fresh interpreter with ``weight_init`` unreachable —
    no model code, no initializer, bit-identical outputs."""
    import repro.api as codo
    p = _bound_mlp()
    x = np.random.default_rng(0).standard_normal((4, 6)).astype("float32")
    np.savez(tmp_path / "ref.npz", x=x, y=np.asarray(p(x)))
    path = tmp_path / "m.json"
    p.export(str(path), weights=True)
    del codo

    proc = _fresh_interpreter(f"""
        import numpy as np
        from repro.core import frontend

        def boom(shape, dtype=np.float32):
            raise AssertionError("weight_init reached while serving a "
                                 "v1.3 weight-carrying artifact")
        frontend.weight_init = boom

        import repro.api as codo
        p = codo.load({str(path)!r})
        ref = np.load({str(tmp_path / "ref.npz")!r})
        out = np.asarray(p(ref["x"]))
        np.testing.assert_array_equal(out, ref["y"])
        print("V13_SELF_CONTAINED_OK")
    """)
    assert proc.returncode == 0, proc.stderr
    assert "V13_SELF_CONTAINED_OK" in proc.stdout


def test_v13_hash_mismatch_fails(tmp_path):
    from repro.core.artifact import artifact_weights
    p = _bound_mlp()
    doc = p.export(weights=True)
    name = next(iter(doc["weights"]["arrays"]))

    forged = json.loads(json.dumps(doc))
    forged["weights"]["arrays"][name]["sha256"] = "0" * 64
    with pytest.raises(ArtifactError, match="content hash mismatch"):
        artifact_weights(forged)

    import base64
    tampered = json.loads(json.dumps(doc))
    entry = tampered["weights"]["arrays"][name]
    raw = bytearray(base64.b64decode(entry["data"]))
    raw[0] ^= 0xFF                                  # flip payload bits
    entry["data"] = base64.b64encode(bytes(raw)).decode()
    with pytest.raises(ArtifactError, match="content hash mismatch"):
        artifact_weights(tampered)


def test_v13_missing_sidecar_fails(tmp_path):
    import repro.api as codo
    from repro.core.artifact import artifact_weights, sidecar_path
    p = _bound_mlp()
    path = tmp_path / "m.json"
    p.export(str(path), weights=True, sidecar=True)
    sidecar_path(path).unlink()
    with pytest.raises(ArtifactError, match="missing or unreadable"):
        artifact_weights(path)
    with pytest.raises(ArtifactError, match="missing or unreadable"):
        codo.load(path)                             # load never half-binds


def test_v13_validation_rejects_malformed_weights():
    doc = _bound_mlp().export(weights=True)
    name = next(iter(doc["weights"]["arrays"]))

    bad_fmt = json.loads(json.dumps(doc))
    bad_fmt["weights"]["format"] = "carrier-pigeon"
    with pytest.raises(ArtifactError, match="weights.format"):
        validate_artifact(bad_fmt)

    no_file = json.loads(json.dumps(doc))
    no_file["weights"]["format"] = "sidecar"
    with pytest.raises(ArtifactError, match="required for sidecar"):
        validate_artifact(no_file)

    no_data = json.loads(json.dumps(doc))
    del no_data["weights"]["arrays"][name]["data"]
    with pytest.raises(ArtifactError, match="required for embedded"):
        validate_artifact(no_data)

    not_weight = json.loads(json.dumps(doc))
    arrays = not_weight["weights"]["arrays"]
    arrays["x"] = dict(arrays[name])                # an *input* buffer
    with pytest.raises(ArtifactError, match="not a weight buffer"):
        validate_artifact(not_weight)


def test_pre_v13_documents_without_weights_still_import():
    from repro.core.artifact import artifact_weights
    doc = export_artifact(_compile_block())         # no weights section
    assert "weights" not in doc
    assert artifact_weights(doc) == {}
    c = import_artifact(json.loads(json.dumps(doc, sort_keys=True)))
    assert c.graph.structural_hash() == doc["integrity"]["structural_hash"]
