"""Reuse-buffer generation (Fig. 7) + auto-scheduler (PA/UP/DP) tests."""

import numpy as np

from repro.core import (CodoOptions, DataflowGraph, codo_opt, conv2d_task,
                        determine_buffers, eliminate_fine, ewise_task,
                        fine_violations, generate_reuse_buffers, graph_latency,
                        pad_task, parallel_safety, sequential_latency)
from repro.core.costmodel import V5E, task_cost
from repro.core.schedule import (apply_degree, autoschedule,
                                 max_task_degree, parallelizable_loops)
from repro.models import dataflow_models as dm


def _conv_graph():
    g = DataflowGraph("conv")
    g.buffer("x", (1, 3, 16, 16), kind="input")
    g.buffer("w", (8, 3, 3, 3), kind="weight")
    g.buffer("xp", (1, 3, 18, 18))
    g.buffer("y", (1, 8, 16, 16), kind="output")
    g.add_task(pad_task("pad", "xp", "x", 1, 3, 16, 16, 1))
    g.add_task(conv2d_task("conv", "y", "xp", "w", 1, 8, 3, 16, 16, 3, 3))
    return g


def test_reuse_buffer_generation():
    g = _conv_graph()
    rep = generate_reuse_buffers(g)
    assert "conv" in rep.rewritten
    conv = g.task("conv")
    assert "lb_xp" in conv.reuse_buffers and "wb_xp" in conv.reuse_buffers
    ci, khm1, row = conv.reuse_buffers["lb_xp"]
    assert (ci, khm1) == (3, 2)             # kh-1 = 2 retained rows
    # read is exact-once over the padded input extent
    r = conv.reads_from("xp")[0]
    assert r.stream_shape == (1, 3, 18, 18)
    # ring classification (Fig. 7 guidance)
    rings = {l.var: l.ring for l in conv.loops}
    assert rings["kh"] == rings["kw"] == "reduction"
    assert rings["h"] == rings["w"] == "fifo"
    assert parallel_safety(conv, "kh") == "free"
    assert parallel_safety(conv, "h") == "coordinate"
    assert parallel_safety(conv, "n") in ("unsafe", "coordinate", "free")


def test_reuse_then_fine_makes_fifo():
    g = _conv_graph()
    generate_reuse_buffers(g)
    eliminate_fine(g)
    assert not fine_violations(g)
    plan = determine_buffers(g)
    assert plan.impl["xp"] == "fifo"


def test_pa_up_dp_monotonic_and_budgeted():
    g = dm.conv3_block(1, 3, 18)
    from repro.core import eliminate_coarse
    eliminate_coarse(g)
    eliminate_fine(g)
    generate_reuse_buffers(g)
    eliminate_fine(g)
    plan = determine_buffers(g)
    rep = autoschedule(g, plan, budget=900)
    lat = rep.stage_latencies
    assert lat["PA"] <= lat["base"]
    assert lat["final"] <= lat["base"]
    assert rep.units_used <= 900 * 2   # DP may rebalance: soft budget check
    # degrees realized on legal loops only
    for t in g.tasks:
        for l in t.loops:
            if l.parallel > 1:
                assert parallel_safety(t, l.var) != "unsafe"
                assert l.parallel <= l.trip


def test_dp_reclaims_units():
    g = dm.conv3_block(1, 3, 18)
    c_with = codo_opt(g, CodoOptions(enable_dp=True))
    c_without = codo_opt(g, CodoOptions(enable_dp=False))
    assert c_with.schedule_report.units_used <= c_without.schedule_report.units_used
    # DP trades at most ~n x latency of non-critical tasks: final stays close
    assert c_with.final.total_cycles <= c_without.final.total_cycles * 2.5


def test_apply_degree_caps():
    g = _conv_graph()
    generate_reuse_buffers(g)
    conv = g.task("conv")
    cap = max_task_degree(conv)
    realized = apply_degree(conv, 10**9)
    assert realized <= cap
    assert all(l.parallel <= l.trip for l in conv.loops)


def test_first_emit_penalty_for_unrewritten_reduction():
    """Fig. 2 Issue 2: un-rewritten reductions emit late."""
    from repro.core import matmul_task

    g = DataflowGraph("late")
    g.buffer("a", (8, 64), kind="input")
    g.buffer("b", (64, 8), kind="weight")
    g.buffer("c", (8, 8))
    g.buffer("o", (8, 8), kind="output")
    g.add_task(matmul_task("mm", "c", "a", "b", 8, 8, 64))
    g.add_task(ewise_task("e", "o", ["c"], (8, 8)))
    mm = g.task("mm")
    late = task_cost(g, mm).first_emit
    eliminate_fine(g)
    early = task_cost(g, mm).first_emit
    assert early < late * 0.2               # rewriting emits much earlier


def test_sequential_baseline_is_slowest():
    g = dm.residual_mlp(16, 64)
    c = codo_opt(g)
    assert c.baseline.total_cycles >= c.final.total_cycles
    assert c.speedup >= 1.0
