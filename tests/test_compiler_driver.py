"""PassManager / compile-cache / batch-driver subsystem tests.

Covers the tentpole invariants: pipeline ordering + invalidation re-runs,
ablation presets as data, content-addressed cache hits that skip the
pipeline (asserted via pass counters), disk-cache round trips, and the
batch ablation driver + CLI.
"""

import dataclasses

import pytest

from repro.core import (ABLATION_PRESETS, CodoOptions, CompileCache,
                        PASS_RUN_COUNTS, Pass, PassManager, codo_opt,
                        codo_opt_batch, verify_violation_free)
from repro.core.compiler import ablation_jobs, main as compiler_main
from repro.models import dataflow_models as dm


def small_graph():
    return dm.residual_block(1, 8, 12)


# --------------------------------------------------------------------------
# Pipeline ordering + presets
# --------------------------------------------------------------------------


def test_default_pipeline_order_matches_paper():
    assert PassManager.default().names() == [
        "coarse", "fine", "reuse", "buffers", "offchip", "schedule"]


def test_presets_are_pass_sets():
    for name, names in ABLATION_PRESETS.items():
        opts = CodoOptions.preset(name)
        assert opts.pass_set() == names, name
    # legacy constructors are the same data
    assert CodoOptions.opt3().pass_set() == ABLATION_PRESETS["opt3"]
    assert CodoOptions.opt5().pass_set() == ABLATION_PRESETS["opt5"]
    with pytest.raises(KeyError):
        CodoOptions.preset("opt9")
    with pytest.raises(KeyError):
        CodoOptions.from_passes({"coarse", "nonexistent"})


def test_from_passes_rejects_inexpressible_sets():
    # reuse/offchip are gated together: one without the other must raise,
    # not silently widen the pass set
    with pytest.raises(ValueError):
        CodoOptions.from_passes({"coarse", "offchip", "buffers"})
    with pytest.raises(ValueError):
        CodoOptions.from_passes({"reuse", "buffers"})


def test_census_can_be_disabled():
    mgr = PassManager(census=False)
    c = codo_opt(small_graph(), cache=None, manager=mgr)
    assert all(r.coarse_before == -1 for r in c.diagnostics.records)
    assert "ms" in c.diagnostics.table()
    assert not verify_violation_free(c)


def test_preset_overrides_forwarded():
    opts = CodoOptions.preset("opt5", budget_units=128, hbm_channels=4)
    assert opts.budget_units == 128 and opts.hbm_channels == 4


def test_diagnostics_record_passes_and_invalidation_rerun():
    c = codo_opt(small_graph(), cache=None)
    names = [(r.name, r.rerun) for r in c.diagnostics.records]
    # reuse declares it invalidates fine -> fine re-runs right after, merged
    assert names == [("coarse", False), ("fine", False), ("reuse", False),
                     ("fine", True), ("buffers", False), ("offchip", False),
                     ("schedule", False)]
    assert all(r.coarse_after == 0 for r in c.diagnostics.records[1:])
    assert c.diagnostics.total_seconds > 0
    assert "fine" in c.diagnostics.pass_seconds


def test_disabled_passes_do_not_run():
    c = codo_opt(small_graph(), CodoOptions.preset("opt2"), cache=None)
    assert c.diagnostics.pass_names == ["coarse", "buffers"]
    assert c.fine_report is None and c.schedule_report is None


def test_register_before_after_ordering():
    mgr = PassManager.default()
    noop = Pass(name="noop", run=lambda g, o, out: None)
    mgr.register(noop, before="buffers")
    assert mgr.names().index("noop") == mgr.names().index("buffers") - 1
    with pytest.raises(ValueError):
        mgr.register(noop)
    c = codo_opt(small_graph(), cache=None, manager=mgr)
    assert "noop" in c.diagnostics.pass_names
    assert not verify_violation_free(c)


# --------------------------------------------------------------------------
# Structural hashing
# --------------------------------------------------------------------------


def test_structural_hash_stable_across_builds():
    assert small_graph().structural_hash() == small_graph().structural_hash()


def test_structural_hash_sensitive_to_structure():
    g1, g2 = small_graph(), small_graph()
    g2.tasks[0].loops[0].trip += 1
    assert g1.structural_hash() != g2.structural_hash()


def test_structural_hash_sees_semantic_constants():
    # scale factors are OpSpec attrs — structural data — so graphs with
    # different numerics never collide in the cache
    from repro.models.dataflow_models import GB

    def build(s):
        b = GB("g")
        x = b.load(b.input("x", (4, 4)))
        b.mark_output(b.scale(x, s))
        return b.g

    assert build(0.5).structural_hash() != build(0.25).structural_hash()
    assert build(0.5).structural_hash() == build(0.5).structural_hash()


def test_structural_hash_sees_closure_const_tags():
    # closure-built tasks keep the legacy contract: constants surface via
    # const: tags (specs are absent, so tags are the only structural trace)
    from repro.core import DataflowGraph, ewise_task

    def build(s):
        g = DataflowGraph("g")
        g.buffer("x", (4,), kind="input")
        g.buffer("o", (4,), kind="output")
        t = ewise_task("t", "o", ["x"], (4,), fn=lambda e, _s=s: {"o": e["x"] * _s})
        t.tags.add(f"const:scale:{s!r}")
        g.add_task(t)
        return g

    assert build(0.5).structural_hash() != build(0.25).structural_hash()


def test_options_cache_key_sensitive():
    assert CodoOptions().cache_key() == CodoOptions().cache_key()
    assert CodoOptions().cache_key() != CodoOptions(budget_units=64).cache_key()
    assert CodoOptions.opt4().cache_key() != CodoOptions.opt5().cache_key()


# --------------------------------------------------------------------------
# Compile cache
# --------------------------------------------------------------------------


def test_cache_hit_skips_passes_and_preserves_result():
    cache = CompileCache()
    c1 = codo_opt(small_graph(), cache=cache)
    counts_after_first = dict(PASS_RUN_COUNTS)
    # fresh build of the same model -> same structural hash -> hit
    c2 = codo_opt(small_graph(), cache=cache)
    assert dict(PASS_RUN_COUNTS) == counts_after_first, "cache hit re-ran passes"
    assert c2.cache_hit and not c1.cache_hit
    assert c2.speedup == c1.speedup
    assert c2.fifo_fraction == c1.fifo_fraction
    assert c2.final.total_cycles == c1.final.total_cycles
    assert c2.compile_seconds < c1.compile_seconds
    assert not verify_violation_free(c2)
    assert cache.stats.hits == 1 and cache.stats.misses == 1


def test_cache_respects_options():
    cache = CompileCache()
    codo_opt(small_graph(), CodoOptions.opt2(), cache=cache)
    c = codo_opt(small_graph(), CodoOptions.opt5(), cache=cache)
    assert not c.cache_hit
    assert cache.stats.misses == 2


def test_cache_returns_isolated_graphs():
    cache = CompileCache()
    c1 = codo_opt(small_graph(), cache=cache)
    c1.graph.tasks[0].loops[0].parallel = 12345   # caller mutates result
    c2 = codo_opt(small_graph(), cache=cache)
    assert c2.cache_hit
    assert c2.graph.tasks[0].loops[0].parallel != 12345


def test_cache_lru_eviction():
    cache = CompileCache(maxsize=1)
    codo_opt(dm.gesummv(24), cache=cache)
    codo_opt(dm.atax(16, 16), cache=cache)      # evicts gesummv
    assert cache.stats.evictions == 1
    c = codo_opt(dm.gesummv(24), cache=cache)
    assert not c.cache_hit


def test_disk_cache_cross_instance_is_executable(tmp_path):
    d = tmp_path / "cc"
    c1 = codo_opt(small_graph(), cache=CompileCache(disk_dir=d))
    assert list(d.glob("*.pkl")), "no disk entry written"
    # a fresh cache (fresh process analogue) hits via the pickle tier
    cache2 = CompileCache(disk_dir=d)
    counts = dict(PASS_RUN_COUNTS)
    c2 = codo_opt(small_graph(), cache=cache2)
    assert dict(PASS_RUN_COUNTS) == counts
    assert c2.cache_hit and cache2.stats.disk_hits == 1
    assert c2.speedup == c1.speedup
    # declarative disk entries reload fully executable: every task re-derives
    # its fn from its OpSpec, and the lowered program matches the oracle
    assert all(t.fn is not None for t in c2.graph.tasks)
    assert all(not t.fn_is_closure for t in c2.graph.tasks)
    assert not verify_violation_free(c2)
    from repro.core import verify_lowering
    src = small_graph()
    verify_lowering(src, c2, dm.random_inputs(src), rtol=3e-4, atol=3e-4)
    # executable entries are promoted into the memory tier
    c3 = codo_opt(small_graph(), cache=cache2)
    assert c3.cache_hit
    assert cache2.stats.promotions == 1 and cache2.stats.hits == 1
    assert len(cache2) == 1


def test_closure_disk_entry_stripped_and_raises_on_lower(tmp_path):
    # closure-built graphs keep the old behavior: disk entries are
    # structural-only, lowering raises a clear error, and they are NOT
    # promoted into the memory tier
    from repro.core import DataflowGraph, ewise_task, lower
    from repro.core.graph import GraphError

    def build():
        g = DataflowGraph("closure_g")
        g.buffer("x", (8,), kind="input")
        g.buffer("o", (8,), kind="output")
        g.add_task(ewise_task("t", "o", ["x"], (8,),
                              fn=lambda e: {"o": e["x"] * 2}))
        return g

    d = tmp_path / "cc"
    codo_opt(build(), cache=CompileCache(disk_dir=d))
    cache2 = CompileCache(disk_dir=d)
    c = codo_opt(build(), cache=cache2)
    assert c.cache_hit
    assert all(t.fn is None for t in c.graph.tasks)
    with pytest.raises(GraphError, match="no numeric"):
        lower(c)
    assert cache2.stats.promotions == 0 and len(cache2) == 0


def test_cache_returns_isolated_buffer_plans():
    from repro.core import downgrade_to_pingpong
    cache = CompileCache()
    c1 = codo_opt(small_graph(), cache=cache)
    fifo_buf = next(b for b, v in c1.buffer_plan.impl.items() if v == "fifo")
    downgrade_to_pingpong(c1.graph, c1.buffer_plan, fifo_buf, "test mutation")
    c2 = codo_opt(small_graph(), cache=cache)
    assert c2.cache_hit
    assert c2.buffer_plan.impl[fifo_buf] == "fifo", \
        "post-compile plan mutation leaked into the cache"


def test_corrupt_disk_entry_degrades_to_recompile(tmp_path):
    d = tmp_path / "cc"
    cache = CompileCache(disk_dir=d)
    codo_opt(small_graph(), cache=cache)
    for p in d.glob("*.pkl"):
        p.write_bytes(b"not a pickle")
    cache2 = CompileCache(disk_dir=d)
    c = codo_opt(small_graph(), cache=cache2)
    assert not c.cache_hit and cache2.stats.disk_errors == 1


# --------------------------------------------------------------------------
# Batch driver + CLI
# --------------------------------------------------------------------------


def test_batch_driver_grid_and_cache():
    workloads = {"gesummv": lambda: dm.gesummv(24),
                 "residual_block": lambda: dm.residual_block(1, 8, 12)}
    cache = CompileCache()
    jobs = ablation_jobs(workloads, presets=["opt1", "opt5"], budget_units=64)
    results = codo_opt_batch(jobs, cache=cache, max_workers=4)
    assert len(results) == 4
    assert all(r.ok for r in results), [r.error for r in results]
    by_cell = {(r.config, r.preset): r.compiled for r in results}
    assert by_cell[("residual_block", "opt5")].speedup > \
        by_cell[("residual_block", "opt1")].speedup
    # identical second batch: every cell served from cache
    again = codo_opt_batch(jobs, cache=cache, max_workers=4)
    assert all(r.cache_hit for r in again)
    # full-pipeline cells stay violation-free even when served from cache
    # (opt1 keeps coarse violations by design — the Fig. 10 lesson)
    assert all(not verify_violation_free(r.compiled)
               for r in again if r.preset == "opt5")


def test_batch_driver_process_pool(tmp_path):
    """The Table VII grid fans out over worker processes: jobs pickle,
    results come back executable, and a second grid is served from the
    shared disk tier."""
    from repro.core.compiler import batch_workloads

    wl = batch_workloads(seq=8)
    sub = {k: wl[k] for k in ("gpt2-medium", "mamba2-780m")}
    jobs = ablation_jobs(sub, presets=["opt1", "opt5"], budget_units=64)
    cache = CompileCache(disk_dir=tmp_path / "cc")
    results = codo_opt_batch(jobs, cache=cache, max_workers=2,
                             executor="process")
    assert len(results) == 4 and all(r.ok for r in results), \
        [r.error for r in results]
    assert not any(r.cache_hit for r in results)
    # results crossed a process boundary and are still executable
    assert all(t.fn is not None
               for r in results for t in r.compiled.graph.tasks)
    again = codo_opt_batch(jobs, cache=CompileCache(disk_dir=tmp_path / "cc"),
                           max_workers=2, executor="process")
    assert all(r.cache_hit for r in again)


def test_batch_process_pool_rejects_unpicklable_jobs():
    jobs = ablation_jobs({"gesummv": lambda: dm.gesummv(24)},
                         presets=["opt5"], budget_units=64)
    jobs = jobs * 2  # need >1 job to engage the pool
    with pytest.raises(ValueError, match="picklable"):
        codo_opt_batch(jobs, cache=None, max_workers=2, executor="process")


def test_lower_memoization_structural():
    from repro.core import LOWER_CACHE_STATS, clear_lower_cache, lower

    clear_lower_cache()
    c1 = codo_opt(small_graph(), cache=None)
    p1 = lower(c1, jit=False)
    # structurally identical fresh compile reuses the built program
    c2 = codo_opt(small_graph(), cache=None)
    p2 = lower(c2, jit=False)
    assert LOWER_CACHE_STATS["hits"] == 1
    assert p2.fn is p1.fn
    # the hit mirrors fusion decisions onto the caller's graph
    assert [t.fused_group for t in c2.graph.tasks] == \
        [t.fused_group for t in c1.graph.tasks]
    env = dm.random_inputs(small_graph())
    import numpy as np
    for k, v in p1(env).items():
        np.testing.assert_allclose(np.asarray(v), np.asarray(p2(env)[k]))


def test_batch_driver_reports_cell_errors():
    def boom():
        raise RuntimeError("bad build")
    results = codo_opt_batch(
        ablation_jobs({"boom": boom}, presets=["opt5"]), cache=None)
    assert len(results) == 1 and not results[0].ok
    assert "bad build" in results[0].error


def test_arch_block_graphs_compile_violation_free():
    from repro.configs import get_config
    from repro.models.dataflow_models import arch_block_graph
    # one config per family: dense / moe / ssm / hybrid / enc-dec
    for name in ("gpt2-medium", "mixtral-8x22b", "mamba2-780m",
                 "recurrentgemma-9b", "whisper-large-v3"):
        g = arch_block_graph(get_config(name), S=16)
        g.validate()
        c = codo_opt(g, CodoOptions(budget_units=64), cache=None)
        assert not verify_violation_free(c), name
        assert c.speedup >= 1.0, name


def test_cli_smoke_and_second_run_hits_cache(tmp_path, capsys):
    argv = ["--configs", "gpt2-medium", "--opts", "opt1,opt2", "--seq", "8",
            "--budget", "64", "--cache-dir", str(tmp_path / "cc"),
            "--csv", str(tmp_path / "grid.csv"), "--jobs", "2"]
    assert compiler_main(argv) == 0
    out1 = capsys.readouterr().out
    assert "gpt2-medium" in out1 and "0 cache hits" in out1
    assert (tmp_path / "grid.csv").read_text().count("gpt2-medium") == 2
    assert compiler_main(argv) == 0
    out2 = capsys.readouterr().out
    assert "2 cache hits" in out2


def test_cli_list_and_bad_config(capsys):
    assert compiler_main(["--list"]) == 0
    out = capsys.readouterr().out.split()
    assert len(out) == 13 and "resnet18" in out and "gpt2-medium" in out \
        and "gpt2_block" in out
    with pytest.raises(SystemExit):
        compiler_main(["--configs", "not-a-config"])


def test_compiled_dataflow_report_mentions_diagnostics():
    c = codo_opt(small_graph(), cache=None)
    rep = c.report()
    assert "diagnostics:" in rep and "compile time" in rep
    assert "cache hit" in dataclasses.replace(
        c.diagnostics, cache_hit=True).table()
