"""Property-based tests (hypothesis) on compiler invariants.

For arbitrary randomly-wired layer graphs:
  1. codo_opt leaves no coarse violations;
  2. every FIFO-classified edge is fine-violation-free;
  3. the lowered program is numerically equal to the un-optimized oracle;
  4. schedule degrees are legal (≤ trip, never on unsafe loops);
  5. the final latency never exceeds the sequential baseline.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Declared in requirements-dev.txt / the `dev` extra; local runs without it
# skip instead of erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (codo_opt, coarse_violations, fine_violations, lower,
                        verify_violation_free)
from repro.core import frontend as F
from repro.core.frontend import TraceError
from repro.core.reuse import parallel_safety
from repro.models.dataflow_models import GB


def build_random_graph(layer_plan, skips, width):
    """An MLP-ish chain with optional residual skips (SPMC generators)."""
    b = GB("rand")
    x = b.load(b.input("x", (4, width)))
    outs = [x]
    for i, kind in enumerate(layer_plan):
        if kind == 0:
            h = b.fc(outs[-1], width, relu=True)
        elif kind == 1:
            h = b.fc(outs[-1], width)
        else:
            h = b.gelu(outs[-1])
        if i in skips and b.shape[outs[-1]] == b.shape[h]:
            h = b.add(h, outs[-1])
        outs.append(h)
    b.mark_output(outs[-1])
    return b.g


graph_strategy = st.tuples(
    st.lists(st.integers(0, 2), min_size=1, max_size=6),
    st.sets(st.integers(0, 5), max_size=3),
    st.sampled_from([8, 16, 32]),
)


@settings(max_examples=25, deadline=None)
@given(graph_strategy)
def test_compiler_invariants(plan):
    layer_plan, skips, width = plan
    g = build_random_graph(layer_plan, skips, width)
    g.validate()
    compiled = codo_opt(g)

    # 1 & 2: violation-free design
    assert not coarse_violations(compiled.graph)
    assert not verify_violation_free(compiled)

    # 3: functional equivalence vs the oracle
    rng = np.random.default_rng(0)
    env = {buf.name: jnp.asarray(rng.standard_normal(buf.shape) * 0.1,
                                 jnp.float32)
           for buf in g.buffers.values() if buf.kind in ("input", "weight")}
    got = lower(compiled, jit=False)(env)
    want = g.execute(env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=2e-4, atol=2e-4)

    # 4: legal degrees
    for t in compiled.graph.tasks:
        for l in t.loops:
            assert 1 <= l.parallel <= max(l.trip, 1)
            if l.parallel > 1:
                assert parallel_safety(t, l.var) != "unsafe"

    # 5: never slower than sequential
    assert compiled.final.total_cycles <= compiled.baseline.total_cycles * 1.01


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4))
def test_fifo_fraction_bounds(n_layers, seed):
    g = build_random_graph([0] * n_layers, set(), 16)
    c = codo_opt(g)
    assert 0.0 <= c.fifo_fraction <= 1.0
    # pure fc/relu chains are fully streamable after rewriting
    assert c.fifo_fraction == 1.0


# --------------------------------------------------------------------------
# ISSUE-7 frontend vocabulary: concat/split/slice, batched matmul, scans
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 4), min_size=2, max_size=4),
       st.integers(0, 1), st.integers(1, 3))
def test_concat_split_roundtrip(sizes, axis, width):
    """split(concat(xs)) recovers every part exactly, for any partition
    on either axis of a rank-2 tensor."""
    rng = np.random.default_rng(0)

    def shp(s):
        return (s, width) if axis == 0 else (width, s)

    xs = [jnp.asarray(rng.standard_normal(shp(s)), jnp.float32)
          for s in sizes]
    cat = F.concat(xs, axis=axis)
    assert cat.shape[axis] == sum(sizes)
    parts = F.split(cat, sizes, axis=axis)
    assert len(parts) == len(xs)
    for p, x in zip(parts, xs):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.data())
def test_slice_window_bounds(n0, n1, data):
    """Any in-range window equals numpy basic slicing; any out-of-range
    window is a TraceError at trace time, never a silent clamp."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((n0, n1)), jnp.float32)
    s0 = data.draw(st.integers(0, n0 - 1), label="start0")
    z0 = data.draw(st.integers(1, n0 - s0), label="size0")
    s1 = data.draw(st.integers(0, n1 - 1), label="start1")
    z1 = data.draw(st.integers(1, n1 - s1), label="size1")
    got = F.slice_(x, (s0, s1), (z0, z1))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(x)[s0:s0 + z0, s1:s1 + z1])

    def overrun(z):
        return F.slice_(z, (s0, s1), (n0 - s0 + 1, z1))

    with pytest.raises(TraceError):
        F.trace(overrun, (n0, n1), name="overrun_slice")


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(1, 6), st.integers(1, 6),
       st.integers(1, 6))
def test_batched_matmul_shape_inference(B, M, K, N):
    """Traced (B,M,K)@(B,K,N) infers (B,M,N) and executes to jnp.matmul;
    a mismatched contraction dim is rejected at trace time."""
    def f(a, b):
        return F.matmul(a, b)

    g = F.trace(f, (B, M, K), (B, K, N), name="bmm_prop")
    (out,) = g.outputs()
    assert tuple(out.shape) == (B, M, N)
    rng = np.random.default_rng(2)
    env = {"a": jnp.asarray(rng.standard_normal((B, M, K)), jnp.float32),
           "b": jnp.asarray(rng.standard_normal((B, K, N)), jnp.float32)}
    want = jnp.matmul(env["a"], env["b"])
    got = g.execute(dict(env))[out.name]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(TraceError):
        F.trace(f, (B, M, K), (B, K + 1, N), name="bmm_bad")


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 24), st.integers(1, 8))
def test_rglru_scan_matches_sequential_recurrence(B, S, D):
    """The associative-scan reference and the frontend op both equal the
    sequential recurrence h_t = a_t * h_{t-1} + b_t (h_0 = 0) — the
    associativity the chunked kernel relies on."""
    from repro.kernels.rglru import rglru_ref
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0.5, 0.999, (B, S, D)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, S, D)) * 0.1, jnp.float32)
    want = np.zeros((B, S, D), np.float32)
    h = np.zeros((B, D), np.float32)
    for t in range(S):
        h = np.asarray(a)[:, t] * h + np.asarray(b)[:, t]
        want[:, t] = h
    np.testing.assert_allclose(np.asarray(rglru_ref(a, b)), want,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(F.rglru_scan(a, b)), want,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 4), st.integers(1, 6),
       st.integers(1, 6))
def test_ssd_scan_matches_sequential_recurrence(nc, BH, P, N):
    """The chunked-state reference and the frontend op both emit the
    carried-in state h_c (h_0 = 0; h_{c+1} = h_c * dec_c + st_c)."""
    from repro.kernels.ssd import ssd_chunk_scan_ref
    rng = np.random.default_rng(4)
    states = jnp.asarray(rng.standard_normal((nc, BH, P, N)) * 0.1,
                         jnp.float32)
    decay = jnp.asarray(rng.uniform(0.5, 0.99, (nc, BH, 1, 1)), jnp.float32)
    want = np.zeros((nc, BH, P, N), np.float32)
    h = np.zeros((BH, P, N), np.float32)
    for c in range(nc):
        want[c] = h
        h = h * np.asarray(decay)[c] + np.asarray(states)[c]
    np.testing.assert_allclose(np.asarray(ssd_chunk_scan_ref(states, decay)),
                               want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(F.ssd_scan(states, decay)),
                               want, rtol=1e-5, atol=1e-5)
